"""Core: shared types + the five-layer paradigm's cross-layer interfaces."""
from repro.core.types import (  # noqa: F401
    INPUT_SHAPES,
    LONG_500K,
    DECODE_32K,
    MULTI_POD_MESH,
    PREFILL_32K,
    SHAPES_BY_NAME,
    SINGLE_POD_MESH,
    TRAIN_4K,
    LayerSpec,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.core.demand import (  # noqa: F401
    CommDemand,
    CommTask,
    ComputeTask,
    Flow,
    FlowSet,
)
