"""Typed knobs of a cross-layer plan space.

The co-design surface (paper Sec. IV-A) is one joint design space —
placement, per-primitive algorithm, codec budget, scheduling policy,
switch capacity — not a flat keyword list.  A knob declares how much of
that space a caller opens up:

  * :class:`Fixed`  — the knob is pinned to one value (``plan()`` accepts
    only fully-pinned scalar knobs);
  * :class:`Choice` — a finite candidate set for ``search()`` to
    enumerate (or, for the per-primitive algorithm knob, a whitelist the
    selection layer prices as-is);
  * :class:`Search` — an open knob whose candidates come from a
    generator (placement search) or from the selection layer's own
    candidate registry (algorithms).

Knobs live in ``repro.core`` because both ends of the stack read them:
``codesign.api`` walks them top-down, ``ccl.select`` receives them as
per-task constraints instead of ad-hoc ``allow``/``force`` arguments.
"""
from __future__ import annotations

from typing import Any, Tuple


class Knob:
    """Base class; use :class:`Fixed`, :class:`Choice` or :class:`Search`."""

    __slots__ = ()


class Fixed(Knob):
    """The knob is pinned: ``plan()`` uses ``value`` verbatim.  For the
    per-primitive algorithm knob this is a *force* — it bypasses the
    error-budget gate exactly like a single-name ``allow`` did."""

    __slots__ = ("value",)

    def __init__(self, value: Any):
        object.__setattr__(self, "value", value)

    def __setattr__(self, *_):
        raise AttributeError("Fixed is immutable")

    def __repr__(self):
        return f"Fixed({self.value!r})"

    def __eq__(self, other):
        return isinstance(other, Fixed) and self.value == other.value

    def __hash__(self):
        # unhashable values (e.g. a primitive -> budget dict) all share
        # the type's hash: collisions are fine, equal-objects-unequal-
        # hashes would not be (repr() is insertion-order dependent)
        try:
            return hash(("Fixed", self.value))
        except TypeError:
            return hash("Fixed")


class Choice(Knob):
    """A finite candidate set: ``search()`` enumerates the options in the
    given order (the first option is the knob's attribution baseline);
    as an algorithm constraint it is a whitelist that still respects the
    error budget."""

    __slots__ = ("options",)

    def __init__(self, *options: Any):
        if not options:
            raise ValueError("Choice needs at least one option")
        object.__setattr__(self, "options", tuple(options))

    def __setattr__(self, *_):
        raise AttributeError("Choice is immutable")

    def __repr__(self):
        return f"Choice{self.options!r}"

    def __eq__(self, other):
        return isinstance(other, Choice) and self.options == other.options

    def __hash__(self):
        try:
            return hash(("Choice", self.options))
        except TypeError:
            return hash("Choice")  # see Fixed.__hash__


class Search(Knob):
    """An open knob: candidates come from an optimizer — placement pulls
    heuristics + a hill climb (``codesign.placement_search``),
    ``bucket_bytes``/``stagger`` generate deterministic ladders/grids,
    and ``synthesize`` opens the SCCL/TACCL-style schedule synthesizer
    (``ccl.synth``) as a priced candidate next to the registry; as an
    algorithm constraint it means "every registered candidate", i.e. the
    selection layer's default.  ``seeds`` lets the caller inject extra
    starting candidates (e.g. hand-built Placements) — and
    ``search(problem, seeds_dir=...)`` persists each run's winner as a
    warm start for the next (``codesign.seeds``)."""

    __slots__ = ("seeds",)

    def __init__(self, *, seeds: Tuple[Any, ...] = ()):
        object.__setattr__(self, "seeds", tuple(seeds))

    def __setattr__(self, *_):
        raise AttributeError("Search is immutable")

    def __repr__(self):
        return f"Search(seeds={self.seeds!r})" if self.seeds else "Search()"

    def __eq__(self, other):
        return isinstance(other, Search) and self.seeds == other.seeds

    def __hash__(self):
        try:
            return hash(("Search", self.seeds))
        except TypeError:
            return hash("Search")  # see Fixed.__hash__


def as_knob(value: Any) -> Knob:
    """Coerce a raw value into a knob (raw = pinned)."""
    return value if isinstance(value, Knob) else Fixed(value)


def is_free(knob: Knob) -> bool:
    """Free knobs are what ``search()`` walks; Fixed ones are pinned."""
    return isinstance(knob, (Choice, Search))
