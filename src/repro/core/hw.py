"""Target hardware constants (TPU v5e) for the roofline model.

This container runs on CPU; these constants describe the TARGET chip used
to convert the dry-run's compiled FLOP/byte counts into roofline seconds.
"""

PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per ICI link (~v5e per direction)
ICI_LINKS_PER_CHIP = 4       # 2D torus: 4 links per chip (v5e)
DCN_BW_PER_HOST = 25e9       # bytes/s inter-pod (per 8-chip host, approx)
VMEM_BYTES = 128 * 2 ** 20   # ~128 MiB VMEM per core (v5e ~128MB)
HBM_BYTES = 16 * 2 ** 30     # 16 GiB HBM per chip
MXU_TILE = 128               # systolic array dimension


def roofline_seconds(flops: float, hbm_bytes: float, coll_bytes: float,
                     chips: int) -> dict:
    """The three roofline terms (seconds) from Sec. ROOFLINE ANALYSIS.

    ``flops``/``hbm_bytes`` are TOTALS across chips (cost_analysis of the
    SPMD module is per-device; callers pass per-device numbers with
    chips=1).  ``coll_bytes`` is the summed operand bytes of collective ops
    per device."""
    return {
        "compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": coll_bytes / (chips * ICI_BW_PER_LINK),
    }
