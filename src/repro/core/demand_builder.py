"""CommDemand builder: parallelization strategy -> iteration task graph.

This is the quantitative bridge between the model/strategy layer and the
scheduler/CCL/network layers (the downward red arrow in Fig. 5a): given a
ModelConfig, a workload shape and a mesh, emit the compute tasks and the
collective tasks of ONE training iteration with their dependency edges and
sizes.  The schedulers and several benchmarks consume this.

Traffic sizes follow the classical accounting (all bf16 activations / f32
gradient sync unless stated):
  * Megatron TP: one All-Reduce of (B,S,d) per block per direction [7]
  * DP: one gradient sync (AR or RS+AG) per layer bucket
  * MoE EP: All-to-All dispatch+combine of the capacity buffers (fwd and
    bwd each) — the Lina/Janus bottleneck traffic
  * PP: p2p activation transfer per microbatch boundary

Two overlap rewrites make the iteration DAG searchable (the codesign
``bucket_bytes`` / ``decompose`` knobs):
  * ``bucket_bytes`` coalesces/splits per-layer gradient syncs into a
    chained bucket DAG — bucket *i* becomes ready the moment the last
    contributing layer's backward retires (MG-WFBP/ByteScheduler-style
    tensor fusion), exposing the bucket-size tradeoff to the scheduler.
  * :func:`decompose_demand` rewrites TP collectives into the p-step
    ring of ``parallel/collective_matmul.py``: the adjacent matmuls
    split into p partials and each ring permute rides under a partial.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import hw
from repro.core.demand import CommDemand, CommTask, ComputeTask
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DemandParams:
    mfu: float = 0.5              # assumed compute efficiency
    act_bytes: int = 2            # bf16 activations
    grad_bytes: int = 4           # f32 gradient sync
    zero1: bool = True            # reduce-scatter instead of all-reduce
    capacity_factor: float = 1.25
    grad_chunks: int = 1          # Lina-style splitting of gradient sync


def build_demand(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
                 dp_params: Optional[DemandParams] = None,
                 bucket_bytes: Optional[int] = None) -> CommDemand:
    """Emit one iteration's task graph.  ``bucket_bytes`` switches the
    gradient sync from the legacy per-layer (x ``grad_chunks``) tasks to
    fused buckets of that size: layer grads accumulate in backward order
    and a bucket task is emitted the moment it fills, depending on the
    layer whose backward completed it — so big buckets amortize alpha
    while small buckets start (and hide) earlier."""
    if dp_params is None:
        dp_params = DemandParams()
    tp = mesh.tp
    dp = mesh.dp
    chips = mesh.num_devices
    tokens = shape.global_batch * shape.seq_len
    tokens_dev = tokens / chips  # per-device tokens (seq+batch sharded)
    d = cfg.d_model
    peak = hw.PEAK_FLOPS_BF16 * dp_params.mfu

    demand = CommDemand(job_id=f"{cfg.name}:{shape.name}")
    specs = cfg.layer_specs()
    pc = cfg.param_counts()
    per_layer_params = []
    moe_dff = cfg.moe_d_ff or cfg.d_ff

    def layer_active_params(spec) -> float:
        total = 0.0
        hd = cfg.resolved_head_dim
        if spec.mixer in ("attn", "cross_attn"):
            if cfg.attention == "mla":
                total += (d * cfg.q_lora_rank
                          + cfg.q_lora_rank * cfg.num_heads
                          * (hd + cfg.qk_rope_head_dim)
                          + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                          + cfg.kv_lora_rank * cfg.num_heads * 2 * hd
                          + cfg.num_heads * hd * d)
            else:
                total += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        else:
            din = cfg.ssm_d_inner
            total += d * (2 * din + 2 * cfg.ssm_state + cfg.ssm_num_heads) \
                + din * d
        mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
        if spec.ffn == "dense":
            total += mult * d * cfg.d_ff
        elif spec.ffn == "moe":
            total += mult * d * moe_dff * (cfg.top_k
                                           + cfg.num_shared_experts)
        return total

    def layer_total_params(spec) -> float:
        """Gradient-sync size: ALL resident params (every expert), not the
        top-k active subset."""
        total = layer_active_params(spec)
        if spec.ffn == "moe":
            mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
            total += mult * d * moe_dff * (cfg.num_experts - cfg.top_k)
        return total

    # ---------------- forward ----------------
    mult = {"train": (2, 4), "prefill": (2, 0), "decode": (2, 0)}[shape.kind]
    fwd_mult, bwd_mult = mult
    tp_ar_bytes = int(tokens_dev * tp * d * dp_params.act_bytes)

    for i, spec in enumerate(specs):
        ap = layer_active_params(spec)
        per_layer_params.append(ap)
        flops_dev = fwd_mult * ap * tokens / chips
        demand.compute_tasks.append(ComputeTask(
            f"fwd{i}", flops_dev, flops_dev / peak, demand.job_id))
        if tp > 1:
            demand.comm_tasks.append(CommTask(
                f"tp_fwd{i}", "all_reduce", tp_ar_bytes,
                tuple(range(tp)), after_compute=(f"fwd{i}",),
                before_compute=f"fwd{i+1}" if i + 1 < len(specs) else "head",
                job_id=demand.job_id, axis="model"))
        if spec.ffn == "moe" and tp > 1:
            a2a = int(tokens_dev * cfg.top_k * d * dp_params.act_bytes
                      * dp_params.capacity_factor)
            demand.comm_tasks.append(CommTask(
                f"a2a_fwd{i}", "all_to_all", 2 * a2a,  # dispatch+combine
                tuple(range(tp)), after_compute=(f"fwd{i}",),
                before_compute=f"fwd{i+1}" if i + 1 < len(specs) else "head",
                job_id=demand.job_id, axis="model"))

    head_flops = fwd_mult * cfg.padded_vocab * d * tokens / chips
    demand.compute_tasks.append(ComputeTask(
        "head", head_flops, head_flops / peak, demand.job_id))

    if shape.kind != "train":
        return demand

    # ---------------- backward ----------------
    grad_prim = "reduce_scatter" if dp_params.zero1 else "all_reduce"
    bucket_acc = 0        # gradient bytes accumulated towards the bucket
    bucket_id = 0
    if bucket_bytes is not None:
        bucket_bytes = max(1, int(bucket_bytes))

    def emit_bucket(size: int, layer: int, slack: float) -> None:
        nonlocal bucket_id
        demand.comm_tasks.append(CommTask(
            f"gbucket{bucket_id}", grad_prim, size, tuple(range(dp)),
            after_compute=(f"bwd{layer}",), before_compute="opt",
            slack=slack, job_id=demand.job_id, axis="data"))
        bucket_id += 1

    for i in reversed(range(len(specs))):
        spec = specs[i]
        flops_dev = bwd_mult * per_layer_params[i] * tokens / chips
        demand.compute_tasks.append(ComputeTask(
            f"bwd{i}", flops_dev, flops_dev / peak, demand.job_id))
        if tp > 1:
            demand.comm_tasks.append(CommTask(
                f"tp_bwd{i}", "all_reduce", tp_ar_bytes,
                tuple(range(tp)), after_compute=(f"bwd{i}",),
                before_compute=f"bwd{i-1}" if i else "opt",
                job_id=demand.job_id, axis="model"))
        if spec.ffn == "moe" and tp > 1:
            a2a = int(tokens_dev * cfg.top_k * d * dp_params.act_bytes
                      * dp_params.capacity_factor)
            demand.comm_tasks.append(CommTask(
                f"a2a_bwd{i}", "all_to_all", 2 * a2a,
                tuple(range(tp)), after_compute=(f"bwd{i}",),
                before_compute=f"bwd{i-1}" if i else "opt",
                job_id=demand.job_id, axis="model"))
        if dp > 1:
            # gradient sync: overlappable (blocks only the optimizer);
            # slack = how much bwd compute remains to hide behind
            grad_bytes = int(layer_total_params(spec) / tp
                             * dp_params.grad_bytes)
            remaining = sum(per_layer_params[:i]) * bwd_mult \
                * tokens / chips / peak
            if bucket_bytes is None:
                # legacy per-layer sync, optionally Lina-split
                nchunks = max(1, dp_params.grad_chunks)
                for ci in range(nchunks):
                    demand.comm_tasks.append(CommTask(
                        f"grad{i}.{ci}", grad_prim,
                        grad_bytes // nchunks,
                        tuple(range(dp)), after_compute=(f"bwd{i}",),
                        before_compute="opt", slack=remaining,
                        job_id=demand.job_id, axis="data"))
            else:
                # fused buckets: emit every bucket this layer fills
                # (oversize layers emit several), carry the remainder
                bucket_acc += grad_bytes
                while bucket_acc >= bucket_bytes:
                    emit_bucket(bucket_bytes, i, remaining)
                    bucket_acc -= bucket_bytes
    if bucket_bytes is not None and bucket_acc > 0:
        emit_bucket(bucket_acc, 0, 0.0)  # trailing partial bucket

    opt_flops = 10 * pc["total"] / chips  # elementwise AdamW
    demand.compute_tasks.append(ComputeTask(
        "opt", opt_flops, opt_flops / peak, demand.job_id))
    return demand


# primitives decompose_demand knows how to rewrite (the codesign
# ``decompose=True`` knob expands to exactly this tuple)
DECOMPOSABLE_PRIMITIVES = ("all_reduce", "all_gather", "reduce_scatter")


def decompose_demand(demand: CommDemand,
                     primitives: Sequence[str] = DECOMPOSABLE_PRIMITIVES,
                     axis: Optional[str] = "model") -> CommDemand:
    """Rewrite bulk TP collectives into the p-step ring of
    ``parallel/collective_matmul.py`` (Wang et al., ASPLOS'23).

    A matched task with producer compute ``a`` and consumer ``b`` splits
    both into p partials (``a#0..a#{p-1}``) and replaces the bulk
    collective with 2(p-1) ``permute`` tasks carrying n/p each:

      * reduce-scatter half (``matmul_rs``): permute k of the running
        accumulator becomes ready when partial ``a#{k-1}`` retires and
        rides the wire under ``a#k``; only the last one gates ``b#0``.
      * all-gather half (``ag_matmul``): permute k carries the chunk
        partial ``b#k`` consumes and overlaps ``b#{k-1}`` (double
        buffering), so steady-state exposure per step is
        ``max(0, permute - partial)`` — the kernel's actual behaviour.

    Wire bytes are conserved (2(p-1)·n/p per participant = the bulk
    ring), so any JCT win is pure overlap, not free bandwidth.  A plain
    ``all_gather`` rewrites to the AG half only (consumer split), a
    ``reduce_scatter`` to the RS half (producer split).  Tasks whose
    adjacent compute is missing, or whose producer/consumer is already
    split with a different factor, are left intact.  Edges of untouched
    tasks are remapped onto the partials (``after`` -> last partial,
    ``before`` -> first)."""
    primitives = tuple(primitives)
    split: Dict[str, int] = {}          # compute task -> partial count
    decomposed: Dict[str, Tuple[str, Optional[str]]] = {}  # tid -> (a, b)
    compute_ids = {c.task_id for c in demand.compute_tasks}

    for t in demand.comm_tasks:
        p = len(t.group)
        if (t.primitive not in primitives or p <= 1
                or (axis is not None and t.axis != axis)):
            continue
        a = t.after_compute[0] if len(t.after_compute) == 1 else None
        b = t.before_compute
        need = {"all_reduce": (a, b), "all_gather": (None, b),
                "reduce_scatter": (a, None)}[t.primitive]
        anchors = [c for c in need if c is not None]
        if not anchors or any(c not in compute_ids for c in anchors):
            continue
        if any(split.get(c, p) != p for c in anchors):
            continue  # conflicting split factor: leave this task bulk
        for c in anchors:
            split[c] = p
        decomposed[t.task_id] = need

    if not decomposed:
        return demand

    def last(c: str) -> str:
        return f"{c}#{split[c] - 1}" if c in split else c

    def first(c: str) -> str:
        return f"{c}#0" if c in split else c

    out = CommDemand(job_id=demand.job_id)
    for c in demand.compute_tasks:
        p = split.get(c.task_id)
        if p is None:
            out.compute_tasks.append(c)
        else:
            out.compute_tasks.extend(
                dataclasses.replace(c, task_id=f"{c.task_id}#{k}",
                                    flops=c.flops / p,
                                    duration=c.duration / p)
                for k in range(p))

    for t in demand.comm_tasks:
        if t.task_id not in decomposed:
            out.comm_tasks.append(dataclasses.replace(
                t, after_compute=tuple(last(c) for c in t.after_compute),
                before_compute=first(t.before_compute)
                if t.before_compute else None))
            continue
        a, b = decomposed[t.task_id]
        p = len(t.group)
        chunk = max(1, t.size_bytes // p)
        # size_bytes convention: all_reduce carries the per-participant
        # payload, AG/RS the total — either way the ring step moves n/p
        if a is not None:   # reduce-scatter half, under the producer
            for k in range(1, p):
                out.comm_tasks.append(dataclasses.replace(
                    t, task_id=f"{t.task_id}.rs{k}", primitive="permute",
                    size_bytes=chunk, after_compute=(f"{a}#{k - 1}",),
                    before_compute=(first(b) if b is not None else
                                    first(t.before_compute)
                                    if t.before_compute else None)
                    if k == p - 1 else None))
        if b is not None:   # all-gather half, under the consumer
            for k in range(1, p):
                if k == 1:
                    after = (f"{a}#{p - 1}",) if a is not None else \
                        tuple(last(c) for c in t.after_compute)
                else:
                    after = (f"{b}#{k - 2}",)
                out.comm_tasks.append(dataclasses.replace(
                    t, task_id=f"{t.task_id}.ag{k}", primitive="permute",
                    size_bytes=chunk, after_compute=after,
                    before_compute=f"{b}#{k}"))
    return out


def janus_traffic_ratio(cfg: ModelConfig, shape: ShapeConfig,
                        mesh: MeshConfig) -> dict:
    """Janus [10] data-centric vs expert-centric MoE traffic.

    Expert-centric (classic EP): every MoE layer moves 2x the routed token
    activations through All-to-All, fwd + bwd.
    Data-centric (Janus): moves the EXPERT WEIGHTS to the data instead —
    each device fetches the experts it lacks once per layer (prefetchable,
    and sharable across the DP group via broadcast).
    """
    tokens = shape.global_batch * shape.seq_len
    chips = mesh.num_devices
    d = cfg.d_model
    moe_layers = sum(1 for s in cfg.layer_specs() if s.ffn == "moe")
    mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
    expert_params = mult * d * (cfg.moe_d_ff or cfg.d_ff)

    # per-device, per-layer bytes
    token_bytes = 4 * (tokens / chips) * cfg.top_k * d * 2  # a2a x2, fwd+bwd
    expert_bytes = (cfg.num_experts / chips) * expert_params * 2 \
        * (chips - 1) / chips * 2  # fetch all non-local experts (bf16)

    return {
        "expert_centric_bytes": token_bytes * moe_layers,
        "data_centric_bytes": expert_bytes * moe_layers,
        "ratio": (token_bytes / expert_bytes) if expert_bytes else float("inf"),
    }
