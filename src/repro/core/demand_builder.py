"""CommDemand builder: parallelization strategy -> iteration task graph.

This is the quantitative bridge between the model/strategy layer and the
scheduler/CCL/network layers (the downward red arrow in Fig. 5a): given a
ModelConfig, a workload shape and a mesh, emit the compute tasks and the
collective tasks of ONE training iteration with their dependency edges and
sizes.  The schedulers and several benchmarks consume this.

Traffic sizes follow the classical accounting (all bf16 activations / f32
gradient sync unless stated):
  * Megatron TP: one All-Reduce of (B,S,d) per block per direction [7]
  * DP: one gradient sync (AR or RS+AG) per layer bucket
  * MoE EP: All-to-All dispatch+combine of the capacity buffers (fwd and
    bwd each) — the Lina/Janus bottleneck traffic
  * PP: p2p activation transfer per microbatch boundary
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core import hw
from repro.core.demand import CommDemand, CommTask, ComputeTask
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DemandParams:
    mfu: float = 0.5              # assumed compute efficiency
    act_bytes: int = 2            # bf16 activations
    grad_bytes: int = 4           # f32 gradient sync
    zero1: bool = True            # reduce-scatter instead of all-reduce
    capacity_factor: float = 1.25
    grad_chunks: int = 1          # Lina-style splitting of gradient sync


def build_demand(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
                 dp_params: DemandParams = DemandParams()) -> CommDemand:
    tp = mesh.tp
    dp = mesh.dp
    chips = mesh.num_devices
    tokens = shape.global_batch * shape.seq_len
    tokens_dev = tokens / chips  # per-device tokens (seq+batch sharded)
    d = cfg.d_model
    peak = hw.PEAK_FLOPS_BF16 * dp_params.mfu

    demand = CommDemand(job_id=f"{cfg.name}:{shape.name}")
    specs = cfg.layer_specs()
    pc = cfg.param_counts()
    per_layer_params = []
    moe_dff = cfg.moe_d_ff or cfg.d_ff

    def layer_active_params(spec) -> float:
        total = 0.0
        hd = cfg.resolved_head_dim
        if spec.mixer in ("attn", "cross_attn"):
            if cfg.attention == "mla":
                total += (d * cfg.q_lora_rank
                          + cfg.q_lora_rank * cfg.num_heads
                          * (hd + cfg.qk_rope_head_dim)
                          + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                          + cfg.kv_lora_rank * cfg.num_heads * 2 * hd
                          + cfg.num_heads * hd * d)
            else:
                total += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        else:
            din = cfg.ssm_d_inner
            total += d * (2 * din + 2 * cfg.ssm_state + cfg.ssm_num_heads) \
                + din * d
        mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
        if spec.ffn == "dense":
            total += mult * d * cfg.d_ff
        elif spec.ffn == "moe":
            total += mult * d * moe_dff * (cfg.top_k
                                           + cfg.num_shared_experts)
        return total

    def layer_total_params(spec) -> float:
        """Gradient-sync size: ALL resident params (every expert), not the
        top-k active subset."""
        total = layer_active_params(spec)
        if spec.ffn == "moe":
            mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
            total += mult * d * moe_dff * (cfg.num_experts - cfg.top_k)
        return total

    # ---------------- forward ----------------
    mult = {"train": (2, 4), "prefill": (2, 0), "decode": (2, 0)}[shape.kind]
    fwd_mult, bwd_mult = mult
    tp_ar_bytes = int(tokens_dev * tp * d * dp_params.act_bytes)

    for i, spec in enumerate(specs):
        ap = layer_active_params(spec)
        per_layer_params.append(ap)
        flops_dev = fwd_mult * ap * tokens / chips
        demand.compute_tasks.append(ComputeTask(
            f"fwd{i}", flops_dev, flops_dev / peak, demand.job_id))
        if tp > 1:
            demand.comm_tasks.append(CommTask(
                f"tp_fwd{i}", "all_reduce", tp_ar_bytes,
                tuple(range(tp)), after_compute=(f"fwd{i}",),
                before_compute=f"fwd{i+1}" if i + 1 < len(specs) else "head",
                job_id=demand.job_id, axis="model"))
        if spec.ffn == "moe" and tp > 1:
            a2a = int(tokens_dev * cfg.top_k * d * dp_params.act_bytes
                      * dp_params.capacity_factor)
            demand.comm_tasks.append(CommTask(
                f"a2a_fwd{i}", "all_to_all", 2 * a2a,  # dispatch+combine
                tuple(range(tp)), after_compute=(f"fwd{i}",),
                before_compute=f"fwd{i+1}" if i + 1 < len(specs) else "head",
                job_id=demand.job_id, axis="model"))

    head_flops = fwd_mult * cfg.padded_vocab * d * tokens / chips
    demand.compute_tasks.append(ComputeTask(
        "head", head_flops, head_flops / peak, demand.job_id))

    if shape.kind != "train":
        return demand

    # ---------------- backward ----------------
    for i in reversed(range(len(specs))):
        spec = specs[i]
        flops_dev = bwd_mult * per_layer_params[i] * tokens / chips
        demand.compute_tasks.append(ComputeTask(
            f"bwd{i}", flops_dev, flops_dev / peak, demand.job_id))
        if tp > 1:
            demand.comm_tasks.append(CommTask(
                f"tp_bwd{i}", "all_reduce", tp_ar_bytes,
                tuple(range(tp)), after_compute=(f"bwd{i}",),
                before_compute=f"bwd{i-1}" if i else "opt",
                job_id=demand.job_id, axis="model"))
        if spec.ffn == "moe" and tp > 1:
            a2a = int(tokens_dev * cfg.top_k * d * dp_params.act_bytes
                      * dp_params.capacity_factor)
            demand.comm_tasks.append(CommTask(
                f"a2a_bwd{i}", "all_to_all", 2 * a2a,
                tuple(range(tp)), after_compute=(f"bwd{i}",),
                before_compute=f"bwd{i-1}" if i else "opt",
                job_id=demand.job_id, axis="model"))
        if dp > 1:
            # gradient sync: overlappable (blocks only the optimizer);
            # slack = how much bwd compute remains to hide behind
            grad_bytes = int(layer_total_params(spec) / tp
                             * dp_params.grad_bytes)
            prim = "reduce_scatter" if dp_params.zero1 else "all_reduce"
            remaining = sum(per_layer_params[:i]) * bwd_mult \
                * tokens / chips / peak
            nchunks = max(1, dp_params.grad_chunks)
            for ci in range(nchunks):
                demand.comm_tasks.append(CommTask(
                    f"grad{i}.{ci}", prim, grad_bytes // nchunks,
                    tuple(range(dp)), after_compute=(f"bwd{i}",),
                    before_compute="opt", slack=remaining,
                    job_id=demand.job_id, axis="data"))

    opt_flops = 10 * pc["total"] / chips  # elementwise AdamW
    demand.compute_tasks.append(ComputeTask(
        "opt", opt_flops, opt_flops / peak, demand.job_id))
    return demand


def janus_traffic_ratio(cfg: ModelConfig, shape: ShapeConfig,
                        mesh: MeshConfig) -> dict:
    """Janus [10] data-centric vs expert-centric MoE traffic.

    Expert-centric (classic EP): every MoE layer moves 2x the routed token
    activations through All-to-All, fwd + bwd.
    Data-centric (Janus): moves the EXPERT WEIGHTS to the data instead —
    each device fetches the experts it lacks once per layer (prefetchable,
    and sharable across the DP group via broadcast).
    """
    tokens = shape.global_batch * shape.seq_len
    chips = mesh.num_devices
    d = cfg.d_model
    moe_layers = sum(1 for s in cfg.layer_specs() if s.ffn == "moe")
    mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
    expert_params = mult * d * (cfg.moe_d_ff or cfg.d_ff)

    # per-device, per-layer bytes
    token_bytes = 4 * (tokens / chips) * cfg.top_k * d * 2  # a2a x2, fwd+bwd
    expert_bytes = (cfg.num_experts / chips) * expert_params * 2 \
        * (chips - 1) / chips * 2  # fetch all non-local experts (bf16)

    return {
        "expert_centric_bytes": token_bytes * moe_layers,
        "data_centric_bytes": expert_bytes * moe_layers,
        "ratio": (token_bytes / expert_bytes) if expert_bytes else float("inf"),
    }
