"""Cross-layer interface types for the five-layer paradigm.

The survey's central observation (Sec. II-E / IV-A) is that the three layers
are "relatively independent" and would benefit from explicit information
exchange.  This module is that exchange: the parallelization-strategy layer
emits a :class:`CommDemand` (what must be communicated, between whom, and
with which dependencies on compute); the CCL layer turns each
:class:`CommTask` into a :class:`FlowSet` of point-to-point flows for a
concrete algorithm; the network layer + flow scheduler place those flows on
links.  Objective throughout is JCT (job completion time), not per-flow FCT.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional, Sequence, Tuple

Primitive = Literal[
    "all_reduce", "all_gather", "reduce_scatter", "all_to_all",
    "broadcast", "p2p", "permute",
]


@dataclass(frozen=True)
class CommTask:
    """One collective communication task in the iteration task graph."""

    task_id: str
    primitive: Primitive
    size_bytes: int  # per-participant payload (pre-algorithm)
    group: Tuple[int, ...]  # participating device ids (the "communicator")
    # dependency edges: ids of compute tasks that must finish first, and the
    # compute task (if any) that cannot start until this task completes.
    after_compute: Tuple[str, ...] = ()
    before_compute: Optional[str] = None
    # deadline slack (seconds) before this task blocks the critical path;
    # the "deadline" notion from the paper's Fig. 5(b) case study.
    slack: float = 0.0
    job_id: str = "job0"
    # which logical mesh axis the communicator spans ("model" / "data" /
    # "all" / None).  The codesign placement layer uses it to resolve the
    # logical group onto physical devices without guessing from group size.
    axis: Optional[str] = None
    # serving phase tag ("prefill" / "kv" / "decode"; None for training
    # tasks): lets SLO accounting and traces attribute comm to the
    # request-lifecycle stage it serves.
    phase: Optional[str] = None


@dataclass(frozen=True)
class ComputeTask:
    task_id: str
    flops: float
    duration: float  # seconds on the target chip
    job_id: str = "job0"


@dataclass
class CommDemand:
    """Everything the Para. layer tells the layers below (red arrows, Fig.5a)."""

    comm_tasks: List[CommTask] = field(default_factory=list)
    compute_tasks: List[ComputeTask] = field(default_factory=list)
    job_id: str = "job0"

    def total_bytes(self) -> int:
        return sum(t.size_bytes for t in self.comm_tasks)

    def by_primitive(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t in self.comm_tasks:
            out[t.primitive] = out.get(t.primitive, 0) + t.size_bytes
        return out


@dataclass(frozen=True)
class Flow:
    """A point-to-point transfer emitted by a CCL algorithm step."""

    src: int
    dst: int
    size_bytes: int
    task_id: str  # CommTask it belongs to
    step: int  # algorithm step index (steps are sequential within a task)
    job_id: str = "job0"


@dataclass
class FlowSet:
    """The traffic a CCL algorithm generates for one CommTask."""

    task_id: str
    algorithm: str
    flows: List[Flow] = field(default_factory=list)
    num_steps: int = 0
    makespan: Optional[float] = None  # schedule's own completion estimate

    def bytes_on_wire(self) -> int:
        return sum(f.size_bytes for f in self.flows)
