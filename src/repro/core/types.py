"""Core configuration types shared by every layer of the framework.

The paper's three-layer paradigm (Parallelization Strategy / CCL / Network)
is wired together through the types in this module: a ``ModelConfig``
describes the DNN at the top of the stack, a ``ShapeConfig`` describes the
workload, and ``MeshConfig`` describes how the parallelization strategy maps
onto hardware axes.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

LayerKind = Literal["attn", "mamba", "cross_attn"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: its mixer (attention / mamba) and its FFN."""

    mixer: LayerKind = "attn"
    ffn: FFNKind = "dense"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per ``src/repro/configs/<id>.py``.

    All 10 assigned architectures are expressible with this single config:
    dense GQA, MLA, sliding-window, MoE (shared + routed experts), Mamba2/SSD,
    hybrid interleaves, encoder-decoder and VLM cross-attention interleaves.
    """

    name: str
    family: Literal["dense", "ssm", "moe", "audio", "vlm", "hybrid"]
    source: str  # citation bracket from the assignment

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour ---
    attention: Literal["gqa", "mla", "none"] = "gqa"
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # tokens; None = full attention

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0  # 0 -> head_dim

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim; 0 -> d_ff
    moe_layer_period: int = 1  # MoE FFN every k-th layer (Jamba: 2)
    moe_first_dense: int = 0  # first N layers use dense FFN (DeepSeek-V2: 1)
    router_aux_loss: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_kernel: int = 4
    attn_period: int = 0  # hybrid: one attn layer every k layers (Jamba: 8)

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0

    # --- VLM cross-attention interleave ---
    cross_attn_period: int = 0  # one cross-attn layer every k layers
    num_vision_tokens: int = 0  # patch embeddings per image (stub frontend)
    num_audio_frames: int = 0  # frame embeddings (stub frontend)

    # --- misc ---
    ffn_act: Literal["swiglu", "gelu", "geglu"] = "swiglu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 524_288

    # ------------------------------------------------------------------
    # Derived properties
    # ------------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the embedding/LM-head shard cleanly over TP=16."""
        return _round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    # ------------------------------------------------------------------
    # Layer pattern
    # ------------------------------------------------------------------

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Per-layer (mixer, ffn) pattern for the decoder stack."""
        specs = []
        for i in range(self.num_layers):
            # mixer
            if self.attention == "none":
                mixer = "mamba"
            elif self.attn_period > 0:
                # hybrid: one attention layer per period, rest mamba
                mixer = "attn" if i % self.attn_period == 0 else "mamba"
            elif self.cross_attn_period > 0 and (i % self.cross_attn_period
                                                 == self.cross_attn_period - 1):
                mixer = "cross_attn"
            else:
                mixer = "attn"
            # ffn
            if self.ssm_state > 0 and self.attn_period == 0:
                ffn = "none" if self.d_ff == 0 else "dense"
            elif self.is_moe and i >= self.moe_first_dense and (
                    i % self.moe_layer_period == self.moe_layer_period - 1
                    or self.moe_layer_period == 1):
                ffn = "moe"
            else:
                ffn = "dense"
            specs.append(LayerSpec(mixer=mixer, ffn=ffn))
        return tuple(specs)

    def layer_groups(self) -> Tuple[Tuple[Tuple[LayerSpec, ...], int], ...]:
        """Group the layer pattern into (period, repeats) so the stack can be
        built as ``scan`` over stacked params — keeps HLO size O(period), not
        O(num_layers), which is what makes 100-layer dry-runs compile fast.
        """
        specs = self.layer_specs()
        # find, over small prefixes, the smallest period that tiles the rest;
        # prefer the decomposition with the shortest period (most repeats).
        best = ((specs, 1),)
        best_period = len(specs)
        for prefix in range(0, 3):
            body = specs[prefix:]
            m = len(body)
            if not m:
                continue
            for period in range(1, m + 1):
                if m % period:
                    continue
                pat = body[:period]
                if all(body[j] == pat[j % period] for j in range(m)):
                    if period < best_period:
                        groups = []
                        if prefix:
                            groups.append((specs[:prefix], 1))
                        groups.append((pat, m // period))
                        best = tuple(groups)
                        best_period = period
                    break  # smallest period for this prefix found
        return best

    # ------------------------------------------------------------------
    # Parameter counting (used by roofline MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------

    def param_counts(self) -> dict:
        """Returns dict with total and active (per-token) parameter counts."""
        d = self.d_model
        hd = self.resolved_head_dim
        vhd = self.resolved_v_head_dim
        total = 0
        active = 0
        # embeddings (+ untied head)
        emb = self.padded_vocab * d
        total += emb * (1 if self.tie_embeddings else 2)
        active += emb * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attention == "mla":
                p = d * self.q_lora_rank if self.q_lora_rank else 0
                qin = self.q_lora_rank or d
                p += qin * self.num_heads * (hd + self.qk_rope_head_dim)
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * self.num_heads * (hd + vhd)
                p += self.num_heads * vhd * d
                return p
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def mamba_params() -> int:
            din = self.ssm_d_inner
            nh = self.ssm_num_heads
            # in_proj: z, x, B, C, dt ; out_proj
            p = d * (2 * din + 2 * self.ssm_state + nh)
            p += self.ssm_conv_kernel * (din + 2 * self.ssm_state)
            p += nh * 2  # A_log, D
            p += din * d
            return p

        def ffn_params(dff: int) -> int:
            if self.ffn_act in ("swiglu", "geglu"):
                return 3 * d * dff
            return 2 * d * dff

        moe_dff = self.moe_d_ff or self.d_ff
        for spec in self.layer_specs():
            if spec.mixer in ("attn", "cross_attn"):
                a = attn_params()
                total += a
                active += a
            else:
                m = mamba_params()
                total += m
                active += m
            if spec.ffn == "dense":
                f = ffn_params(self.d_ff)
                total += f
                active += f
            elif spec.ffn == "moe":
                routed = self.num_experts * ffn_params(moe_dff)
                shared = self.num_shared_experts * ffn_params(moe_dff)
                total += routed + shared + d * self.num_experts
                active += (self.top_k * ffn_params(moe_dff) + shared
                           + d * self.num_experts)
        if self.encoder_layers:
            # encoder: self-attn + dense ffn per layer, plus decoder gains
            # cross-attn (already counted via cross_attn_period==0 here we add)
            enc = self.encoder_layers * (attn_params() + ffn_params(self.d_ff))
            total += enc
            active += enc
            # decoder cross-attention blocks (one per decoder layer)
            ca = self.num_layers * attn_params()
            total += ca
            active += ca
        return {"total": total, "active": active}


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    # decode shapes attend against a cache of ``seq_len`` and produce 1 token.


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


# ---------------------------------------------------------------------------
# Mesh / parallelization strategy config (the paper's "Para." layer knob)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    """How logical parallelism axes map onto the device mesh."""

    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    # which mesh axes carry each parallel dimension
    data_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    pipeline_axis: Optional[str] = None

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str) -> int:
        return self.shape[self.axis_names.index(name)]

    @property
    def tp(self) -> int:
        return math.prod(self.axis_size(a) for a in self.model_axes)

    @property
    def dp(self) -> int:
        return math.prod(self.axis_size(a) for a in self.data_axes)


SINGLE_POD_MESH = MeshConfig()
MULTI_POD_MESH = MeshConfig(
    shape=(2, 16, 16), axis_names=("pod", "data", "model"),
    data_axes=("pod", "data"), model_axes=("model",))


# ---------------------------------------------------------------------------
# Training hyper-parameters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    zero1: bool = True  # shard optimizer state over the data axis
    remat: bool = True  # activation checkpointing per layer
    grad_sync: Literal["all_reduce", "reduce_scatter"] = "reduce_scatter"
    microbatches: int = 1  # grad-accumulation steps (activation memory / K)
    grad_dtype: Literal["f32", "bf16"] = "f32"  # sync precision (§Perf)
    seed: int = 0
