"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax

from repro.core.types import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_smoke_mesh():
    """1x1 mesh with production axis names — the EP shard_map path runs
    unchanged on a single device (all_to_all over a size-1 axis)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def smoke_mesh_config() -> MeshConfig:
    return MeshConfig(shape=(1, 1))
