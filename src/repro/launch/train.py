"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq 128

``--devices N`` builds an (N/d, d) host-device mesh (set before jax import)
so the pjit path — planner shardings, EP shard_map, ZeRO-1 — runs on CPU
exactly as it would on the production mesh.
"""
import argparse
import os
import sys


def _preparse_devices() -> int:
    for i, a in enumerate(sys.argv):
        if a == "--devices" and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 1


_N_DEV = _preparse_devices()
if _N_DEV > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_N_DEV} "
        + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.checkpoint.io import save_checkpoint  # noqa: E402
from repro.configs import ARCHS, get_config, smoke_config  # noqa: E402
from repro.core.types import MeshConfig, TrainConfig  # noqa: E402
from repro.data.pipeline import make_batches  # noqa: E402
from repro.data.stubs import audio_frames, vision_patches  # noqa: E402
from repro.models.transformer import init_params  # noqa: E402
from repro.optim.adamw import init_opt_state  # noqa: E402
from repro.parallel.planner import make_ctx, param_specs  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps, remat=False)

    mesh = ctx = None
    if args.devices > 1:
        d = args.model_axis
        mcfg = MeshConfig(shape=(args.devices // d, d))
        mesh = jax.make_mesh(mcfg.shape, mcfg.axis_names)
        ctx = make_ctx(mesh, mcfg, remat=False)
        print(f"mesh: {dict(zip(mcfg.axis_names, mcfg.shape))}")

    key = jax.random.PRNGKey(tcfg.seed)
    params = init_params(cfg, key)
    if mesh is not None:
        specs = param_specs(cfg, mcfg)
        params = jax.device_put(params, jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), specs,
            is_leaf=lambda x: isinstance(x, P)))
    opt = init_opt_state(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"vocab={cfg.vocab_size} layers={cfg.num_layers}")

    step_fn = jax.jit(make_train_step(cfg, tcfg, ctx), donate_argnums=(0, 1))
    batches = make_batches(cfg, args.batch, args.seq, seed=tcfg.seed)
    context = None
    if cfg.is_encoder_decoder:
        context = jnp.asarray(audio_frames(cfg, args.batch))
    elif cfg.cross_attn_period:
        context = jnp.asarray(vision_patches(cfg, args.batch))

    t0 = time.time()
    tokens_seen = 0
    for i, batch in zip(range(args.steps), batches):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if context is not None:
            b["context"] = context
        params, opt, m = step_fn(params, opt, b)
        tokens_seen += args.batch * args.seq
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"tok/s={tokens_seen/max(dt,1e-9):,.0f}")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, params, opt)
        print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
