"""Compiled-artifact analysis: collective-byte parsing + roofline terms.

``compiled.cost_analysis()`` gives HLO FLOPs and bytes accessed, but not
collective traffic — we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op, attributing each to the mesh axes it runs over
(derived from replica_groups size) so the ICI vs DCN distinction of the
"Intra-Inter" co-design can be made.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.IGNORECASE)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    ops: List[Tuple[str, int]] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    Result shape ~ operand shape for all-reduce/permute/all-to-all; for
    all-gather it is the post-gather (larger) shape, a conservative upper
    bound on wire traffic; reduce-scatter's result is post-scatter, a lower
    bound — together they bracket ring-algorithm wire bytes well."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind, suffix = m.group(1), m.group(2).lower(), m.group(3)
        if suffix == "-start":
            continue  # async pair: the '-done' line carries the result shape
        nbytes = _shape_bytes(shape_str)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
        stats.ops.append((kind, nbytes))
    return stats


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returns [dict]
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0) or 0)
    out["total_per_device"] = (out["argument_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               - out["alias_size_in_bytes"])
    return out
