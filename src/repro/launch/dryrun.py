import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split  — the two lines above MUST run before any jax import: jax
# locks the device count on first init, and the dry-run needs 512
# placeholder host devices to build the production meshes.
import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.core import hw
from repro.core.types import (INPUT_SHAPES, MULTI_POD_MESH, SHAPES_BY_NAME,
                              SINGLE_POD_MESH, ModelConfig, ShapeConfig,
                              TrainConfig)
from repro.launch.analysis import (cost_summary, memory_summary,
                                   parse_collectives)
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.launch.specs import (cache_shapes, decode_window, input_specs,
                                uses_swa_variant)
from repro.models.transformer import decode_step, forward, init_params
from repro.optim.adamw import init_opt_state
from repro.parallel.planner import (apply_fsdp, batch_specs, cache_specs,
                                    guarded, make_ctx, param_specs,
                                    zero1_spec)
from repro.train.step import make_train_step

FSDP_THRESHOLD_BYTES = 4 * 2 ** 30  # params/device above this -> FSDP
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _bspec(mcfg):
    axes = tuple(mcfg.data_axes)
    return axes if len(axes) > 1 else axes[0]


def _shard(mesh, spec):
    return NamedSharding(mesh, spec)


def build_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
                 fsdp: Optional[bool] = None, causal_skip: bool = False,
                 remat: Optional[bool] = None, unroll: bool = False,
                 microbatches: int = 1, grad_dtype: str = "f32",
                 pad_heads: bool = False, ws_decode: bool = False,
                 cfg_override: Optional[ModelConfig] = None,
                 extra_notes: Optional[list] = None):
    """Lower one (arch x shape x mesh) combination. Returns (lowered, meta)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if pad_heads:
        # §Perf: pad query heads up to the TP degree so attention shards
        # (zero-init extra heads are function-preserving at init time)
        import dataclasses
        tp0 = (MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH).tp
        new_h = ((cfg.num_heads + tp0 - 1) // tp0) * tp0
        cfg = dataclasses.replace(cfg, num_heads=new_h,
                                  head_dim=cfg.resolved_head_dim)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mcfg = mesh_config(multi_pod=multi_pod)
    notes = extra_notes if extra_notes is not None else []

    pspecs = param_specs(cfg, mcfg, notes)
    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16))
    param_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(params_shapes))
    tp = mcfg.tp
    if fsdp is None:
        fsdp = param_bytes / tp > FSDP_THRESHOLD_BYTES
    if fsdp:
        pspecs = apply_fsdp(pspecs, params_shapes, mcfg)
        notes.append(f"fsdp=True (param_bytes/tp = "
                     f"{param_bytes / tp / 2**30:.1f} GiB)")

    if remat is None:
        remat = shape.kind == "train"
    # unroll layer scans: XLA's cost analysis visits while bodies once, so
    # scanned stacks under-count FLOPs/collectives by ~num_layers; unrolled
    # modules give exact counts (compile is slower but still minutes).
    ctx = make_ctx(mesh, mcfg, remat=remat, causal_skip=causal_skip,
                   unroll_layers=unroll)
    ctx.ep_weight_stationary = ws_decode
    p_sh = jax.tree.map(lambda sp: _shard(mesh, sp), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
    ins = input_specs(cfg, shape)
    b = _bspec(mcfg)

    def in_shard(name, sds):
        if name == "pos":
            return _shard(mesh, P())
        axes = (b,) + (None,) * (len(sds.shape) - 1)
        return _shard(mesh, guarded(sds.shape, axes, mcfg, notes,
                                    what=f"input:{name}"))

    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "fsdp": bool(fsdp),
        "swa_variant": uses_swa_variant(cfg, shape),
        "causal_skip": causal_skip,
        "param_bytes": param_bytes,
        "notes": list(notes),
    }

    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=microbatches, grad_dtype=grad_dtype)
        opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
        opt_specs = {
            "m": jax.tree.map(
                lambda sp, sh: zero1_spec(sp, sh.shape, mcfg), pspecs,
                opt_shapes["m"], is_leaf=lambda x: isinstance(x, P)),
            "v": jax.tree.map(
                lambda sp, sh: zero1_spec(sp, sh.shape, mcfg), pspecs,
                opt_shapes["v"], is_leaf=lambda x: isinstance(x, P)),
            "step": P(),
        }
        o_sh = jax.tree.map(lambda sp: _shard(mesh, sp), opt_specs,
                            is_leaf=lambda x: isinstance(x, P))
        batch_sh = {k: in_shard(k, v) for k, v in ins.items()}
        step_fn = make_train_step(cfg, tcfg, ctx)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, batch_sh))
        lowered = jitted.lower(params_shapes, opt_shapes, ins)
        return lowered, meta

    if shape.kind == "prefill":
        batch_sh = {k: in_shard(k, v) for k, v in ins.items()}

        def prefill_fn(params, batch):
            logits, _ = forward(cfg, params, batch["tokens"],
                                context=batch.get("context"), ctx=ctx)
            return logits

        jitted = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh))
        lowered = jitted.lower(params_shapes, ins)
        return lowered, meta

    # ---- decode ----
    win = decode_window(cfg, shape)
    c_shapes = cache_shapes(cfg, shape, params_shapes)
    c_specs = cache_specs(cfg, mcfg, shape.global_batch, c_shapes, notes)
    c_sh = jax.tree.map(lambda sp: _shard(mesh, sp), c_specs,
                        is_leaf=lambda x: isinstance(x, P))
    tok_sh = in_shard("tokens", ins["tokens"])
    pos_sh = _shard(mesh, P())

    def decode_fn(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos, ctx=ctx,
                           window=win)

    jitted = jax.jit(decode_fn,
                     in_shardings=(p_sh, c_sh, tok_sh, pos_sh))
    lowered = jitted.lower(params_shapes, c_shapes, ins["tokens"],
                           ins["pos"])
    meta["cache_bytes"] = sum(l.size * l.dtype.itemsize
                              for l in jax.tree.leaves(c_shapes))
    return lowered, meta


# ---------------------------------------------------------------------------
# Exact cost accounting via reduced-depth unrolled variants
# ---------------------------------------------------------------------------
#
# XLA's cost analysis visits a while-loop body ONCE, so the full-depth scan
# module under-counts FLOPs/bytes/collectives by ~num_layers.  Unrolling the
# full stack is exact but compiles for tens of minutes at 100 layers.
# Instead we compile tiny unrolled variants (last layer-group at 1 and 2
# repeats; encoder at 1 and 2 layers) and extrapolate linearly — exact,
# because repeated layers are structurally identical.


def _cost_vector(compiled) -> Dict[str, float]:
    cost = cost_summary(compiled)
    coll = parse_collectives(compiled.as_text())
    vec = {"flops": cost["flops"], "bytes": cost["bytes"],
           "transcendentals": cost["transcendentals"],
           "collective_bytes": float(coll.total_bytes)}
    for k, v in coll.bytes_by_kind.items():
        vec[f"coll_{k}"] = float(v)
    for k, v in coll.count_by_kind.items():
        vec[f"count_{k}"] = float(v)
    return vec


def _vec_add(a, b, scale=1.0):
    keys = set(a) | set(b)
    return {k: a.get(k, 0.0) + scale * b.get(k, 0.0) for k in keys}


def _reduced(cfg: ModelConfig, last_repeats: int,
             encoder_layers: Optional[int] = None) -> ModelConfig:
    import dataclasses
    groups = cfg.layer_groups()
    assert all(r == 1 for _, r in groups[:-1]), \
        "cost extrapolation assumes only the last group repeats"
    n = sum(len(p) for p, _ in groups[:-1]) + len(groups[-1][0]) * last_repeats
    kw = {"num_layers": n}
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = (encoder_layers if encoder_layers is not None
                                else 1)
    return dataclasses.replace(cfg, **kw)


def measure_costs(arch: str, shape_name: str, *, multi_pod: bool = False,
                  fsdp: Optional[bool] = None, **kw) -> Dict[str, float]:
    """Exact whole-model cost vector via reduced-depth unrolled compiles."""
    cfg = get_config(arch)
    # pin fsdp from the full-size config so variants shard identically
    if fsdp is None:
        params_shapes = jax.eval_shape(
            lambda: init_params(cfg, jax.random.PRNGKey(0),
                                dtype=jnp.bfloat16))
        pb = sum(l.size * l.dtype.itemsize
                 for l in jax.tree.leaves(params_shapes))
        fsdp = pb / mesh_config(multi_pod=multi_pod).tp > FSDP_THRESHOLD_BYTES

    def compile_cost(c):
        lowered, _ = build_dryrun(arch, shape_name, multi_pod=multi_pod,
                                  fsdp=fsdp, unroll=True, cfg_override=c,
                                  **kw)
        return _cost_vector(lowered.compile())

    last_r = cfg.layer_groups()[-1][1]
    base = compile_cost(_reduced(cfg, 1))
    total = dict(base)
    if last_r > 1:
        var = compile_cost(_reduced(cfg, 2))
        per_layer = _vec_add(var, base, scale=-1.0)
        total = _vec_add(total, per_layer, scale=float(last_r - 1))
    if cfg.is_encoder_decoder and cfg.encoder_layers > 1:
        var_e = compile_cost(_reduced(cfg, 1, encoder_layers=2))
        per_enc = _vec_add(var_e, base, scale=-1.0)
        total = _vec_add(total, per_enc, scale=float(cfg.encoder_layers - 1))
    return total


def analyse(meta, mem, costs) -> Dict[str, Any]:
    cfg = get_config(meta["arch"])
    shape = SHAPES_BY_NAME[meta["shape"]]
    chips = 512 if meta["mesh"] == "2x16x16" else 256

    terms = hw.roofline_seconds(costs["flops"], costs["bytes"],
                                costs["collective_bytes"], chips=1)
    dominant = max(terms, key=terms.get)

    pc = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * pc["active"] * tokens  # fwd+bwd
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * pc["active"] * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2 * pc["active"] * tokens
    useful_ratio = model_flops / max(costs["flops"] * chips, 1.0)

    return dict(
        meta,
        chips=chips,
        flops_per_device=costs["flops"],
        bytes_per_device=costs["bytes"],
        transcendentals=costs["transcendentals"],
        collective_bytes_per_device=costs["collective_bytes"],
        collectives_by_kind={k[5:]: v for k, v in costs.items()
                             if k.startswith("coll_")},
        collective_counts={k[6:]: v for k, v in costs.items()
                           if k.startswith("count_")},
        memory=mem,
        roofline=terms,
        dominant=dominant,
        model_flops=model_flops,
        useful_flops_ratio=useful_ratio,
    )


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            save: bool = True, verbose: bool = True, skip_costs: bool = False,
            **kw) -> Dict[str, Any]:
    # 1) full-depth scan module: proves the combination lowers+compiles on
    #    the production mesh, and yields the per-device memory picture.
    t0 = time.time()
    lowered, meta = build_dryrun(arch, shape_name, multi_pod=multi_pod, **kw)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = memory_summary(compiled)
    if verbose:
        print(compiled.memory_analysis())
    # 2) exact cost vector from reduced-depth unrolled variants.
    if skip_costs:
        costs = _cost_vector(compiled)
    else:
        costs = measure_costs(arch, shape_name, multi_pod=multi_pod, **kw)
    t3 = time.time()
    result = analyse(meta, mem, costs)
    result["lower_s"] = round(t1 - t0, 2)
    result["compile_s"] = round(t2 - t1, 2)
    result["cost_measure_s"] = round(t3 - t2, 2)
    if verbose:
        print({k: costs.get(k) for k in ("flops", "bytes",
                                         "transcendentals",
                                         "collective_bytes")})
        r = result["roofline"]
        print(f"[{arch} x {shape_name} x {result['mesh']}] "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"dominant={result['dominant']} "
              f"useful={result['useful_flops_ratio']:.2f} "
              f"temp={mem['temp_size_in_bytes']/2**30:.2f}GiB "
              f"(lower {result['lower_s']}s compile {result['compile_s']}s "
              f"costs {result['cost_measure_s']}s)")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        def _default(v):
            # identity-safe default check (True == 1 in Python!)
            return v is None or v is False or v == "f32" or \
                (v == 1 and v is not True)

        tag = f"{arch}_{shape_name}_{result['mesh']}"
        for k, v in sorted(kw.items()):
            if not _default(v):
                tag += f"_{k}-{v}"
        result["variant_kwargs"] = {k: v for k, v in kw.items()
                                    if not _default(v)}
        with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in INPUT_SHAPES] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--skip-costs", action="store_true",
                    help="lower+compile+memory only (no cost extrapolation)"
                         " — used for the multi-pod lowering proof")
    ap.add_argument("--resume", action="store_true",
                    help="skip combinations whose result JSON exists")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else [s.name for s in INPUT_SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.resume:
                    mesh_tag = "2x16x16" if mp else "16x16"
                    fp = os.path.join(RESULTS_DIR,
                                      f"{arch}_{shape}_{mesh_tag}.json")
                    if os.path.exists(fp):
                        print(f"skip (exists): {arch} x {shape} x {mesh_tag}")
                        continue
                try:
                    run_one(arch, shape, multi_pod=mp,
                            causal_skip=args.causal_skip,
                            skip_costs=args.skip_costs,
                            save=not args.no_save)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"FAIL [{arch} x {shape} x mp={mp}]: {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
