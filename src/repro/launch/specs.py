"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs`` is the dry-run's workload description: training batches,
prefill prompts, or decode steps with their KV/SSM caches.  The long-context
policy (which architectures decode 500k tokens natively vs. via the
sliding-window variant) lives here as ``decode_window``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig, ShapeConfig

SWA_VARIANT_WINDOW = 8192


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Window override for decode shapes.  None = model's own policy.

    long_500k policy (DESIGN.md §Arch-applicability):
      native   — SSM (no KV), hybrid (9 attn layers, seq-sharded cache),
                 MLA (compact latent cache), archs with built-in SWA;
      variant  — full-attention dense/MoE/VLM archs run the sliding-window
                 variant (window 8192), flagged in the roofline table.
    """
    if shape.name != "long_500k":
        return None
    if cfg.sliding_window or cfg.attention == "none" or cfg.attn_period:
        return None
    if cfg.attention == "mla":
        return None
    return SWA_VARIANT_WINDOW


def uses_swa_variant(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    return decode_window(cfg, shape) is not None


def context_spec(cfg: ModelConfig, batch: int, dtype) -> Optional[Any]:
    if cfg.is_encoder_decoder:
        return jax.ShapeDtypeStruct((batch, cfg.num_audio_frames,
                                     cfg.d_model), dtype)
    if cfg.cross_attn_period:
        return jax.ShapeDtypeStruct((batch, cfg.num_vision_tokens,
                                     cfg.d_model), dtype)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model inputs as ShapeDtypeStructs for ``.lower()``."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        ctxs = context_spec(cfg, b, dtype)
        if ctxs is not None:
            out["context"] = ctxs
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        ctxs = context_spec(cfg, b, dtype)
        if ctxs is not None:
            out["context"] = ctxs
        return out
    # decode: one new token against a cache of seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig, params_shapes,
                 dtype=jnp.bfloat16):
    """eval_shape of the decode cache for this workload."""
    from repro.models.transformer import init_cache
    win = decode_window(cfg, shape)
    ctx_s = context_spec(cfg, shape.global_batch, dtype)

    def build(p, c):
        return init_cache(cfg, p, shape.global_batch, shape.seq_len,
                          dtype, context=c, window=win)

    if ctx_s is not None:
        return jax.eval_shape(build, params_shapes, ctx_s)
    return jax.eval_shape(lambda p: build(p, None), params_shapes)
