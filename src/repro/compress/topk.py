"""Top-k magnitude sparsification with error feedback (DGC-style).

Keeps the ``fraction`` largest-magnitude entries (values + int32 indices
on the wire, hence ``wire_ratio = 2 * fraction`` for fp32 payloads) and
carries the dropped mass in a residual that re-enters the next step's
input — the error-feedback loop that turns a 97%-per-step lossy codec
into an asymptotically unbiased one (the property test in
``tests/test_compress.py`` pins this down).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec, CodecSpec, Encoded, codec_spec


class TopKCodec(Codec):
    def __init__(self, fraction: float = 0.05,
                 spec: Optional[CodecSpec] = None):
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction
        self.spec = spec or codec_spec("topk")

    def _k(self, n: int) -> int:
        return max(1, int(n * self.fraction))

    def _encode(self, x, key=None) -> Encoded:
        flat = x.reshape(-1).astype(jnp.float32)
        k = self._k(flat.size)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        values = flat[idx]
        wire = k * (4 + 4)  # fp32 value + int32 index
        return Encoded(self.spec.name, x.shape, x.dtype,
                       (values, idx.astype(jnp.int32)), wire)

    def decode(self, enc: Encoded):
        values, idx = enc.arrays
        n = math.prod(enc.shape)
        dense = jnp.zeros((n,), jnp.float32).at[idx].set(values)
        return dense.reshape(enc.shape)
