"""Uniform int8/int4 quantization codec.

One symmetric absmax scale per tensor (the wire-cheap variant; the Pallas
kernel path uses per-row scales for accuracy at the same asymptotic
ratio); int4 payloads are nibble-packed so the wire bytes really are half
of int8's.

Rounding: deterministic round-to-nearest by default — matching the
executable compressed ring and the keyless pricing paths.  Construct with
``stochastic=True`` (and pass ``key=`` to every encode) for unbiased
rounding, E[decode(encode(x))] = x — what keeps the quantized ring
all-reduce's error O(sqrt(p)) rather than O(p) across accumulation steps;
a stochastic codec with no key raises instead of silently degrading to
biased rounding.
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.compress.codec import Codec, CodecSpec, Encoded, codec_spec
from repro.kernels.compress.ref import (dequantize_ref, pack_int4,
                                        quantize_ref, unpack_int4)


class QuantCodec(Codec):
    def __init__(self, bits: int = 8, stochastic: bool = False,
                 spec: Optional[CodecSpec] = None):
        if bits not in (4, 8):
            raise ValueError(f"bits must be 4 or 8, got {bits}")
        self.bits = bits
        self.stochastic = stochastic
        self.spec = spec or codec_spec(f"q{bits}")

    def _encode(self, x, key=None) -> Encoded:
        if self.stochastic and key is None:
            raise ValueError(
                "QuantCodec(stochastic=True) needs key= on every encode; "
                "use stochastic=False for deterministic rounding")
        flat = x.reshape(-1)
        q, scale = quantize_ref(flat, bits=self.bits,
                                stochastic=self.stochastic, key=key)
        if self.bits == 4:
            q = pack_int4(q)
        wire = math.ceil(flat.size * self.bits / 8) + 4  # payload + scale
        return Encoded(self.spec.name, x.shape, x.dtype,
                       (q, scale.reshape(1)), wire)

    def decode(self, enc: Encoded):
        q, scale = enc.arrays
        n = math.prod(enc.shape)
        if self.bits == 4:
            q = unpack_int4(q, n)
        return dequantize_ref(q, scale[0]).reshape(enc.shape).astype(
            jnp.float32)
