"""Common codec API for gradient compression (paper Sec. II-A lever 3).

The parallelization-strategy layer can shrink the exposed-communication
term by sending *less* instead of sending *faster*: quantization,
sparsification, and low-rank factorization (Shi et al. / Tang et al.
quantitative surveys).  This module defines the layer interface the rest
of the stack programs against:

  * :class:`CodecSpec`  — the *static* contract a codec makes with the
    pricing layers: wire-byte ratio, nominal relative error, whether an
    error-feedback residual compensates across steps, and how many
    full-payload memory passes encode+decode cost.  Specs are plain
    numbers so ``ccl.cost`` / ``ccl.select`` can price compressed
    candidates without touching jax.
  * :class:`Codec`      — the executable face: ``encode(x, state) ->
    (Encoded, state)`` / ``decode(Encoded) -> x`` as jit-traceable JAX
    functions (``Encoded`` is a registered pytree), with the DGC-style
    error-feedback residual handled generically in the base class.
  * a registry (``get_codec`` / ``codec_spec``) plus the
    ``"<algorithm>+<codec>"`` naming convention (``split_algorithm`` /
    ``base_algorithm``) used by ``ccl.algorithms`` to register compressed
    collective candidates such as ``ring+q8`` and ``ps+topk``.

Concrete codecs live in :mod:`repro.compress.quant` (int8/int4 uniform
quantization with stochastic rounding), :mod:`repro.compress.topk`
(magnitude sparsification with error feedback), and
:mod:`repro.compress.lowrank` (PowerSGD-style rank-r factorization); the
hot encode/decode loops have Pallas TPU kernels under
``repro.kernels.compress`` with the pure-JAX references these codecs run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class CodecSpec:
    """The static contract between a codec and the pricing layers.

    ``wire_ratio``  — wire bytes emitted per fp32 payload byte (< 1).
    ``rel_error``   — nominal single-shot relative L2 error, the number the
                      selection layer's ``error_budget`` knob is compared
                      against (a documented modeling constant, not a bound).
    ``error_feedback`` — the codec keeps a residual state that re-injects
                      the compression error into the next step, halving the
                      *effective* long-run error (see ``effective_error``).
    ``passes``      — full-payload memory passes encode+decode cost, the
                      compute-overhead term of the cost models.
    """

    name: str
    wire_ratio: float
    rel_error: float
    error_feedback: bool = False
    passes: float = 2.0

    @property
    def effective_error(self) -> float:
        """What selection compares against the error budget: codecs with an
        error-feedback residual are charged half their single-shot error
        (the residual provably re-injects what one step dropped)."""
        return self.rel_error * (0.5 if self.error_feedback else 1.0)


# ---------------------------------------------------------------------------
# "<base>+<codec>" naming convention for compressed collective candidates
# ---------------------------------------------------------------------------


def split_algorithm(name: str) -> Tuple[str, Optional[str]]:
    """``"ring+q8" -> ("ring", "q8")``; plain names get ``(name, None)``."""
    if "+" in name:
        base, codec = name.split("+", 1)
        return base, codec
    return name, None


def base_algorithm(name: str) -> str:
    """The underlying collective algorithm a candidate name resolves to.
    ``ps`` (parameter-server) is an alias for the ``atp`` flow pattern —
    the compressed PS candidates push sparse gradients through the same
    worker->ps->worker schedule."""
    base, _ = split_algorithm(name)
    return "atp" if base == "ps" else base


# ---------------------------------------------------------------------------
# Executable codecs
# ---------------------------------------------------------------------------


@dataclass
class Encoded:
    """A compressed payload: the wire arrays plus what decode needs.

    Registered as a jax pytree (arrays are children) so encode/decode
    round-trips stay jit-traceable and the arrays can be ``ppermute``d
    individually by the compressed collectives in ``ccl.primitives``.
    """

    codec: str
    shape: Tuple[int, ...]
    dtype: Any
    arrays: Tuple[Any, ...]
    wire_bytes: int = 0


def _encoded_flatten(e: Encoded):
    return tuple(e.arrays), (e.codec, e.shape, e.dtype, e.wire_bytes)


def _encoded_unflatten(aux, children):
    codec, shape, dtype, wire = aux
    return Encoded(codec, shape, dtype, tuple(children), wire)


def _register_pytree() -> None:
    import jax

    jax.tree_util.register_pytree_node(
        Encoded, _encoded_flatten, _encoded_unflatten)


try:  # jax is a hard dependency of the repo; guard only for doc tooling
    _register_pytree()
except ImportError:  # pragma: no cover
    pass


class Codec:
    """Base class: error feedback handled generically.

    Subclasses implement ``_encode(x, key)`` (compress, no residual logic)
    and ``decode(enc)``.  ``encode`` folds the carried residual into the
    input first and returns the new residual, so a caller's loop is just::

        state = codec.init_state(grad)
        for step ...:
            enc, state = codec.encode(grad, state)
            send(enc.arrays); ...
    """

    spec: CodecSpec

    def init_state(self, x):
        """Zero residual for error-feedback codecs, else ``None``."""
        if not self.spec.error_feedback:
            return None
        import jax.numpy as jnp

        return jnp.zeros(x.shape, jnp.float32)

    def encode(self, x, state=None, key=None):
        """Compress ``x`` (+ carried residual) -> ``(Encoded, new_state)``."""
        if self.spec.error_feedback and state is not None:
            y = x.astype(state.dtype) + state
        else:
            y = x
        enc = self._encode(y, key=key)
        if self.spec.error_feedback:
            new_state = y - self.decode(enc).astype(y.dtype)
            return enc, new_state
        return enc, state

    def _encode(self, x, key=None) -> Encoded:
        raise NotImplementedError

    def decode(self, enc: Encoded):
        raise NotImplementedError

    def wire_bytes(self, size_bytes: int) -> int:
        """Static wire-byte estimate for an fp32 payload of ``size_bytes``."""
        return max(int(size_bytes * self.spec.wire_ratio), 1)

    def roundtrip(self, x, state=None, key=None):
        """encode+decode in one call (what a compressed collective applies
        per hop); returns ``(decoded, new_state)``."""
        enc, state = self.encode(x, state=state, key=key)
        return self.decode(enc), state


# ---------------------------------------------------------------------------
# Registry.  Specs are static (importable without jax); instances are built
# lazily so pricing-only callers never pay the codec import.
# ---------------------------------------------------------------------------

# Nominal spec constants (modeling choices, asserted against measured
# behaviour in tests/test_compress.py):
#   q8 / q4   — wire_ratio = bits/32 (+ one fp32 scale, amortized away);
#               rel_error ~ half an int step relative to absmax.
#   topk      — keep the top 5% magnitudes; values + int32 indices on the
#               wire (2 * fraction); single-shot error ~ sqrt(1 - fraction)
#               of the payload norm, compensated by error feedback.
#   lowrank   — PowerSGD rank-4: (m+n)*r vs m*n words; passes charged for
#               the two projections + orthonormalization.
SPECS: Dict[str, CodecSpec] = {
    "q8": CodecSpec("q8", wire_ratio=8 / 32, rel_error=0.006,
                    error_feedback=False, passes=2.0),
    "q4": CodecSpec("q4", wire_ratio=4 / 32, rel_error=0.09,
                    error_feedback=False, passes=2.0),
    "topk": CodecSpec("topk", wire_ratio=2 * 0.05, rel_error=0.97,
                      error_feedback=True, passes=3.0),
    "lowrank": CodecSpec("lowrank", wire_ratio=0.06, rel_error=0.7,
                         error_feedback=True, passes=6.0),
}

_FACTORIES: Dict[str, Callable[[], "Codec"]] = {}
_INSTANCES: Dict[str, "Codec"] = {}


def register_codec(spec: CodecSpec, factory: Callable[[], Codec]) -> None:
    SPECS[spec.name] = spec
    _FACTORIES[spec.name] = factory
    _INSTANCES.pop(spec.name, None)


def codec_spec(name: str) -> CodecSpec:
    """Static pricing spec for ``name`` (no jax import)."""
    try:
        return SPECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; registered: {list(SPECS)}")


def _default_factory(name: str) -> Codec:
    if name in ("q8", "q4"):
        from repro.compress.quant import QuantCodec

        return QuantCodec(bits=8 if name == "q8" else 4)
    if name == "topk":
        from repro.compress.topk import TopKCodec

        return TopKCodec(fraction=0.05)
    if name == "lowrank":
        from repro.compress.lowrank import LowRankCodec

        return LowRankCodec(rank=4)
    raise KeyError(f"unknown codec {name!r}; registered: {list(SPECS)}")


def get_codec(name: str) -> Codec:
    """Executable codec instance for ``name`` (cached)."""
    if name not in _INSTANCES:
        factory = _FACTORIES.get(name)
        _INSTANCES[name] = factory() if factory else _default_factory(name)
    return _INSTANCES[name]
