"""PowerSGD-style low-rank gradient codec with error feedback.

One subspace iteration: P = orth(M @ Q0), Q = M^T @ P, wire = (P, Q)
— ``(m + n) * r`` words against ``m * n``.  The projection matmuls are
the ``repro.kernels.compress`` matmul primitive; Q0 is a fixed
pseudo-random test matrix (deterministic per shape, so every rank in a
collective projects into the same subspace and partial sums stay
consistent — PowerSGD's linearity property).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec, CodecSpec, Encoded, codec_spec
from repro.kernels.compress.ref import matmul_ref


def _matrix_shape(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """View any payload as a near-square matrix (static, trace-safe)."""
    n = math.prod(shape)
    if len(shape) >= 2:
        m = shape[0]
        return m, n // m
    # best divisor <= sqrt(n); prime payloads degrade to a single row
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = d
        d += 1
    return best, n // best


class LowRankCodec(Codec):
    def __init__(self, rank: int = 4, spec: Optional[CodecSpec] = None):
        if rank < 1:
            raise ValueError(f"rank must be >= 1, got {rank}")
        self.rank = rank
        self.spec = spec or codec_spec("lowrank")

    def _encode(self, x, key=None) -> Encoded:
        m, n = _matrix_shape(x.shape)
        mat = x.reshape(m, n).astype(jnp.float32)
        r = min(self.rank, m, n)
        q0 = jax.random.normal(jax.random.PRNGKey(r + n % 9973), (n, r))
        p = matmul_ref(mat, q0)             # (m, r)
        p, _ = jnp.linalg.qr(p)             # orthonormal columns
        q = matmul_ref(mat.T, p)            # (n, r)
        wire = (m + n) * r * 4
        return Encoded(self.spec.name, x.shape, x.dtype, (p, q), wire)

    def decode(self, enc: Encoded):
        p, q = enc.arrays
        return matmul_ref(p, q.T).reshape(enc.shape)
