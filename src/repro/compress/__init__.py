"""Gradient-compression subsystem (paper Sec. II-A lever 3; Shi/Tang
quantitative surveys).

``codec``      the layer interface: static :class:`CodecSpec` pricing
               contracts, the executable :class:`Codec` API with generic
               error feedback, the registry, and the ``"algo+codec"``
               naming convention compressed collective candidates use.
``quant``      int8/int4 uniform quantization with stochastic rounding.
``topk``       magnitude sparsification with error-feedback residual.
``lowrank``    PowerSGD-style rank-r factorization.

Vertical integration: ``ccl.primitives.compressed_ring_all_reduce``
executes a quantized ring on real devices; ``ccl.algorithms`` registers
compressed flow-schedule candidates (``ring+q8``, ``ps+topk``, ...);
``ccl.cost`` / ``ccl.select`` price wire-byte savings against
encode/decode overhead; ``codesign.plan_iteration(error_budget=...)``
lets selection pick compression per CommTask and reports bytes saved.
"""
from repro.compress.codec import (Codec, CodecSpec, Encoded,  # noqa: F401
                                  SPECS, base_algorithm, codec_spec,
                                  get_codec, register_codec,
                                  split_algorithm)
from repro.compress.lowrank import LowRankCodec  # noqa: F401
from repro.compress.quant import QuantCodec  # noqa: F401
from repro.compress.topk import TopKCodec  # noqa: F401
