"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D). Materialized softmax."""
    b, h, sq, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v)
    return o.reshape(b, h, sq, d).astype(q.dtype)
