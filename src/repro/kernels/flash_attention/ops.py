"""Jitted public wrapper for the flash-attention kernel.

``interpret=True`` executes the kernel body on CPU (how this container
validates it); on a real TPU pass ``interpret=False``.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """Flash attention with GQA/causal/sliding-window support.

    q: (B, H, Sq, D); k, v: (B, KV, Sk, D); returns (B, H, Sq, D)."""
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=interpret)


reference = attention_ref
