"""Flash-attention Pallas TPU kernel (GQA, causal, sliding-window).

TPU adaptation of the classic GPU flash attention: instead of warp-level
softmax reductions, the online-softmax state (m, l, acc) lives in VMEM
scratch that persists across the sequential KV-block grid dimension, and
the (bq x bk) score tile is a single MXU matmul.  Block sizes are multiples
of 128 to align with the MXU systolic array; K/V tiles stream HBM->VMEM via
the BlockSpec pipeline.

Layout: q (B, H, Sq, D), k/v (B, KV, Sk, D) -> out (B, H, Sq, D).
Grid: (B, H, Sq/bq, Sk/bk); the last dimension is 'arbitrary' (sequential)
so scratch carries across KV blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window, bq: int, bk: int,
                 num_kblocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    # skip fully-masked blocks (causal: K block entirely after the Q block;
    # SWA: K block entirely before the window)
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    # (window lower-bound skip handled via mask; pl.when below keeps the
    # pipeline structure static)

    @pl.when(run if isinstance(run, bool) else run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)      # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_kblocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, window=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D) with H % KV == 0."""
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    assert h % kv == 0
    g = h // kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, bq=bq,
        bk=bk, num_kblocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, _g=g: (b_, h_ // _g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, _g=g: (b_, h_ // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output acc
        ],
        interpret=interpret,
    )(q, k, v)
