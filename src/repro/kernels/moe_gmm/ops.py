"""Jitted public wrapper for the grouped expert matmul kernel."""
from __future__ import annotations

from repro.kernels.moe_gmm.kernel import moe_gmm_kernel
from repro.kernels.moe_gmm.ref import moe_gmm_ref


def moe_gmm(x, w, *, bc: int = 128, bf: int = 128, bd: int = 256,
            interpret: bool = True):
    """Capacity-padded grouped expert matmul: (E,C,d) x (E,d,f) -> (E,C,f)."""
    return moe_gmm_kernel(x, w, bc=bc, bf=bf, bd=bd, interpret=interpret)


reference = moe_gmm_ref
