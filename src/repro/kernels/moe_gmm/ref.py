"""Pure-jnp oracle for the grouped expert matmul."""
from __future__ import annotations

import jax.numpy as jnp


def moe_gmm_ref(x, w):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x, w).astype(x.dtype)
