"""Grouped expert matmul Pallas TPU kernel.

The MoE EP path (repro.models.moe) computes each local expert over its
capacity-padded token buffer: (E, C, d) x (E, d, f) -> (E, C, f).  On GPU
this is megablocks-style grouped GEMM with dynamic tile indexing; the TPU
adaptation keeps the capacity-padded layout (static shapes — what the XLA
pipeline and the A2A buffers already use) and tiles each expert's matmul
over the MXU with an f32 VMEM accumulator across the K (d) grid dimension.

Grid: (E, C/bc, f/bf, d/bd), last dimension sequential (accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]            # (bc, bd)
    w = w_ref[0]            # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm_kernel(x, w, *, bc: int = 128, bf: int = 128, bd: int = 256,
                   interpret: bool = True):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    e, c, d = x.shape
    _, _, f = w.shape
    bc = min(bc, c)
    bf = min(bf, f)
    bd = min(bd, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0
    nk = d // bd

    kernel = functools.partial(_gmm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(e, c // bc, f // bf, nk),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ie, ic, if_, ik: (ie, ic, ik)),
            pl.BlockSpec((1, bd, bf), lambda ie, ic, if_, ik: (ie, ik, if_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ie, ic, if_, ik: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
