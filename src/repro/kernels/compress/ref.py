"""Pure-JAX references for the compression kernels (the oracles the Pallas
kernels are validated against, and the implementations the codecs and the
compressed collectives in ``ccl.primitives`` run on any backend)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_TINY = 1e-30  # guards scale against all-zero payloads


def quantize_ref(x: jax.Array, bits: int = 8, stochastic: bool = False,
                 key: Optional[jax.Array] = None, per_row: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    """Uniform symmetric quantization to ``bits`` (stored as int8).

    ``per_row=True`` scales each row of a 2D input independently (the
    kernel's layout); otherwise one scale covers the whole tensor.
    ``stochastic=True`` rounds stochastically with ``key`` (unbiased —
    E[dequant] = x); default is round-to-nearest."""
    qmax = float(2 ** (bits - 1) - 1)
    x32 = x.astype(jnp.float32)
    if per_row:
        absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    else:
        absmax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(absmax, _TINY) / qmax
    scaled = x32 / scale
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding needs a PRNG key")
        u = jax.random.uniform(key, x.shape)
        q = jnp.floor(scaled + u)
    else:
        q = jnp.round(scaled)
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int8)
    return q, jnp.asarray(scale, jnp.float32)


def dequantize_ref(q: jax.Array, scale: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack a 1D int8 array of 4-bit values (range [-7, 7]) into uint8
    nibble pairs — the transform that makes a q4 payload genuinely half
    the q8 wire bytes.  Odd lengths get a zero nibble of padding."""
    flat = q.reshape(-1)
    if flat.size % 2:
        flat = jnp.pad(flat, (0, 1))
    u = (flat.astype(jnp.int32) + 8).astype(jnp.uint8)  # [-7,7] -> [1,15]
    return (u[0::2] | (u[1::2] << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_int4`; ``n`` is the unpacked length."""
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
    return out.astype(jnp.int8)


def wire_codec(bits: int, length: int):
    """(encode, decode) pair for quantize-on-the-wire collectives: encode
    maps a length-``length`` fp chunk to (int payload, 1-element fp32
    scale) — nibble-packed for ``bits=4`` so the wire saving is real —
    and decode inverts it.  Shared by the compressed ring and the
    synthesized move-list interpreter in ``ccl.primitives`` so every
    send-loop compresses identically (and swaps to the Pallas kernels
    together)."""

    def encode(v: jax.Array) -> Tuple[jax.Array, jax.Array]:
        q, scale = quantize_ref(v, bits=bits)
        if bits == 4:
            q = pack_int4(q)
        return q, scale.reshape(1)

    def decode(q: jax.Array, scale: jax.Array) -> jax.Array:
        if bits == 4:
            q = unpack_int4(q, length)
        return dequantize_ref(q, scale[0])

    return encode, decode


def sparsify_ref(x: jax.Array, thresh: jax.Array) -> jax.Array:
    """Magnitude thresholding: keep entries with |x| >= thresh (thresh
    broadcasts; per-row for 2D inputs), zero the rest."""
    x32 = x.astype(jnp.float32)
    return jnp.where(jnp.abs(x32) >= thresh, x32, 0.0)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """fp32-accumulated matmul — the PowerSGD projection primitive."""
    return jax.lax.dot_general(
        a.astype(jnp.float32), b.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
