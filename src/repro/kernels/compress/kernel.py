"""Pallas TPU kernels for gradient compression.

Three codec hot loops as fused VMEM kernels (per the survey's lever-3
compression arrow — the encode/decode passes sit on the critical path of
every compressed collective step, so they must run at VPU/MXU speed, not
as a chain of HBM-bound jnp ops):

  * ``quantize``   — per-row absmax scale + uniform int8/int4 rounding in
    one pass; stochastic rounding takes pre-generated uint32 bits (kept as
    an input so the kernel is reproducible and interpret-mode exact).
  * ``dequantize`` — scale-multiply back to fp32.
  * ``sparsify``   — magnitude thresholding against a per-row threshold
    (the top-k codec computes the k-th magnitude outside; the dense
    mask-apply is the bandwidth-bound pass).
  * ``matmul``     — fp32-accumulated blocked matmul, the PowerSGD
    projection primitive (M @ Q and M^T @ P).

Grids iterate over row blocks; the row length rides in whole (gradient
payloads are flattened to (rows, row_len) by ``ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TINY = 1e-30


def _quantize_kernel(x_ref, *refs, qmax: float, stochastic: bool):
    if stochastic:
        rand_ref, q_ref, scale_ref = refs
    else:
        q_ref, scale_ref = refs
    x = x_ref[...].astype(jnp.float32)                    # (bm, n)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (bm, 1)
    scale = jnp.maximum(absmax, _TINY) / qmax
    scale_ref[...] = scale
    scaled = x / scale
    if stochastic:
        # uint32 -> uniform [0, 1): take the top 24 bits (exact in fp32)
        u = (rand_ref[...] >> 8).astype(jnp.float32) * (2.0 ** -24)
        q = jnp.floor(scaled + u)
    else:
        q = jnp.round(scaled)
    q_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "stochastic", "bm",
                                             "interpret"))
def quantize_kernel(x, rand_bits=None, *, bits: int = 8,
                    stochastic: bool = False, bm: int = 8,
                    interpret: bool = True):
    """x: (m, n) -> (q int8 (m, n), scale f32 (m, 1)), per-row scales.
    ``rand_bits`` (uint32, same shape) is only required — and only moved
    into VMEM — when ``stochastic=True``; the deterministic hot path stays
    a single-input bandwidth-bound pass."""
    m, n = x.shape
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    qmax = float(2 ** (bits - 1) - 1)
    kernel = functools.partial(_quantize_kernel, qmax=qmax,
                               stochastic=stochastic)
    block = pl.BlockSpec((bm, n), lambda i: (i, 0))
    operands = (x,)
    in_specs = [block]
    if stochastic:
        if rand_bits is None:
            raise ValueError("stochastic quantize needs rand_bits")
        operands = (x, rand_bits)
        in_specs = [block, block]
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=[block,
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
    )(*operands)


def _dequantize_kernel(q_ref, scale_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def dequantize_kernel(q, scale, *, bm: int = 8, interpret: bool = True):
    """(q int8 (m, n), scale (m, 1)) -> f32 (m, n)."""
    m, n = q.shape
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(q, scale)


def _sparsify_kernel(x_ref, thresh_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.where(jnp.abs(x) >= thresh_ref[...], x, 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def sparsify_kernel(x, thresh, *, bm: int = 8, interpret: bool = True):
    """x: (m, n), thresh: (m, 1) -> masked f32 (m, n)."""
    m, n = x.shape
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _sparsify_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, thresh)


def _matmul_kernel(a_ref, b_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def matmul_kernel(a, b, *, bm: int = 128, bn: int = 128,
                  interpret: bool = True):
    """Blocked (m, k) x (k, n) -> f32 (m, n); k rides whole (PowerSGD
    ranks are tiny, the k dimension is the payload one)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
