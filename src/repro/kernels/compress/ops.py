"""Jitted public wrappers for the compression kernels.

Payloads of any shape are flattened to a (rows, row_len) layout with
per-row scales/thresholds — the layout both the Pallas kernels and the
references share.  ``interpret=True`` (the default everywhere in this
repo) runs the same kernels through the Pallas interpreter on CPU.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.compress.kernel import (dequantize_kernel, matmul_kernel,
                                           quantize_kernel, sparsify_kernel)
from repro.kernels.compress.ref import (dequantize_ref, matmul_ref,
                                        quantize_ref, sparsify_ref)


def _as_rows(x: jax.Array, row_len: int = 256) -> Tuple[jax.Array, int]:
    """Flatten + zero-pad to (rows, row_len); returns (rows2d, orig_size)."""
    flat = x.reshape(-1)
    n = flat.size
    pad = (-n) % row_len
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, row_len), n


def _row_block(rows: int, want: int = 8) -> int:
    """Largest divisor of ``rows`` that is <= ``want`` (the kernels require
    the grid to tile the row count exactly)."""
    for bm in range(min(want, rows), 0, -1):
        if rows % bm == 0:
            return bm
    return 1


def quantize(x: jax.Array, *, bits: int = 8, stochastic: bool = False,
             key: Optional[jax.Array] = None, row_len: int = 256,
             interpret: bool = True
             ) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Quantize any-shape ``x`` -> (q int8 (rows, row_len), scales (rows, 1),
    original shape).  Stochastic rounding draws its bits from ``key``."""
    rows, _ = _as_rows(x, row_len)
    rand = None
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding needs a PRNG key")
        rand = jax.random.bits(key, rows.shape, jnp.uint32)
    q, scales = quantize_kernel(rows, rand, bits=bits, stochastic=stochastic,
                                bm=_row_block(rows.shape[0]),
                                interpret=interpret)
    return q, scales, x.shape


def dequantize(q: jax.Array, scales: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.float32, *, interpret: bool = True) -> jax.Array:
    out = dequantize_kernel(q, scales, bm=_row_block(q.shape[0]),
                            interpret=interpret)
    n = math.prod(shape)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def sparsify(x: jax.Array, thresh: jax.Array, *, row_len: int = 256,
             interpret: bool = True) -> jax.Array:
    """Zero entries of ``x`` below the (scalar) magnitude threshold."""
    rows, n = _as_rows(x, row_len)
    t = jnp.broadcast_to(jnp.asarray(thresh, jnp.float32),
                         (rows.shape[0], 1))
    out = sparsify_kernel(rows, t, bm=_row_block(rows.shape[0]),
                          interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


def lowrank_project(m: jax.Array, q: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """PowerSGD projection P = M @ Q (and, transposed, Q' = M^T @ P) with
    fp32 accumulation; block sizes snap to divisors of the operand dims."""
    return matmul_kernel(m, q, bm=_row_block(m.shape[0], 128),
                         bn=_row_block(q.shape[1], 128),
                         interpret=interpret)


reference = {
    "quantize": quantize_ref,
    "dequantize": dequantize_ref,
    "sparsify": sparsify_ref,
    "matmul": matmul_ref,
}
