"""Gradient-compression kernels: Pallas quantize/dequantize/sparsify/matmul
with pure-JAX references (see ``repro.compress`` for the codec layer)."""
from repro.kernels.compress.ops import (dequantize, lowrank_project,  # noqa: F401
                                        quantize, sparsify)
