"""Jitted public wrapper for the SSD scan kernel."""
from __future__ import annotations

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = True):
    """Chunked SSD scan. x: (B,H,L,P); dt: (B,H,L); a: (H,); b,c: (B,L,N)."""
    return ssd_scan_kernel(x, dt, a, b, c, chunk=chunk, interpret=interpret)


reference = ssd_scan_ref
