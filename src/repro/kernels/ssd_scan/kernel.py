"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the GPU selective-scan: the chunked *dual form* turns the
recurrence into (Q x Q) and (Q x N)/(N x P) matmuls per chunk (MXU work),
with only the inter-chunk state carried sequentially.  The carry state
(P x N per head) lives in VMEM scratch and persists across the sequential
chunk grid dimension — the Pallas analogue of the fused CUDA scan's
register-resident state (DESIGN.md hardware-adaptation note).

Layouts: x (B, H, L, P), dt (B, H, L), a (H,), b/c (B, L, N) (group-
broadcast over heads).  Output y (B, H, L, P).
Grid: (B, H, L/Q) with the chunk dimension sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)             # scalar
    bmat = b_ref[0].astype(jnp.float32)          # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)          # (Q, N)

    da = dt * a                                  # (Q,)
    cs = jnp.cumsum(da)                          # (Q,)
    # intra-chunk decay matrix L[i,j] = exp(cs_i - cs_j) for j <= i
    diff = cs[:, None] - cs[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(tril, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * lmat * dt[None, :]              # (Q, Q)
    y_diag = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    decay_in = jnp.exp(cs)                       # (Q,)
    h_prev = h_ref[...]                          # (P, N)
    y_off = jax.lax.dot_general(cmat, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_diag + y_off * decay_in[:, None]).astype(y_ref.dtype)

    # state update: h = h * exp(sum da) + x^T @ (b * decay_out * dt)
    decay_out = jnp.exp(cs[-1] - cs)             # (Q,)
    bw = bmat * (decay_out * dt)[:, None]        # (Q, N)
    state = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    h_ref[...] = h_prev * jnp.exp(cs[-1]) + state


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x, dt, a, b, c, *, chunk: int = 128,
                    interpret: bool = True):
    """x: (B,H,L,P); dt: (B,H,L); a: (H,); b,c: (B,L,N) -> y (B,H,L,P)."""
    bsz, h, l, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    assert l % q == 0
    nc = l // q

    kernel = functools.partial(_ssd_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, q), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, q, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, q, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p),
                               lambda ib, ih, ic: (ib, ih, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
