"""Pure-jnp oracle for the SSD scan kernel: the model-level chunked SSD
from repro.models.ssm, re-laid-out to the kernel's (B,H,L,P) convention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(x, dt, a, b, c, *, chunk: int = 128):
    """x: (B,H,L,P); dt: (B,H,L); a: (H,); b,c: (B,L,N)."""
    xm = jnp.moveaxis(x, 1, 2)      # (B,L,H,P)
    dtm = jnp.moveaxis(dt, 1, 2)    # (B,L,H)
    y, _ = ssd_chunked(xm.astype(jnp.float32), dtm.astype(jnp.float32),
                       a.astype(jnp.float32), b.astype(jnp.float32),
                       c.astype(jnp.float32), chunk=chunk)
    return jnp.moveaxis(y, 2, 1).astype(x.dtype)
