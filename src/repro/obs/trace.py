"""Structured tracing with a Chrome Trace Event (Perfetto) exporter.

The engine's answers are timelines — ``SimResult.timeline`` schedules,
cluster phase offsets, dynamics event streams — but until now they were
bare tuples.  :class:`Trace` is the recorder: spans (``ph:"X"``),
counter samples (``ph:"C"``) and instant events (``ph:"i"``) keyed by a
process/thread grid, exported as Chrome Trace Event JSON that loads
directly in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

The builders turn each report layer into tracks:

  * :func:`timeline_tracks` / :func:`trace_from_report` — one process
    per job with a *compute* thread, a *comm* thread (args carry the
    chosen algorithm/codec/size), an *exposed comm* thread whose spans
    flag the stall intervals compute spent waiting on the wire (colored
    red via ``cname``), and — given the live ``Topology`` — per-link
    utilization counter tracks regenerated through
    ``net.simulate.link_rate_series``;
  * :func:`trace_from_search` — the winner's full tracks plus a search
    process: frontier candidates as instants and JCT counter series;
  * :func:`trace_from_cluster` — one process group per tenant, each
    tenant's iteration shifted by its staggered phase, contended links
    as instants on a cluster process;
  * :func:`trace_from_serving` — a ``ServingReport``'s request
    lifetimes (queue/prefill/decode spans packed into lanes) with SLO
    violations as red instants, plus the priced prefill/decode plans;
  * :func:`trace_from_dynamics` — the event trace (link_fail, replan
    mode, evictions) as instants + replan-cost spans and
    stretch/dirty-set counters, followed by the final cluster plan.

Everything here is dict-driven: builders accept either live report
objects or their ``to_dict()`` JSON, so a persisted report re-exports to
the identical trace (``python -m repro.obs.export``).  Export is
deterministic — stable event ordering, sorted JSON keys — so traces can
be diffed and tested byte-for-byte.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

_US = 1e6  # seconds -> Chrome Trace microseconds

# Chrome reserved color names: exposed communication is flagged red.
EXPOSED_CNAME = "terrible"


@dataclass
class _Event:
    """One recorded event in source units (seconds)."""

    ph: str
    name: str
    ts: float
    pid: int
    tid: int
    dur: float = 0.0
    cat: str = ""
    args: Optional[Dict] = None
    scope: str = "t"
    cname: Optional[str] = None


class Trace:
    """Span / counter / instant-event recorder with Perfetto JSON export."""

    def __init__(self):
        self._events: List[_Event] = []
        self._process_names: Dict[int, str] = {}
        self._process_sort: Dict[int, int] = {}
        self._thread_names: Dict[Tuple[int, int], str] = {}

    # -- structure -----------------------------------------------------

    def process(self, pid: int, name: str,
                sort_index: Optional[int] = None) -> int:
        """Name a process row (a job / tenant / the cluster)."""
        self._process_names[pid] = name
        if sort_index is not None:
            self._process_sort[pid] = sort_index
        return pid

    def thread(self, pid: int, tid: int, name: str) -> int:
        """Name a thread row (a resource track inside a process)."""
        self._thread_names[(pid, tid)] = name
        return tid

    # -- events --------------------------------------------------------

    def span(self, name: str, start_s: float, dur_s: float, pid: int = 0,
             tid: int = 0, cat: str = "", args: Optional[Dict] = None,
             cname: Optional[str] = None) -> None:
        """A complete span (``ph:"X"``); negative durations are clamped."""
        self._events.append(_Event("X", name, start_s, pid, tid,
                                   dur=max(dur_s, 0.0), cat=cat, args=args,
                                   cname=cname))

    def counter(self, name: str, ts_s: float, values: Mapping[str, float],
                pid: int = 0, tid: int = 0) -> None:
        """One sample of a counter track (``ph:"C"``, one series per key)."""
        self._events.append(_Event("C", name, ts_s, pid, tid,
                                   args={k: values[k]
                                         for k in sorted(values)}))

    def instant(self, name: str, ts_s: float, pid: int = 0, tid: int = 0,
                args: Optional[Dict] = None, scope: str = "t",
                cat: str = "", cname: Optional[str] = None) -> None:
        """An instant event (``ph:"i"``; scope t=thread, p=process,
        g=global)."""
        self._events.append(_Event("i", name, ts_s, pid, tid, args=args,
                                   scope=scope, cat=cat, cname=cname))

    # -- export --------------------------------------------------------

    def events(self) -> List[Dict]:
        """Chrome Trace Event dicts: metadata first, then events in
        stable (pid, tid, ts, ph, name) order — same trace, same bytes."""
        out: List[Dict] = []
        for pid in sorted(self._process_names):
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": self._process_names[pid]}})
            if pid in self._process_sort:
                out.append({"ph": "M", "name": "process_sort_index",
                            "pid": pid, "tid": 0,
                            "args": {"sort_index": self._process_sort[pid]}})
        for (pid, tid) in sorted(self._thread_names):
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid,
                        "args": {"name": self._thread_names[(pid, tid)]}})
        for ev in sorted(self._events,
                         key=lambda e: (e.pid, e.tid, e.ts, e.ph, e.name)):
            d: Dict = {"ph": ev.ph, "name": ev.name,
                       "ts": round(ev.ts * _US, 3), "pid": ev.pid,
                       "tid": ev.tid}
            if ev.ph == "X":
                d["dur"] = round(ev.dur * _US, 3)
            if ev.ph == "i":
                d["s"] = ev.scope
            if ev.cat:
                d["cat"] = ev.cat
            if ev.cname:
                d["cname"] = ev.cname
            if ev.args is not None:
                d["args"] = ev.args
            out.append(d)
        return out

    def to_chrome(self) -> Dict:
        return {"displayTimeUnit": "ms", "traceEvents": self.events()}

    def to_json(self) -> str:
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":"))

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path


def validate_chrome(doc: Dict) -> List[str]:
    """Problems with a Chrome Trace Event document (empty list = valid):
    required keys and types per phase, and — per (pid, tid) track —
    non-overlapping complete spans (the single-resource invariant the
    scheduler timeline guarantees)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    spans: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "C", "i", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        missing = [key for key in ("name", "pid", "tid") if key not in ev]
        if missing:
            problems.append(f"event {i} ({ph}): missing {missing}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i} ({ph}): name not a string")
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i} ({ph}): ts not a number")
            continue
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)):
                problems.append(f"event {i} (X): dur not a number")
            elif ev["dur"] < 0:
                problems.append(f"event {i} (X): negative dur {ev['dur']}")
            else:
                spans.setdefault((ev["pid"], ev["tid"]), []).append(
                    (ev["ts"], ev["ts"] + ev["dur"], ev["name"]))
        if ph == "i" and ev.get("s", "t") not in ("t", "p", "g"):
            problems.append(f"event {i} (i): bad scope {ev.get('s')!r}")
    eps = 2e-3  # 2ns: ts/dur are rounded to 3 decimals of a us, so two
    #             touching spans can land 0.001us "overlapped"
    for (pid, tid), sp in sorted(spans.items()):
        sp.sort()
        for (s0, e0, n0), (s1, e1, n1) in zip(sp, sp[1:]):
            if s1 < e0 - eps:
                problems.append(
                    f"track pid={pid} tid={tid}: span {n1!r}@{s1} overlaps "
                    f"{n0!r} ending {e0}")
    return problems


# ---------------------------------------------------------------------------
# Builders: report layers -> tracks
# ---------------------------------------------------------------------------

# thread ids inside one job's process
TID_COMPUTE, TID_COMM, TID_EXPOSED = 0, 1, 2
_LINK_TID_BASE = 8  # counter tracks sit above the resource threads


def _as_dict(obj) -> Dict:
    """A report in dict form: live objects go through their ``to_dict``."""
    return obj if isinstance(obj, Mapping) else obj.to_dict()


def timeline_tracks(trace: Trace, pid: int, label: str,
                    timeline: Sequence[Tuple[str, float, float]],
                    task_exposed_s: Optional[Mapping[str, float]] = None,
                    task_args: Optional[Mapping[str, Dict]] = None,
                    t0: float = 0.0) -> Trace:
    """One job's executed schedule as compute/comm/exposed threads.

    ``timeline`` entries are the scheduler's ``("comp:<id>"|"comm:<id>",
    start, end)`` segments; ``task_exposed_s`` flags each comm task's
    stall interval — the last ``exposed_s`` seconds before its final
    segment retires (exact: ``wait_for_running`` stalls compute until
    the in-flight comm finishes) — as a red span on its own thread;
    ``task_args`` attaches per-comm-task span args (algorithm, size,
    codec).  ``t0`` shifts the whole job (cluster phase offsets)."""
    trace.process(pid, label)
    trace.thread(pid, TID_COMPUTE, "compute")
    trace.thread(pid, TID_COMM, "comm")
    if task_exposed_s:
        trace.thread(pid, TID_EXPOSED, "exposed comm")
    last_comm_end: Dict[str, float] = {}
    for name, start, end in timeline:
        kind, _, task_id = name.partition(":")
        if kind == "comm":
            args = dict((task_args or {}).get(task_id, {}))
            exposed = (task_exposed_s or {}).get(task_id, 0.0)
            if exposed > 0:
                args["exposed_s"] = exposed
            trace.span(task_id, t0 + start, end - start, pid=pid,
                       tid=TID_COMM, cat="comm", args=args or None)
            last_comm_end[task_id] = max(last_comm_end.get(task_id, end),
                                         end)
        else:
            trace.span(task_id, t0 + start, end - start, pid=pid,
                       tid=TID_COMPUTE, cat="compute")
    for task_id, exposed in sorted((task_exposed_s or {}).items()):
        if exposed <= 0 or task_id not in last_comm_end:
            continue
        end = last_comm_end[task_id]
        trace.span(f"exposed:{task_id}", t0 + end - exposed, exposed,
                   pid=pid, tid=TID_EXPOSED, cat="exposed",
                   cname=EXPOSED_CNAME, args={"exposed_s": exposed})
    return trace


def _link_counter_tracks(trace: Trace, pid: int, report: Dict, topo,
                         t0: float, max_links: int) -> None:
    """Per-link byte-rate counter tracks for one job's comm schedule,
    regenerated from the persisted choices through the network layer
    (``net.simulate.link_rate_series``; no in-network-aggregation
    discount — the profile is the pre-aggregation offered load)."""
    from repro.ccl.select import flows_on_topology
    from repro.core.demand import CommTask
    from repro.net.simulate import link_rate_series

    choices = {c["task_id"]: c for c in report.get("choices", [])}
    placed = []
    for name, start, end in report.get("timeline", []):
        kind, _, task_id = name.partition(":")
        c = choices.get(task_id)
        if kind != "comm" or c is None:
            continue
        task = CommTask(task_id, c["primitive"], c["size_bytes"],
                        tuple(c["group"]))
        try:
            fs = flows_on_topology(topo, task, c["algorithm"])
        except (ValueError, KeyError):
            continue  # degraded view without this group's route
        placed.append((fs, start, end))
    if not placed:
        return
    series = link_rate_series(topo, placed)
    # keep the hottest tracks (by byte-seconds area), deterministic order
    def area(points):
        return sum(r * (points[i + 1][0] - t)
                   for i, (t, r) in enumerate(points[:-1]))

    links = sorted(series, key=lambda l: (-area(series[l]), str(l)))
    for i, link in enumerate(links[:max_links]):
        name = f"link {'->'.join(str(n) for n in link)} B/s"
        for t, rate in series[link]:
            trace.counter(name, t0 + t, {"bytes_per_s": rate}, pid=pid,
                          tid=_LINK_TID_BASE + i)


def trace_from_report(report, topo=None, trace: Optional[Trace] = None,
                      pid: int = 1, label: Optional[str] = None,
                      t0: float = 0.0, max_links: int = 16) -> Trace:
    """A ``CodesignReport`` (live or ``to_dict()`` JSON) as one process:
    compute / comm / exposed threads plus — when the live ``Topology``
    is given — per-link utilization counters."""
    d = _as_dict(report)
    trace = trace if trace is not None else Trace()
    if label is None:
        label = (f"plan jct={d.get('jct', 0.0):.4g}s "
                 f"({d.get('policy', '?')}, {d.get('cost_model', '?')})")
    task_args = {}
    for c in d.get("choices", []):
        args = {"algorithm": c["algorithm"], "primitive": c["primitive"],
                "size_bytes": c["size_bytes"], "cost_s": c["cost_s"]}
        if c.get("codec"):
            args["codec"] = c["codec"]
        task_args[c["task_id"]] = args
    timeline_tracks(trace, pid, label, d.get("timeline", []),
                    task_exposed_s=d.get("task_exposed_s", {}),
                    task_args=task_args, t0=t0)
    if topo is not None:
        _link_counter_tracks(trace, pid, d, topo, t0, max_links)
    return trace


def trace_from_search(result, topo=None, max_links: int = 16) -> Trace:
    """A ``SearchResult``: the winning plan's full tracks plus a search
    process — every frontier candidate as an instant (args carry its
    assignment, JCT and feasibility; the evaluation index is the
    pseudo-time axis) and JCT counter series."""
    d = _as_dict(result)
    trace = Trace()
    trace_from_report(d["best"], topo=topo, trace=trace, pid=1,
                      max_links=max_links)
    pid = trace.process(0, f"search ({d.get('evaluated', 0)} evals)",
                        sort_index=-1)
    trace.thread(pid, 0, "frontier")
    telemetry = d.get("telemetry", {})
    if telemetry:
        trace.instant("telemetry", 0.0, pid=pid, tid=0, scope="p",
                      args=telemetry)
    best_jct = d.get("best", {}).get("jct")
    for i, cand in enumerate(d.get("frontier", [])):
        assignment = {
            k: (v.get("strategy", "custom") if isinstance(v, Mapping)
                else v)
            for k, v in cand.get("assignment", {}).items()}
        trace.instant(
            "candidate", float(i), pid=pid, tid=0,
            args={"assignment": assignment, "jct": cand.get("jct"),
                  "feasible": cand.get("feasible"),
                  "reason": cand.get("reason"),
                  "requests": cand.get("requests", 1)})
        values = {"jct_s": cand.get("jct", 0.0)}
        if best_jct is not None:
            values["best_jct_s"] = best_jct
        trace.counter("frontier jct", float(i), values, pid=pid, tid=1)
    return trace


def trace_from_cluster(report, topo=None, trace: Optional[Trace] = None,
                       pid_base: int = 1, t0: float = 0.0,
                       max_links: int = 4) -> Trace:
    """A ``ClusterReport``: one process group per tenant — each tenant's
    iteration tracks shifted by its staggered phase offset — plus a
    cluster process carrying the contended-link map as instants."""
    d = _as_dict(report)
    trace = trace if trace is not None else Trace()
    cpid = trace.process(pid_base - 1, "cluster", sort_index=-1)
    trace.thread(cpid, 0, "contention")
    for i, (link, users) in enumerate(sorted(d.get("contended",
                                                   {}).items())):
        trace.instant(f"contended {link}", t0 + float(i) * 1e-6, pid=cpid,
                      tid=0, scope="p", args={"bytes_by_job": dict(users)})
    phases = d.get("phases", {})
    staggered = d.get("staggered_jct", {})
    for i, job in enumerate(d.get("jobs", [])):
        name = job["name"]
        phase = phases.get(name, 0.0)
        label = (f"{name} phase={phase:.4g}s "
                 f"jct={staggered.get(name, 0.0):.4g}s")
        trace_from_report(job["report"], topo=topo, trace=trace,
                          pid=pid_base + i, label=label, t0=t0 + phase,
                          max_links=max_links)
    return trace


def trace_from_serving(report, topo=None, trace: Optional[Trace] = None,
                       pid_base: int = 1, max_links: int = 8) -> Trace:
    """A ``ServingReport``: one serving process whose lanes carry each
    request's lifetime — a *queue* span (arrival to prefill admission),
    a *prefill* span (admission to first token) and a *decode* span
    (first token to finish) — with SLO violations flagged as red
    instants, plus the priced prefill/decode batch plans as their own
    processes.  Requests are packed greedily into lanes so concurrent
    lifetimes never overlap on one track (the ``validate_chrome``
    invariant)."""
    d = _as_dict(report)
    trace = trace if trace is not None else Trace()
    spid = trace.process(
        pid_base - 1,
        f"serving {d.get('name', '?')} "
        f"ttft_p99={d.get('ttft', {}).get('p99', 0.0):.4g}s "
        f"attain={d.get('slo_attainment', 0.0):.3g}",
        sort_index=-1)
    summary = {k: d.get(k) for k in
               ("offered_rps", "goodput_rps", "slo_attainment",
                "stagger_s", "horizon_s", "kv_bytes_per_request")}
    summary["ttft"] = d.get("ttft", {})
    summary["tpot"] = d.get("tpot", {})
    trace.instant("summary", 0.0, pid=spid, tid=0, scope="p", args=summary)
    slo = d.get("slo", {})
    lanes: List[float] = []  # per-lane last span end
    reqs = sorted(d.get("requests", []),
                  key=lambda r: (r.get("t_arrive", 0.0), str(r.get("rid"))))
    for r in reqs:
        t_arr = r.get("t_arrive", 0.0)
        t_pf = r.get("t_prefill", t_arr)
        t_first = r.get("t_first")
        t_fin = r.get("t_finish")
        if t_first is None or t_fin is None:
            continue
        lane = next((i for i, end in enumerate(lanes)
                     if end <= t_arr + 1e-12), None)
        if lane is None:
            lane = len(lanes)
            lanes.append(0.0)
            trace.thread(spid, lane, f"lane {lane}")
        lanes[lane] = t_fin
        rid = r.get("rid", "?")
        args = {"ttft_s": r.get("ttft"), "tpot_s": r.get("tpot"),
                "slo_ok": r.get("slo_ok")}
        if t_pf > t_arr:
            trace.span(f"queue:{rid}", t_arr, t_pf - t_arr, pid=spid,
                       tid=lane, cat="queue")
        trace.span(f"prefill:{rid}", t_pf, t_first - t_pf, pid=spid,
                   tid=lane, cat="prefill", args=args)
        trace.span(f"decode:{rid}", t_first, t_fin - t_first, pid=spid,
                   tid=lane, cat="decode")
        if not r.get("slo_ok", True):
            trace.instant(
                f"slo_violation:{rid}", t_first, pid=spid, tid=lane,
                cname=EXPOSED_CNAME,
                args={"ttft_s": r.get("ttft"), "tpot_s": r.get("tpot"),
                      "slo_ttft_s": slo.get("ttft_s"),
                      "slo_tpot_s": slo.get("tpot_s")})
    for i, phase in enumerate(("prefill", "decode")):
        ph = d.get(phase)
        if ph:
            trace_from_report(ph, topo=topo, trace=trace, pid=pid_base + i,
                              label=f"{phase} batch plan",
                              max_links=max_links)
    return trace


def trace_from_dynamics(report, topo=None) -> Trace:
    """A ``DynamicsReport``: the event stream as instants on a cluster
    dynamics track (kind/target, replan mode, evictions), replan cost as
    spans, worst-stretch / dirty-set counters — then the final plan's
    tenant processes."""
    d = _as_dict(report)
    trace = Trace()
    pid = trace.process(0, "cluster dynamics", sort_index=-2)
    trace.thread(pid, 0, "events")
    trace.thread(pid, 1, "replan")
    cursor = 0.0  # replan spans mix event time with wall-clock duration;
    #               the cursor keeps the track's spans disjoint
    for rec in d.get("records", []):
        t = rec.get("time", 0.0)
        args = {"mode": rec["mode"], "dirty_jobs": rec["dirty_jobs"],
                "dirty_links": rec["dirty_links"],
                "replan_s": rec["replan_s"],
                "worst_stretch": rec["worst_stretch"]}
        if rec.get("regret") is not None:
            args["regret"] = rec["regret"]
        trace.instant(f"{rec['kind']}:{rec['target']}", t, pid=pid, tid=0,
                      scope="p", args=args,
                      cname=None if rec["mode"] == "incremental"
                      else EXPOSED_CNAME)
        for name in rec.get("evicted", []):
            trace.instant(f"evict:{name}", t, pid=pid, tid=0, scope="p",
                          cname=EXPOSED_CNAME)
        start = max(t, cursor)
        trace.span(f"replan[{rec['mode']}]", start, rec["replan_s"],
                   pid=pid, tid=1, cat="replan",
                   args={"full_replan_s": rec.get("full_replan_s")})
        cursor = start + rec["replan_s"]
        trace.counter("worst stretch", t,
                      {"stretch": rec["worst_stretch"]}, pid=pid, tid=2)
        trace.counter("dirty", t,
                      {"jobs": len(rec["dirty_jobs"]),
                       "links": len(rec["dirty_links"])}, pid=pid, tid=3)
    telemetry = d.get("telemetry", {})
    if telemetry:
        trace.instant("telemetry", 0.0, pid=pid, tid=0, scope="p",
                      args=telemetry)
    trace_from_cluster(d["final"], topo=topo, trace=trace, pid_base=2)
    return trace
