"""Persisted report JSON -> Perfetto trace, as a module CLI.

    python -m repro.obs.export experiments/plan.json
    python -m repro.obs.export run.json -o run.trace.json --kind search

Accepts any report the engine persists (``CodesignReport`` /
``SearchResult`` / ``ClusterReport`` / ``DynamicsReport`` ``to_dict()``
JSON); the kind is sniffed from the document's keys unless ``--kind``
pins it.  The output loads in https://ui.perfetto.dev or
``chrome://tracing``.  Pure dict work — no topology is available from
JSON alone, so per-link counter tracks (which need the live
``Topology``) come from the in-process ``to_trace(topo=...)`` path
instead.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

from repro.obs.trace import (Trace, trace_from_cluster, trace_from_dynamics,
                             trace_from_report, trace_from_search,
                             trace_from_serving)

KINDS = ("report", "search", "cluster", "dynamics", "serving")


def detect_kind(d: Dict) -> str:
    """Which report a ``to_dict()`` document is, from its key shape."""
    if "records" in d and "final" in d:
        return "dynamics"
    if "best" in d and "frontier" in d:
        return "search"
    if "ttft" in d and "requests" in d:
        return "serving"
    if "jobs" in d and "staggered_jct" in d:
        return "cluster"
    if "choices" in d and "jct" in d:
        return "report"
    raise ValueError(
        f"unrecognized report document (top-level keys {sorted(d)[:8]}); "
        f"expected a CodesignReport / SearchResult / ClusterReport / "
        f"DynamicsReport / ServingReport to_dict() JSON")


def build_trace(d: Dict, kind: Optional[str] = None) -> Trace:
    kind = kind or detect_kind(d)
    if kind == "dynamics":
        return trace_from_dynamics(d)
    if kind == "search":
        return trace_from_search(d)
    if kind == "serving":
        return trace_from_serving(d)
    if kind == "cluster":
        return trace_from_cluster(d)
    if kind == "report":
        return trace_from_report(d)
    raise ValueError(f"unknown kind {kind!r} (one of {KINDS})")


def export_file(path: str, out: Optional[str] = None,
                kind: Optional[str] = None) -> str:
    with open(path) as f:
        d = json.load(f)
    if out is None:
        stem = path[:-5] if path.endswith(".json") else path
        out = stem + ".trace.json"
    return build_trace(d, kind).write(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Convert persisted report JSON to a Perfetto-loadable "
                    "Chrome Trace Event file.")
    ap.add_argument("report", help="report to_dict() JSON file")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: <report>.trace.json)")
    ap.add_argument("--kind", choices=KINDS, default=None,
                    help="report kind (default: sniff from keys)")
    args = ap.parse_args(argv)
    out = export_file(args.report, args.out, args.kind)
    print(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
