"""Deterministic counters and timing observations for the engine's hot
paths.

A :class:`Meters` is a flat bag of named counters (``incr``) and value
observations (``observe`` — running sum/count/min/max), plus a ``time``
context manager that observes wall-clock against an injectable clock.
Everything the engine counts is *deterministic by construction*: the same
plan/search/replan run produces the same counter values, so tests can
assert them exactly — only clock-derived observations vary, and the clock
is injectable precisely so tests can pin those too.

Consumers:

  * ``ccl.select.FlowSim`` — memoization hit/miss counters, labelled per
    switch-capacity bucket (one FlowSim per aggregation budget);
  * ``codesign.api.search`` — per-candidate records plus the aggregated
    cost-model counters, surfaced as ``SearchResult.telemetry``;
  * ``codesign.dynamics.ClusterDynamics`` — per-event dirty-set sizes and
    replan-mode tallies, surfaced as ``DynamicsReport.telemetry``;
  * ``sched.flows`` — phase-search evaluation counts.

This module imports nothing from ``repro`` (it sits below every layer).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Dict, Optional


class Meters:
    """Named counters + value observations behind one injectable clock."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._counters: Dict[str, float] = {}
        self._observations: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    # Counters
    # ------------------------------------------------------------------

    def incr(self, name: str, by: float = 1.0) -> float:
        """Add ``by`` to counter ``name`` (created at 0); returns the new
        value."""
        v = self._counters.get(name, 0.0) + by
        self._counters[name] = v
        return v

    def get(self, name: str, default: float = 0.0) -> float:
        return self._counters.get(name, default)

    def ratio(self, num: str, *parts: str) -> Optional[float]:
        """``num / (num + parts...)`` over counter values — the hit-rate
        helper (None when nothing was counted)."""
        n = self.get(num)
        total = n + sum(self.get(p) for p in parts)
        return n / total if total > 0 else None

    # ------------------------------------------------------------------
    # Observations
    # ------------------------------------------------------------------

    def observe(self, name: str, value: float) -> None:
        """Record one sample of ``name`` (running sum/count/min/max)."""
        o = self._observations.get(name)
        if o is None:
            self._observations[name] = {"sum": float(value), "count": 1.0,
                                        "min": float(value),
                                        "max": float(value)}
        else:
            o["sum"] += value
            o["count"] += 1.0
            o["min"] = min(o["min"], value)
            o["max"] = max(o["max"], value)

    @contextmanager
    def time(self, name: str):
        """Observe the wall-clock of a block under ``name`` (uses the
        injected clock, so tests can make timings exact)."""
        t0 = self.clock()
        try:
            yield
        finally:
            self.observe(name, self.clock() - t0)

    # ------------------------------------------------------------------
    # Aggregation
    # ------------------------------------------------------------------

    def merge(self, other: "Meters") -> "Meters":
        """Fold ``other``'s counters and observations into this one."""
        for name, v in other._counters.items():
            self._counters[name] = self._counters.get(name, 0.0) + v
        for name, o in other._observations.items():
            mine = self._observations.get(name)
            if mine is None:
                self._observations[name] = dict(o)
            else:
                mine["sum"] += o["sum"]
                mine["count"] += o["count"]
                mine["min"] = min(mine["min"], o["min"])
                mine["max"] = max(mine["max"], o["max"])
        return self

    def snapshot(self) -> Dict[str, float]:
        """Flat, key-sorted view: counters verbatim, observations expanded
        to ``name.sum`` / ``name.count`` / ``name.min`` / ``name.max`` —
        JSON-ready and deterministic in iteration order."""
        out = dict(self._counters)
        for name, o in self._observations.items():
            for stat, v in o.items():
                out[f"{name}.{stat}"] = v
        return {k: out[k] for k in sorted(out)}
