"""Wall-clock probes for the executable collectives — measured vs modeled.

The ROADMAP's calibration item needs one thing the engine never had:
*measured* collective times to hold against the ``AlphaBeta``/``FlowSim``
predictions.  :func:`probe_all_reduce` runs one executable implementation
from ``ccl.primitives`` on a device mesh (a forced-host-device mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in CI, real
accelerators when available), bracketing each run with
``block_until_ready`` so the span is the collective's wall-clock, not
dispatch time.  Each :class:`CollectiveProbe` carries the measurement
next to the closed-form prediction for the same
(algorithm, size, world); :func:`probes_to_trace` lays both out
side-by-side in a Perfetto trace, and :func:`model_vs_measured`
summarizes the drift — the regression target a calibration fit would
minimize.

This module imports ``jax`` lazily inside the probe functions so
``repro.obs`` stays importable (and the export CLI usable) without
touching an accelerator runtime.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import Trace


@dataclass
class CollectiveProbe:
    """One (implementation, size) measurement next to its prediction."""

    impl: str                 # executable name (ccl.primitives)
    algorithm: str            # the priced equivalent (MODEL_EQUIVALENTS)
    size_bytes: int
    world: int                # devices in the mesh axis
    measured_s: float         # min over timed runs (the standard estimator)
    modeled_s: float          # algo_cost prediction under the CostParams
    runs_s: List[float] = field(default_factory=list)
    model_terms: Dict[str, float] = field(default_factory=dict)
    primitive: str = "all_reduce"
    device_kind: str = "cpu"

    @property
    def ratio(self) -> Optional[float]:
        """measured / modeled (None when the model predicts 0)."""
        return self.measured_s / self.modeled_s if self.modeled_s > 0 \
            else None

    def to_dict(self) -> Dict:
        return {"impl": self.impl, "algorithm": self.algorithm,
                "size_bytes": self.size_bytes, "world": self.world,
                "measured_s": self.measured_s, "modeled_s": self.modeled_s,
                "runs_s": list(self.runs_s),
                "model_terms": dict(self.model_terms),
                "primitive": self.primitive,
                "device_kind": self.device_kind}

    @classmethod
    def from_dict(cls, d: Dict) -> "CollectiveProbe":
        return cls(impl=d["impl"], algorithm=d["algorithm"],
                   size_bytes=d["size_bytes"], world=d["world"],
                   measured_s=d["measured_s"], modeled_s=d["modeled_s"],
                   runs_s=list(d.get("runs_s", [])),
                   model_terms=dict(d.get("model_terms", {})),
                   primitive=d.get("primitive", "all_reduce"),
                   device_kind=d.get("device_kind", "cpu"))


def _default_mesh():
    import jax
    import numpy as np
    devices = jax.devices()
    return jax.sharding.Mesh(np.array(devices), ("x",))


def probe_all_reduce(impl: str, size_bytes: int, mesh=None,
                     params=None, repeats: int = 3, warmup: int = 1,
                     clock: Callable[[], float] = time.perf_counter
                     ) -> CollectiveProbe:
    """Measure one executable all-reduce and pair it with its prediction.

    ``impl`` names a ``ccl.primitives.IMPLEMENTATIONS`` entry; the mesh
    defaults to all visible devices on one axis.  Every timed run is
    ``block_until_ready``-bracketed; ``warmup`` runs absorb compilation.
    The prediction prices the ``MODEL_EQUIVALENTS`` algorithm with
    ``algo_cost`` under ``params`` (default :class:`CostParams`) — drift
    between the two is the calibration signal, not an error."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ccl.cost import CostParams, algo_cost, cost_terms
    from repro.ccl.primitives import MODEL_EQUIVALENTS, make_all_reduce

    if impl not in MODEL_EQUIVALENTS:
        raise ValueError(f"unknown implementation {impl!r} "
                         f"(one of {sorted(MODEL_EQUIVALENTS)})")
    mesh = mesh if mesh is not None else _default_mesh()
    axis = mesh.axis_names[0]
    p = mesh.shape[axis]
    cp = params if params is not None else CostParams()

    elems = max(size_bytes // 4, p)
    elems += (-elems) % p  # shardable along the mesh axis
    # deterministic payload, no PRNG (probe results must be reproducible
    # modulo the clock)
    x = (jnp.arange(elems, dtype=jnp.float32) % 13.0) / 16.0 - 0.4
    x = jax.device_put(x, NamedSharding(mesh, P(axis)))
    fn = make_all_reduce(impl, mesh, axis)

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(x))
    runs: List[float] = []
    for _ in range(max(repeats, 1)):
        t0 = clock()
        jax.block_until_ready(fn(x))
        runs.append(clock() - t0)

    algorithm = MODEL_EQUIVALENTS[impl]
    return CollectiveProbe(
        impl=impl, algorithm=algorithm, size_bytes=size_bytes, world=p,
        measured_s=min(runs),
        modeled_s=algo_cost("all_reduce", algorithm, size_bytes, p, cp),
        runs_s=runs,
        model_terms=cost_terms("all_reduce", algorithm, size_bytes, p, cp),
        device_kind=jax.devices()[0].platform)


def probe_suite(impls: Sequence[str] = ("ring", "bidir_ring"),
                sizes: Sequence[int] = (1 << 16, 1 << 20), mesh=None,
                params=None, repeats: int = 3, warmup: int = 1,
                clock: Callable[[], float] = time.perf_counter
                ) -> List[CollectiveProbe]:
    """Probe an implementation x size grid (deterministic order)."""
    mesh = mesh if mesh is not None else _default_mesh()
    return [probe_all_reduce(impl, size, mesh=mesh, params=params,
                             repeats=repeats, warmup=warmup, clock=clock)
            for impl in impls for size in sizes]


def probes_to_trace(probes: Sequence[CollectiveProbe],
                    trace: Optional[Trace] = None, pid: int = 50,
                    t0: float = 0.0) -> Trace:
    """Measured and modeled spans side-by-side: one process, a
    *measured* thread and a *modeled* thread, each probe laid out on a
    shared cursor so the pair lines up vertically in Perfetto."""
    trace = trace if trace is not None else Trace()
    trace.process(pid, "collectives: measured vs modeled")
    trace.thread(pid, 0, "measured")
    trace.thread(pid, 1, "modeled")
    cursor = t0
    for pr in probes:
        name = f"{pr.impl} {pr.size_bytes}B"
        args = pr.to_dict()
        args.pop("model_terms", None)
        trace.span(name, cursor, pr.measured_s, pid=pid, tid=0,
                   cat="measured", args=args)
        trace.span(f"model:{pr.algorithm} {pr.size_bytes}B", cursor,
                   pr.modeled_s, pid=pid, tid=1, cat="modeled",
                   args=pr.model_terms or None)
        cursor += max(pr.measured_s, pr.modeled_s) * 1.05 + 1e-6
    return trace


def model_vs_measured(probes: Sequence[CollectiveProbe]) -> Dict:
    """Drift summary: per-probe rows plus aggregate measured/modeled
    ratio statistics (geometric mean and mean |log2 error| — the scale-
    free quantities a calibration regression would drive to 1 and 0)."""
    rows = []
    log2_errs = []
    for pr in probes:
        row = pr.to_dict()
        row.pop("runs_s", None)
        row["ratio"] = pr.ratio
        if pr.ratio is not None and pr.ratio > 0:
            err = math.log2(pr.ratio)
            row["log2_err"] = err
            log2_errs.append(err)
        rows.append(row)
    summary: Dict = {"count": len(rows), "rows": rows}
    if log2_errs:
        summary["geomean_ratio"] = 2.0 ** (sum(log2_errs) / len(log2_errs))
        summary["mean_abs_log2_err"] = (sum(abs(e) for e in log2_errs)
                                        / len(log2_errs))
        summary["max_ratio"] = 2.0 ** max(log2_errs)
        summary["min_ratio"] = 2.0 ** min(log2_errs)
    return summary
