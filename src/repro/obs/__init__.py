"""repro.obs — tracing, telemetry and measured-vs-modeled probes.

The observability layer of the five-layer engine:

  * ``obs.trace``  — span/counter/instant recorder + Chrome Trace Event
    (Perfetto) export; every report type gains ``to_trace()`` and
    ``python -m repro.obs.export`` converts persisted report JSON;
  * ``obs.meters`` — deterministic counters threaded through FlowSim
    memoization, ``search()`` and ``ClusterDynamics``;
  * ``obs.probe``  — ``block_until_ready``-bracketed wall-clock spans
    for the executable collectives next to their model predictions
    (import it explicitly: it is kept out of this namespace so the
    trace/export surface never pulls in the jax runtime).
"""
from repro.obs.meters import Meters
from repro.obs.trace import (EXPOSED_CNAME, Trace, timeline_tracks,
                             trace_from_cluster, trace_from_dynamics,
                             trace_from_report, trace_from_search,
                             trace_from_serving, validate_chrome)

__all__ = [
    "Meters", "Trace", "EXPOSED_CNAME", "timeline_tracks",
    "trace_from_report", "trace_from_search", "trace_from_cluster",
    "trace_from_dynamics", "trace_from_serving", "validate_chrome",
]
