"""repro: reproduction of "Communication Optimization for Distributed
Training" — models, CCL, network, scheduler, and codesign layers.

Also hosts the jax version-compat shims the whole package relies on.
"""
import jax

if not hasattr(jax, "shard_map"):
    # jax < 0.5 ships shard_map under jax.experimental only (with the
    # replication check named check_rep rather than check_vma); newer
    # releases promote it to the top level.  Alias the modern spelling so
    # one form works everywhere (package code and test scripts).
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f=None, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if f is None:
            return lambda g: _experimental_sm(g, **kwargs)
        return _experimental_sm(f, **kwargs)

    jax.shard_map = _shard_map
