"""Continuous batching: slot-based serving with per-sequence positions.

Real serving never has aligned requests; this driver keeps a fixed pool of
``max_slots`` cache slots, each with its own decode position.  New requests
are admitted into free slots mid-flight (their prompt is replayed through
the same batched decode step while other slots keep generating), finished
slots are recycled.  Works for every architecture family: the GQA ring
buffer and MLA latent cache invalidate stale entries purely from the
slot's position, recurrent (SSM/conv) state is zeroed on admit, and
precomputed cross-attention K/V (shared context, no slot axis) is left
untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ModelConfig
from repro.models.transformer import decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # measured request lifecycle, in batcher step indices: admission into
    # a slot, first emitted token, completion.  The measured counterpart
    # of the modeled TTFT/TPOT in ``codesign.serving`` (same
    # measured-vs-modeled idiom as the kernel probes).
    t_admit: Optional[int] = None
    t_first: Optional[int] = None
    t_finish: Optional[int] = None


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, max_slots: int,
                 max_len: int, context=None, temperature: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, params, max_slots, max_len,
                                context=context)
        # Identify each cache leaf's slot axis *structurally*: the one
        # axis whose extent changes with max_slots (compared via
        # eval_shape, no allocation).  Matching shape[1] == max_slots
        # false-positived when a head/layer/window axis coincidentally
        # equalled max_slots, zeroing live state for every slot.
        shapes = [
            jax.eval_shape(lambda n=n: init_cache(cfg, params, n, max_len,
                                                  context=context))
            for n in (max_slots, max_slots + 1)]

        def slot_axis(a, b) -> int:
            diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                    if x != y]
            return diff[0] if len(diff) == 1 else -1  # -1 = no slot axis

        self._slot_axis = jax.tree.map(slot_axis, *shapes)
        self.pos = np.zeros(max_slots, np.int32)      # next write position
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(max_slots)]
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.steps = 0  # decode steps executed; indexes request lifecycle

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int, rid: int) -> None:
        # a prompt needs max_len - 1 positions at most: one slot must stay
        # free to generate into.  Longer prompts used to be admitted, hit
        # the pos >= max_len - 1 stop mid-replay, and were returned "done"
        # with garbage output — reject up front instead.
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"request {rid}: prompt has {len(prompt)} tokens but "
                f"max_len={self.max_len} leaves room for at most "
                f"{self.max_len - 1}; truncate the prompt or raise max_len")
        self.queue.append(Request(rid, list(prompt), max_new))

    def _reset_slot_state(self, slot: int) -> None:
        """Zero recurrent/cross state for a recycled slot (KV ring buffers
        and MLA caches self-invalidate from the position)."""
        def zero_slot(a, ax):
            if ax < 0:
                return a
            return a.at[(slice(None),) * ax + (slot,)].set(0)
        self.cache = jax.tree.map(zero_slot, self.cache, self._slot_axis)

    def _admit(self) -> None:
        for s in range(self.max_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                req.t_admit = self.steps
                self.slot_req[s] = req
                self.slot_pending[s] = list(req.prompt)
                self.pos[s] = 0
                self._reset_slot_state(s)

    @property
    def active(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    # ------------------------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One batched decode step across all slots.  Slots still replaying
        their prompt feed the next prompt token; generating slots feed
        their previous output.  Returns {rid: emitted_token}."""
        self._admit()
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[s]:
                tokens[s, 0] = self.slot_pending[s][0]
            elif req.out:
                tokens[s, 0] = req.out[-1]
            else:  # empty prompt edge case
                tokens[s, 0] = 0
        pos = jnp.asarray(np.minimum(self.pos, self.max_len - 1))
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens), pos)
        last = logits[:, 0, :]
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(
                sub, last / self.temperature, axis=-1))
        else:
            nxt = np.asarray(jnp.argmax(last, axis=-1))

        emitted: Dict[int, int] = {}
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[s]:
                fed = self.slot_pending[s].pop(0)
                self.pos[s] += 1
                if not self.slot_pending[s]:
                    # prompt fully ingested: this step's logits are the
                    # first generation
                    tok = int(nxt[s])
                    if not req.out:
                        req.t_first = self.steps
                    req.out.append(tok)
                    emitted[req.rid] = tok
            else:
                tok = int(nxt[s])
                self.pos[s] += 1
                if not req.out:
                    req.t_first = self.steps
                req.out.append(tok)
                emitted[req.rid] = tok
            if len(req.out) >= req.max_new or \
                    self.pos[s] >= self.max_len - 1:
                req.done = True
                req.t_finish = self.steps
                self.completed.append(req)
                self.slot_req[s] = None
        self.steps += 1
        return emitted

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while self.active and steps < max_steps:
            self.step()
            steps += 1
        return self.completed
