"""Serving: prefill + batched single-token decode against the KV cache."""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.models.transformer import decode_step, forward


def make_serve_step(cfg: ModelConfig, ctx=None,
                    window: Optional[int] = None,
                    temperature: float = 0.0) -> Callable:
    """Returns step(params, cache, tokens (B,1), pos, key) ->
    (next_tokens (B,1), logits, new_cache)."""

    def serve_step(params, cache, tokens, pos, key):
        logits, new_cache = decode_step(cfg, params, cache, tokens, pos,
                                        ctx=ctx, window=window)
        last = logits[:, -1, :]
        if temperature > 0.0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, new_cache

    return serve_step


def make_prefill(cfg: ModelConfig, ctx=None,
                 window: Optional[int] = None) -> Callable:
    """Forward over the prompt; the examples' serving driver re-feeds the
    prompt through decode_step to fill the cache (simple, cache-exact)."""

    def prefill(params, tokens, context=None):
        logits, _ = forward(cfg, params, tokens, context=context, ctx=ctx,
                            window=window)
        return logits

    return prefill
