"""Pipeline parallelism: runnable GPipe stage pipeline + PTD-P's
interleaved-schedule analytics (paper Sec. III-A, [1]).

The runnable path maps stages onto a ``pipe`` mesh axis inside shard_map;
stage boundaries are ``ppermute`` point-to-point transfers — the exact
traffic pattern the survey attributes to pipeline parallelism.  Autodiff
through the ppermute chain gives the backward pipeline for free (reverse
permutes), so the whole thing trains under ``jax.grad``.

The analytic model reproduces PTD-P's central claim: with m microbatches
and interleave factor v, the pipeline bubble shrinks from (p-1)/m to
(p-1)/(m*v) at the cost of v-times more boundary communication.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Analytics (PTD-P Sec. 2.2)
# ---------------------------------------------------------------------------


def bubble_fraction(p: int, m: int, v: int = 1) -> float:
    """Fraction of the iteration spent idle in the pipeline bubble."""
    return (p - 1) / (m * v)


def iteration_time(p: int, m: int, v: int, t_chunk: float,
                   t_comm: float = 0.0) -> float:
    """1F1B schedule makespan: (m*v + p - 1) chunk slots of t_chunk, plus
    per-boundary comm (v times more boundaries when interleaved)."""
    slots = m * v + (p - 1)
    return slots * (t_chunk / v) + m * v * t_comm


# ---------------------------------------------------------------------------
# Runnable GPipe pipeline over a mesh axis
# ---------------------------------------------------------------------------


def pipeline_apply(stage_fn: Callable, stage_params, x_mb: jax.Array,
                   axis_name: str, num_stages: int) -> jax.Array:
    """Run microbatches through the stage pipeline (inside shard_map).

    stage_fn(params, x) -> x; stage_params: this device's stage params;
    x_mb: (M, ...) microbatches (meaningful on stage 0; other stages
    ignore).  Returns (M, ...) outputs (meaningful on the last stage).
    """
    p = num_stages
    m = x_mb.shape[0]
    idx = lax.axis_index(axis_name)
    fwd_perm = [(i, i + 1) for i in range(p - 1)]

    state = jnp.zeros_like(x_mb[0])
    outs = jnp.zeros_like(x_mb)
    recv = jnp.zeros_like(x_mb[0])

    for t in range(m + p - 1):
        # stage 0 injects microbatch t; others take the received activation
        mb_idx = min(t, m - 1)
        inp = jnp.where(idx == 0, x_mb[mb_idx], recv)
        active = (t - idx >= 0) & (t - idx < m)
        out = stage_fn(stage_params, inp)
        out = jnp.where(active, out, jnp.zeros_like(out))
        # last stage stores its finished microbatch (t - (p-1))
        done_idx = t - (p - 1)
        if done_idx >= 0:
            store = jnp.where(idx == p - 1, out, jnp.zeros_like(out))
            outs = lax.dynamic_update_slice_in_dim(
                outs, store[None], done_idx, axis=0)
        # hand activations to the next stage
        if p > 1:
            recv = lax.ppermute(out, axis_name, fwd_perm)
    # make the outputs visible on every stage (only the last stage holds
    # non-zeros, so a psum acts as the final broadcast)
    return lax.psum(outs, axis_name)


def interleaved_pipeline_apply(stage_fn: Callable, chunk_params,
                               x_mb: jax.Array, axis_name: str,
                               num_stages: int, v: int) -> jax.Array:
    """PTD-P interleaved schedule, runnable (inside shard_map).

    Each device holds ``v`` model CHUNKS (params stacked on a leading v
    dim); virtual stage k runs on device k % p with chunk k // p, so an
    activation ring-hops right every tick and finishes after v*p ticks.
    The bubble shrinks to (p-1)/(m*v) at the cost of v times more boundary
    traffic — exactly the paper's PTD-P row, now executable.

    stage_fn(chunk_param, x) -> x; x_mb: (M, ...) microbatches (stage 0
    injects); returns (M, ...) outputs (psum-broadcast at the end).
    """
    p = num_stages
    m = x_mb.shape[0]
    idx = lax.axis_index(axis_name)
    right = [(i, (i + 1) % p) for i in range(p)]
    total_vstages = v * p

    outs = jnp.zeros_like(x_mb)
    recv = jnp.zeros_like(x_mb[0])
    recv_vs = jnp.full((), -1, jnp.int32)  # virtual stage of recv (-1 idle)
    inj_count = jnp.zeros((), jnp.int32)   # microbatches injected (dev 0)
    done_count = jnp.zeros((), jnp.int32)  # microbatches finished (FIFO)

    # injections stall while a returning chunk occupies device 0, so the
    # tick budget is m visits x v chunks on device 0 plus the drain
    for t in range(m * v + 2 * total_vstages):
        inject = (idx == 0) & (inj_count < m) & (recv_vs < 0)
        x_next = lax.dynamic_index_in_dim(
            x_mb, jnp.clip(inj_count, 0, m - 1), axis=0, keepdims=False)
        x_in = jnp.where(inject, x_next, recv)
        vs = jnp.where(inject, 0, recv_vs)
        inj_count = inj_count + inject.astype(jnp.int32)

        active = (vs >= 0) & (vs < total_vstages) & \
            (lax.rem(vs, p) == idx)
        chunk_idx = jnp.clip(vs // p, 0, v - 1)
        branches = [lambda x_, _c=c: stage_fn(
            jax.tree.map(lambda a, _c2=_c: a[_c2], chunk_params), x_)
            for c in range(v)]
        y = lax.switch(chunk_idx, branches, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        vs_out = jnp.where(active, vs + 1, jnp.full((), -1, jnp.int32))

        # completed activations collect (in injection order) on the last
        # virtual stage's device
        done = vs_out == total_vstages
        store = jnp.where(done, y, jnp.zeros_like(y))
        # scatter-ADD: non-done ticks contribute zeros, so the slot written
        # by the final completion is never clobbered afterwards
        outs = outs.at[jnp.clip(done_count, 0, m - 1)].add(store)
        done_count = done_count + done.astype(jnp.int32)

        # ring-hop everything still in flight
        send = jnp.where(done, jnp.zeros_like(y), y)
        send_vs = jnp.where(done, jnp.full((), -1, jnp.int32), vs_out)
        if p > 1:
            recv = lax.ppermute(send, axis_name, right)
            recv_vs = lax.ppermute(send_vs, axis_name, right)
        else:
            recv, recv_vs = send, send_vs
    return lax.psum(outs, axis_name)


def make_pipeline_fn(stage_fn: Callable, mesh, axis_name: str = "pipe"):
    """Wrap pipeline_apply as a jitted global function.

    stage_params leaves must have a leading dim == num_stages (stacked);
    x_mb: (M, mb, ...) global microbatches.
    """
    p = mesh.shape[axis_name]

    def global_fn(stage_params, x_mb):
        def body(params_local, x_local):
            sp = jax.tree.map(lambda a: a[0], params_local)
            return pipeline_apply(stage_fn, sp, x_local, axis_name, p)

        pspec = jax.tree.map(lambda _: P(axis_name), stage_params)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(pspec, P()),
            out_specs=P(),
            check_vma=False,
        )(stage_params, x_mb)

    return jax.jit(global_fn)
