"""Sharding planner: parallelization strategy -> PartitionSpecs.

This is the top layer of the paper's paradigm.  The *strategy* (which mesh
axes carry data / tensor / expert parallelism) is decided here, and the
choice determines the collective-communication demand that the CCL and
network layers see (Sec. II-E):

  * DP over ``data`` axes  -> gradient All-Reduce / Reduce-Scatter
  * Megatron TP over ``model``  -> per-block activation All-Reduce
  * EP over ``model``  -> MoE All-to-All (train) / All-Reduce (decode)
  * PP over ``pipe``  -> point-to-point (repro.parallel.pipeline)

Every rule is divisibility-guarded: an axis is only used if it divides the
tensor dimension (e.g. qwen2's 14 heads cannot shard over model=16, so its
attention weights stay replicated — recorded as a planner note).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.types import MeshConfig, ModelConfig


Axis = Union[str, Tuple[str, ...], None]


# ---------------------------------------------------------------------------
# Parallel context threaded through model code
# ---------------------------------------------------------------------------


@dataclass
class ParallelCtx:
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    use_ep: bool = True
    capacity_factor: float = 1.25
    decode_capacity_factor: float = 4.0
    remat: bool = True
    causal_skip: bool = False
    unroll_layers: bool = False  # dry-run: unroll layer scans so XLA cost
    # analysis (which visits while bodies once) counts every layer
    ep_weight_stationary: bool = False  # decode MoE: keep FSDP'd expert
    # weights sharded; psum tiny activations instead of gathering weights
    use_pallas: bool = False  # attention via the Pallas kernel (TPU prod
    # path; interpret-executes on CPU — used by integration tests)
    act_spec: Optional[P] = None
    logit_spec: Optional[P] = None
    notes: List[str] = field(default_factory=list)

    @property
    def ep_axis(self) -> str:
        return self.model_axis

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.model_axis] if self.mesh else 1

    @property
    def dp(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n if self.mesh else 1


def make_ctx(mesh: Optional[Mesh], mesh_cfg: MeshConfig, *,
             remat: bool = True, causal_skip: bool = False,
             use_ep: bool = True, unroll_layers: bool = False) -> ParallelCtx:
    batch_axes = tuple(mesh_cfg.data_axes)
    b = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return ParallelCtx(
        mesh=mesh,
        data_axes=batch_axes,
        model_axis=mesh_cfg.model_axes[0],
        remat=remat,
        causal_skip=causal_skip,
        use_ep=use_ep,
        unroll_layers=unroll_layers,
        act_spec=P(b, None, None),
        logit_spec=P(b, None, mesh_cfg.model_axes[0]),
    )


# ---------------------------------------------------------------------------
# Divisibility-guarded spec construction
# ---------------------------------------------------------------------------


def _axis_size(mesh_cfg: MeshConfig, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh_cfg.axis_size(a)
        return n
    return mesh_cfg.axis_size(axis)


def guarded(shape: Sequence[int], axes: Sequence[Axis],
            mesh_cfg: MeshConfig, notes: Optional[List[str]] = None,
            what: str = "") -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    out = []
    for dim, ax in zip(shape, axes):
        if ax is not None and dim % _axis_size(mesh_cfg, ax) == 0:
            out.append(ax)
        else:
            if ax is not None and notes is not None:
                notes.append(f"replicated {what} dim={dim} (axis {ax} "
                             f"size {_axis_size(mesh_cfg, ax)} !| {dim})")
            out.append(None)
    return P(*out)


def validate_spec(spec: P, shape: Sequence[int], mesh_cfg: MeshConfig) -> bool:
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is not None and dim % _axis_size(mesh_cfg, ax) != 0:
            return False
    return True


# ---------------------------------------------------------------------------
# Parameter specs (mirror of models.transformer.init_params structure)
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig, mesh_cfg: MeshConfig,
                notes: Optional[List[str]] = None) -> Any:
    """PartitionSpec pytree matching ``init_params(cfg, ...)``."""
    m = mesh_cfg.model_axes[0]
    tp = _axis_size(mesh_cfg, m)
    shapes = jax.eval_shape(
        lambda k: _init_for_shape(cfg, k), jax.random.PRNGKey(0))
    leaf_paths = jax.tree_util.tree_flatten_with_path(shapes)[0]

    def rule(path: str, shape: Tuple[int, ...]) -> P:
        # strip the group-stacking leading dim
        stacked = bool(re.search(r"group\d+", path)) or "/cross/" in path
        eff = shape[1:] if stacked else shape
        sp = _leaf_rule(path, eff, cfg, mesh_cfg, notes)
        return P(None, *sp) if stacked else sp

    specs = {}
    flat = {}
    for kp, leaf in leaf_paths:
        path = "/" + "/".join(_key_str(k) for k in kp)
        flat[path] = rule(path, leaf.shape)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(shapes), [
            flat["/" + "/".join(_key_str(k) for k in kp)]
            for kp, _ in leaf_paths])


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _init_for_shape(cfg: ModelConfig, key):
    from repro.models.transformer import init_params
    return init_params(cfg, key, dtype=jnp.bfloat16)


def _leaf_rule(path: str, shape, cfg: ModelConfig, mesh_cfg: MeshConfig,
               notes) -> P:
    m = mesh_cfg.model_axes[0]
    g = lambda axes, what: guarded(shape, axes, mesh_cfg, notes,
                                   what=f"{what}:{path}")
    name = path.rsplit("/", 1)[-1]
    # ---- embeddings / head ----
    if name == "embed":
        # vocab-sharded: logits stay sharded over the model axis and the
        # loss logsumexp reduces them with a small All-Reduce instead of
        # materializing (B, S, V) replicated.
        return g((m, None), "embed")
    if name == "lm_head":
        return g((None, m), "lm_head")
    if name == "scale":  # norms
        return P(*([None] * len(shape)))
    # ---- attention ----
    if name in ("wq",):
        return g((None, m, None), "wq")
    if name in ("wk", "wv"):
        return g((None, m, None), "wkv")
    if name == "wo":
        return g((m, None, None), "wo")
    if name in ("bq",):
        return g((m, None), "bq")
    if name in ("bk", "bv"):
        return g((m, None), "bkv")
    if name == "gate_attn":
        return P()
    # ---- MLA ----
    if name == "w_uq":
        return g((None, m, None), "w_uq")
    if name in ("w_uk", "w_uv"):
        return g((None, m, None), "w_ukv")
    if name in ("w_dq", "w_dkv"):
        return P(None, None)
    # ---- MoE ----
    if name == "router":
        return P(None, None)
    if name in ("w_gate", "w_up", "w_down") and "ffn" in path and \
            len(shape) == 3 and cfg.is_moe and shape[0] == cfg.num_experts:
        return g((m, None, None), "moe_expert")
    # ---- dense FFN (also MoE shared expert) ----
    if name in ("w_gate", "w_up"):
        return g((None, m), "ffn_col")
    if name == "w_down":
        return g((m, None), "ffn_row")
    # ---- Mamba ----
    if name in ("z_proj", "x_proj"):
        sp = _mamba_head_axis(cfg, mesh_cfg)
        return g((None, sp), "ssm_col")
    if name == "out_proj":
        sp = _mamba_head_axis(cfg, mesh_cfg)
        return g((sp, None), "ssm_row")
    if name == "dt_proj":
        sp = _mamba_head_axis(cfg, mesh_cfg)
        return g((None, sp), "ssm_dt")
    if name in ("b_proj", "c_proj"):
        return P(None, None)
    if name in ("conv_x",):
        sp = _mamba_head_axis(cfg, mesh_cfg)
        return g((None, sp), "ssm_conv")
    if name == "conv_x_bias":
        sp = _mamba_head_axis(cfg, mesh_cfg)
        return g((sp,), "ssm_conv_bias")
    if name in ("conv_b", "conv_c"):
        return P(None, None)
    if name in ("conv_b_bias", "conv_c_bias"):
        return P(None)
    if name in ("A_log", "D", "dt_bias"):
        sp = _mamba_head_axis(cfg, mesh_cfg)
        return g((sp,), "ssm_head_vec")
    # fallback: replicate
    return P(*([None] * len(shape)))


def _mamba_head_axis(cfg: ModelConfig, mesh_cfg: MeshConfig) -> Axis:
    """Shard SSM channels only when shards align with head boundaries."""
    m = mesh_cfg.model_axes[0]
    tp = _axis_size(mesh_cfg, m)
    if cfg.ssm_num_heads and cfg.ssm_num_heads % tp == 0:
        return m
    return None


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def _bspec(mesh_cfg: MeshConfig) -> Axis:
    axes = tuple(mesh_cfg.data_axes)
    return axes if len(axes) > 1 else axes[0]


def batch_specs(mesh_cfg: MeshConfig) -> Dict[str, P]:
    b = _bspec(mesh_cfg)
    return {
        "tokens": P(b, None),
        "labels": P(b, None),
        "context": P(b, None, None),
    }


def cache_specs(cfg: ModelConfig, mesh_cfg: MeshConfig, batch: int,
                cache_shapes: Any, notes: Optional[List[str]] = None) -> Any:
    """Specs for the decode cache (pytree matching ``cache_shapes`` from
    ``jax.eval_shape``): shard batch over data axes when divisible, otherwise
    shard the sequence/slot dim (long-context batch=1 case)."""
    b = _bspec(mesh_cfg)
    m = mesh_cfg.model_axes[0]
    dp = _axis_size(mesh_cfg, b)
    batch_ok = batch % dp == 0

    def kv_spec(shape):
        # stacked (R, B, slots, KV, hd)
        if batch_ok:
            return guarded(shape, (None, b, None, m, None), mesh_cfg, notes,
                           what="kv_cache")
        return guarded(shape, (None, None, b, m, None), mesh_cfg, notes,
                       what="kv_cache_seqsharded")

    def mla_spec(shape):
        # stacked (R, B, L, lora)
        if batch_ok:
            return guarded(shape, (None, b, None, None), mesh_cfg, notes,
                           what="mla_cache")
        return guarded(shape, (None, None, b, None), mesh_cfg, notes,
                       what="mla_cache_seqsharded")

    def ssm_spec(shape):
        # conv: (R, B, K-1, C) / ssm state: (R, B, H, P, N)
        if len(shape) == 5:
            axes = (None, b if batch_ok else None, m, None, None)
        else:
            axes = (None, b if batch_ok else None, None, m)
        return guarded(shape, axes, mesh_cfg, notes, what="ssm_cache")

    def classify(path: str, shape) -> P:
        name = path.rsplit("/", 1)[-1]
        if name in ("k", "v"):
            if "/cross/" in path:  # cross K/V: (R or L, B, T, H, hd)
                return guarded(shape, (None, b if batch_ok else None, None,
                                       m, None), mesh_cfg, notes,
                               what="cross_cache")
            return kv_spec(shape)
        if name in ("c", "k_rope"):
            return mla_spec(shape)
        if name in ("conv_x", "conv_b", "conv_c", "ssm"):
            if name == "ssm":
                return ssm_spec(shape)
            return guarded(shape, (None, b if batch_ok else None, None,
                                   m if name == "conv_x" else None),
                           mesh_cfg, notes, what="conv_cache")
        return P(*([None] * len(shape)))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = [classify("/" + "/".join(_key_str(k) for k in kp), leaf.shape)
           for kp, leaf in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache_shapes), out)


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state spec = param spec + data axis on first free dim
# ---------------------------------------------------------------------------


def zero1_spec(param_spec: P, shape: Tuple[int, ...],
               mesh_cfg: MeshConfig) -> P:
    b = _bspec(mesh_cfg)
    dp = _axis_size(mesh_cfg, b)
    entries = list(tuple(param_spec) + (None,) * (len(shape) - len(param_spec)))
    if b in entries:  # already data-sharded (FSDP) — nothing to add
        return P(*entries)
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is None and dim % dp == 0:
            entries[i] = b
            return P(*entries)
    return P(*entries)


def apply_fsdp(specs: Any, shapes: Any, mesh_cfg: MeshConfig) -> Any:
    """FSDP / ZeRO-3-style weight sharding: additionally shard each weight
    over the data axes on its first free divisible dim.  XLA all-gathers
    layer weights on demand (visible in the dry-run's collective stats) —
    memory-forced for the >90B-param architectures at bf16."""
    return jax.tree.map(
        lambda sp, sh: zero1_spec(sp, sh.shape, mesh_cfg), specs, shapes,
        is_leaf=lambda x: isinstance(x, P))
