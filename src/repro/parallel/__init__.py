"""Parallelization-strategy layer (paper Sec. II-B / III-A)."""
from repro.parallel.planner import (  # noqa: F401
    ParallelCtx,
    batch_specs,
    cache_specs,
    make_ctx,
    param_specs,
    validate_spec,
)
