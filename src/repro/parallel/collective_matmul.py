"""Collective (decomposed) matmul — the overlap lever from EXPERIMENTS §Perf.

The Megatron TP pattern ``all_gather(x) @ W_col`` serializes a bulk
All-Gather before the MXU can start.  The collective-matmul decomposition
(Wang et al., ASPLOS'23; used by XLA's latency-hiding scheduler on TPU)
splits it into p ring steps: at step s each shard multiplies the chunk it
currently holds while ``ppermute``-ing the next chunk — communication
rides under compute, turning the exposed All-Gather into (ideally) one
chunk-latency of exposure.

Two duals are provided (both inside ``shard_map``):

  * ``ag_matmul``  — y = all_gather_s(x) @ W,  x sharded on its row dim,
    W sharded on columns; output column-sharded.
  * ``matmul_rs``  — y = reduce_scatter_s(x @ W), x column(=contraction)-
    sharded, W row-sharded; the partial-sum reduce-scatter is decomposed
    into the same ring.

On CPU these validate numerically; on a TPU the per-step ppermutes give
the scheduler independent DMA/MXU work to overlap (the HLO shows p
small matmuls interleaved with p collective-permutes instead of one
all-gather + one big dot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ag_matmul(x_local: jax.Array, w_local: jax.Array, axis_name: str,
              axis_size: int) -> jax.Array:
    """x_local: (m/p, k) shard of x (sharded on rows over the axis);
    w_local: (k, n/p) column shard of W.  Returns (m, n/p): this shard's
    columns of all_gather(x) @ W, computed in p ring steps."""
    p = axis_size
    idx = lax.axis_index(axis_name)
    m_local = x_local.shape[0]
    out = jnp.zeros((p * m_local, w_local.shape[1]), x_local.dtype)
    right = [(i, (i + 1) % p) for i in range(p)]
    chunk = x_local
    for s in range(p):
        # the chunk currently held originated at rank (idx - s) mod p:
        # its rows sit at block (idx - s) of the gathered x
        src = (idx - s) % p
        part = jax.lax.dot_general(
            chunk, w_local, (((1,), (0,)), ((), ())),
            preferred_element_type=out.dtype)
        out = lax.dynamic_update_slice_in_dim(out, part, src * m_local,
                                              axis=0)
        if s + 1 < p:
            chunk = lax.ppermute(chunk, axis_name, right)
    return out


def matmul_rs(x_local: jax.Array, w_local: jax.Array, axis_name: str,
              axis_size: int) -> jax.Array:
    """x_local: (m, k/p) contraction shard; w_local: (k/p, n).  Returns
    (m/p, n): this shard's row block of reduce_scatter(x @ W, rows),
    with the partial-sum reduction decomposed into the ring."""
    p = axis_size
    idx = lax.axis_index(axis_name)
    m = x_local.shape[0]
    assert m % p == 0
    mb = m // p
    right = [(i, (i + 1) % p) for i in range(p)]

    def partial(block_idx):
        xb = lax.dynamic_slice_in_dim(x_local, block_idx * mb, mb, axis=0)
        return jax.lax.dot_general(
            xb, w_local, (((1,), (0,)), ((), ())),
            preferred_element_type=x_local.dtype)

    # ring accumulation (same index algebra as ccl.primitives.
    # ring_reduce_scatter): an accumulator created on rank r carries row
    # block r-1 and gathers every rank's partial for it as it travels
    # right; rank i finishes holding the full sum for block i.
    acc = partial((idx - 1) % p)
    for s in range(p - 1):
        acc = lax.ppermute(acc, axis_name, right)
        acc = acc + partial((idx - s - 2) % p)
    return acc
