"""Persisted warm-start seeds for ``codesign.search``.

A search's winning knob assignment is a function of the topology, the
model, and the mesh — and those recur: the same cluster re-plans after
events (``ClusterDynamics``), CI re-runs the same locked benchmarks, and
operators re-search after small config edits.  This module persists the
winner per ``(topology fingerprint, model, shape, mesh)`` as a small JSON
file; ``search(problem, seeds_dir=...)`` loads it as the first candidate
priced (phase ``"warm_start"``) and saves the new winner back.  A stale
seed costs one evaluation; a fresh one makes the incumbent optimal from
candidate #1, so the sweep's remaining budget is pure verification.

Seed files are keyed by content fingerprints, so a rewired topology (or a
degradation view from ``Topology.without_link``) never picks up another
fabric's plan.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Optional

from repro.ccl.synth import topology_fingerprint


def seed_key(problem) -> str:
    """Filename-safe identity of what a seed is valid for."""
    mesh = problem.mesh
    cfg_fp = hashlib.sha1(repr(
        (problem.cfg.name, problem.shape.name, mesh.shape, mesh.axis_names,
         mesh.data_axes, mesh.model_axes, mesh.pipeline_axis)
    ).encode()).hexdigest()[:10]
    return f"{topology_fingerprint(problem.topo)}__{cfg_fp}"


def seed_path(seeds_dir: str, problem) -> str:
    return os.path.join(seeds_dir, f"seed_{seed_key(problem)}.json")


def save_seed(seeds_dir: str, problem, assignment: Dict[str, object]) -> str:
    """Persist a search winner's knob assignment for this problem's
    (topology, model, shape, mesh).  Returns the file path written."""
    from repro.codesign.api import _assignment_value_json
    os.makedirs(seeds_dir, exist_ok=True)
    path = seed_path(seeds_dir, problem)
    payload = {
        "key": seed_key(problem),
        "topology": problem.topo.name,
        "model": problem.cfg.name,
        "assignment": {n: _assignment_value_json(v)
                       for n, v in assignment.items()},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def load_seed(seeds_dir: str, problem) -> Optional[Dict[str, object]]:
    """The persisted winning assignment for this problem, or None when no
    (valid) seed exists.  Unreadable/mismatched files are treated as
    absent — a corrupt seed must never break a search."""
    from repro.codesign.api import _assignment_from_json
    path = seed_path(seeds_dir, problem)
    try:
        with open(path) as f:
            payload = json.load(f)
        if payload.get("key") != seed_key(problem):
            return None
        return _assignment_from_json(payload["assignment"])
    except (OSError, ValueError, KeyError):
        return None
