"""Horizontal multi-job cluster planner (paper Sec. IV-A, CASSINI [16]).

``plan_iteration`` plans one job on an empty network; real clusters run many
jobs whose communication bursts meet on shared links (the Fig. 5(b) case at
(2)).  ``plan_cluster`` closes the loop between the vertical co-design
engine and the horizontal flow scheduler:

  1. carve the topology's accelerators into per-job partitions (explicit
     ``JobSpec.devices`` or first-fit consecutive blocks);
  2. run every job's pinned :class:`CodesignProblem` through ``api.plan``
     — placement, per-task algorithm selection priced on the shared
     topology, JCT — and keep its full per-link byte map;
  3. ask the network layer which links carry traffic from >= 2 jobs
     (``net.simulate.shared_link_load``);
  4. compress each job into a :class:`sched.flows.JobProfile` (compute
     phase, comm burst, per-contended-link demand fraction) and search
     phase shifts with ``sched.flows.stagger_jobs`` to minimize the
     worst-case JCT stretch.

The result is a :class:`ClusterReport`: per-job naive (zero-phase) vs.
staggered JCT, the contended-link map, and the chosen phases — the first
genuinely multi-tenant answer the engine can hand back up the stack.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.ccl.select import CostModel
from repro.core.demand_builder import DemandParams
from repro.core.knobs import Fixed
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig
from repro.net.simulate import shared_link_load
from repro.net.topology import Topology
from repro.sched.flows import (BurstProfile, JobProfile, restagger_jobs,
                               stagger_jobs, stagger_mixed, worst_stretch)
from repro.sched.tasks import Policy

from repro.codesign.api import CodesignProblem, plan
from repro.codesign.placement import Placement, place_mesh
from repro.codesign.report import (CodesignReport, _link_key,
                                   _parse_link_key)


@dataclass(frozen=True)
class JobSpec:
    """One tenant job: what to train, how to shard it, and (optionally)
    which physical devices it owns.

    A job is at heart a :class:`CodesignProblem` minus the cluster-level
    concerns (topology, device carving, switch budget).  Pass either the
    flat fields (the legacy surface) or ``problem=`` — a problem carries
    every per-job knob, so mixing it with flat per-job fields is an
    error; the flat views (``cfg``/``mesh``/``policy``/...) are then
    filled from it."""

    name: str
    cfg: Optional[ModelConfig] = None
    shape: Optional[ShapeConfig] = None
    mesh: Optional[MeshConfig] = None
    devices: Optional[Tuple[int, ...]] = None  # None = first-fit block
    policy: Policy = "priority"
    dp_params: Optional[DemandParams] = None
    force: Optional[Dict[str, str]] = None
    # per-tenant compression tolerance (repro.compress): admits compressed
    # candidates into this job's selection; smaller per-job flows also
    # shrink what the horizontal layer sees on contended links
    error_budget: Union[float, Dict[str, float]] = 0.0
    problem: Optional[CodesignProblem] = None
    # a serving tenant (codesign.serving.ServingSpec): prefill/decode
    # disaggregation + open-loop arrivals instead of a training iteration.
    # Mutually exclusive with the flat fields and with problem=.
    serving: Optional[object] = None

    def __post_init__(self):
        if self.serving is not None:
            if (self.problem is not None or self.cfg is not None
                    or self.shape is not None or self.mesh is not None
                    or self.policy != "priority"
                    or self.dp_params is not None or self.force is not None
                    or self.error_budget != 0.0):
                raise ValueError(
                    f"job {self.name!r}: serving= carries the per-tenant "
                    f"config; don't also pass cfg/shape/mesh/policy/"
                    f"dp_params/force/error_budget/problem")
            object.__setattr__(self, "cfg", self.serving.cfg)
            object.__setattr__(self, "mesh", self.serving.mesh())
            object.__setattr__(self, "dp_params", self.serving.dp_params)
            return
        if self.problem is None:
            if self.cfg is None or self.shape is None or self.mesh is None:
                raise ValueError(f"job {self.name!r} needs cfg/shape/mesh "
                                 f"(or a CodesignProblem via problem=)")
            return
        if (self.cfg is not None or self.shape is not None
                or self.mesh is not None or self.policy != "priority"
                or self.dp_params is not None or self.force is not None
                or self.error_budget != 0.0):
            raise ValueError(
                f"job {self.name!r}: problem= carries the per-job knobs; "
                f"don't also pass cfg/shape/mesh/policy/dp_params/force/"
                f"error_budget")
        sp = self.problem.space
        for knob_name in ("policy", "error_budget"):
            if not isinstance(getattr(sp, knob_name), Fixed):
                raise ValueError(
                    f"job {self.name!r}: plan_cluster needs fully "
                    f"specified per-job problems — {knob_name} is "
                    f"{getattr(sp, knob_name)!r}; run search() per job "
                    f"first or pin it")
        object.__setattr__(self, "cfg", self.problem.cfg)
        object.__setattr__(self, "shape", self.problem.shape)
        object.__setattr__(self, "mesh", self.problem.mesh)
        object.__setattr__(self, "policy", sp.policy.value)
        object.__setattr__(self, "dp_params", self.problem.dp_params)
        object.__setattr__(self, "error_budget", sp.error_budget.value)
        forced = {p: k.value for p, k in sp.algorithm.items()
                  if p != "*" and isinstance(k, Fixed)}
        object.__setattr__(self, "force", forced or None)

    def to_problem(self, topo: Topology, placement: Placement,
                   cost_model: Union[str, CostModel],
                   switch_capacity: Optional[int],
                   hotspot_k: int) -> CodesignProblem:
        """This job as a fully pinned problem on the shared cluster:
        the carved placement and the cluster-level cost model / switch
        budget override whatever the carried problem held."""
        if self.serving is not None:
            from repro.codesign.serving import serving_problem
            prob = serving_problem(self.serving, topo,
                                   cost_model=cost_model,
                                   hotspot_k=hotspot_k)
            space = dataclasses.replace(
                prob.space, placement=Fixed(placement),
                switch_capacity=Fixed(switch_capacity))
            return dataclasses.replace(prob, space=space)
        if self.problem is not None:
            space = dataclasses.replace(
                self.problem.space, placement=Fixed(placement),
                switch_capacity=Fixed(switch_capacity))
            return dataclasses.replace(
                self.problem, topo=topo, space=space,
                cost_model=cost_model, hotspot_k=hotspot_k)
        return CodesignProblem.from_kwargs(
            self.cfg, self.shape, self.mesh, topo, policy=self.policy,
            placement=placement, cost_model=cost_model,
            dp_params=self.dp_params, force=self.force,
            hotspot_k=hotspot_k, switch_capacity=switch_capacity,
            error_budget=self.error_budget)


@dataclass
class JobPlan:
    """One job's single-tenant plan plus its horizontal-layer summary."""

    spec: JobSpec
    devices: Tuple[int, ...]
    report: CodesignReport
    profile: JobProfile
    link_bytes: Dict[Tuple, float]

    def to_dict(self) -> Dict:
        """Plain-JSON form (the ``spec`` carries live configs and is keyed
        by name only — ``from_dict`` takes the live specs back)."""
        return {
            "name": self.spec.name, "devices": list(self.devices),
            "report": self.report.to_dict(),
            "profile": {"compute_s": self.profile.compute_s,
                        "comm_s": self.profile.comm_s,
                        "demand_frac": self.profile.demand_frac},
            "link_bytes": {_link_key(l): b
                           for l, b in self.link_bytes.items()},
        }

    @classmethod
    def from_dict(cls, d: Dict, spec: JobSpec) -> "JobPlan":
        p = d["profile"]
        if "ttft" in d["report"]:  # a serving tenant's report
            from repro.codesign.serving import ServingReport
            report = ServingReport.from_dict(d["report"])
        else:
            report = CodesignReport.from_dict(d["report"])
        return cls(
            spec=spec, devices=tuple(d["devices"]), report=report,
            profile=JobProfile(d["name"], p["compute_s"], p["comm_s"],
                               p["demand_frac"]),
            link_bytes={_parse_link_key(k): b
                        for k, b in d["link_bytes"].items()})


@dataclass
class ClusterReport:
    """What the horizontal planner hands back up the stack."""

    jobs: List[JobPlan]
    contended: Dict[Tuple, Dict[str, float]]  # link -> {job: bytes}
    phases: Dict[str, float]
    naive_jct: Dict[str, float]
    staggered_jct: Dict[str, float]
    cost_model: str = "flowsim"
    link_demands: Dict[str, Dict[Tuple, float]] = field(default_factory=dict)
    # per serving tenant: naive (zero training phases) vs. staggered SLO
    # numbers under co-tenancy — {name: {"naive_ttft_p99": ..., ...}}
    serving: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def solo_jct(self) -> Dict[str, float]:
        """Each job's JCT alone on the cluster (its iteration period)."""
        return {jp.spec.name: jp.profile.period for jp in self.jobs}

    def _stretch(self, jct: Dict[str, float]) -> float:
        # training tenants only: serving quality lives in SLO metrics
        # (self.serving), not in iteration stretch
        profs = [jp.profile for jp in self.jobs if jp.spec.serving is None]
        if not profs:
            return 1.0
        return worst_stretch(jct, profs)

    @property
    def naive_worst_stretch(self) -> float:
        return self._stretch(self.naive_jct)

    @property
    def staggered_worst_stretch(self) -> float:
        return self._stretch(self.staggered_jct)

    @property
    def stagger_speedup(self) -> float:
        """Worst-case JCT improvement of staggering over zero phases."""
        return self.naive_worst_stretch / self.staggered_worst_stretch

    # ------------------------------------------------------------------
    # JSON persistence (the warm-start seed codesign.dynamics re-plans
    # from: per-job reports round-trip via CodesignReport, links as
    # "u->v" keys; JobSpec objects carry live model configs so from_dict
    # takes them back by name)
    # ------------------------------------------------------------------

    def to_dict(self) -> Dict:
        return {
            "jobs": [jp.to_dict() for jp in self.jobs],
            "contended": {_link_key(l): dict(users)
                          for l, users in self.contended.items()},
            "phases": dict(self.phases),
            "naive_jct": dict(self.naive_jct),
            "staggered_jct": dict(self.staggered_jct),
            "cost_model": self.cost_model,
            "link_demands": {name: {_link_key(l): f
                                    for l, f in dem.items()}
                             for name, dem in self.link_demands.items()},
            "serving": {name: dict(m) for name, m in self.serving.items()},
        }

    @classmethod
    def from_dict(cls, d: Dict, specs: Dict[str, JobSpec]
                  ) -> "ClusterReport":
        missing = [j["name"] for j in d["jobs"] if j["name"] not in specs]
        if missing:
            raise ValueError(f"ClusterReport.from_dict needs the live "
                             f"JobSpec for {missing} (specs= by name)")
        return cls(
            jobs=[JobPlan.from_dict(j, specs[j["name"]])
                  for j in d["jobs"]],
            contended={_parse_link_key(k): dict(users)
                       for k, users in d["contended"].items()},
            phases=dict(d["phases"]),
            naive_jct=dict(d["naive_jct"]),
            staggered_jct=dict(d["staggered_jct"]),
            cost_model=d["cost_model"],
            link_demands={name: {_parse_link_key(k): f
                                 for k, f in dem.items()}
                          for name, dem in d["link_demands"].items()},
            serving={name: dict(m)
                     for name, m in d.get("serving", {}).items()})

    def to_trace(self, topo=None, **kw):
        """The cluster plan as a Perfetto trace: one process group per
        tenant, each tenant's iteration tracks shifted by its staggered
        phase, contended links on a cluster process
        (``repro.obs.trace.trace_from_cluster``)."""
        from repro.obs.trace import trace_from_cluster
        return trace_from_cluster(self.to_dict(), topo=topo, **kw)


def _carve_devices(jobs: Sequence[JobSpec], topo: Topology
                   ) -> List[Tuple[int, ...]]:
    """Assign each job its accelerators: explicit ``devices`` first, then
    first-fit consecutive blocks from what remains."""
    taken: Dict[int, str] = {}
    out: List[Optional[Tuple[int, ...]]] = [None] * len(jobs)
    accel = list(topo.accelerators)
    accel_set = set(accel)
    for i, spec in enumerate(jobs):
        if spec.devices is None:
            continue
        devs = tuple(spec.devices)
        if len(devs) != spec.mesh.num_devices:
            raise ValueError(
                f"job {spec.name!r}: {len(devs)} devices for mesh "
                f"{spec.mesh.shape} ({spec.mesh.num_devices} needed)")
        bad = set(devs) - accel_set
        if bad:
            raise ValueError(f"job {spec.name!r}: non-accelerator devices "
                             f"{sorted(bad)} on {topo.name}")
        for d in devs:
            if d in taken:
                raise ValueError(
                    f"device {d} claimed by both {taken[d]!r} and "
                    f"{spec.name!r}")
            taken[d] = spec.name
        out[i] = devs
    free = [d for d in accel if d not in taken]
    for i, spec in enumerate(jobs):
        if out[i] is not None:
            continue
        n = spec.mesh.num_devices
        if n > len(free):
            raise ValueError(
                f"job {spec.name!r} needs {n} devices but only {len(free)} "
                f"of {topo.name}'s {len(accel)} remain")
        out[i] = tuple(free[:n])
        for d in out[i]:
            taken[d] = spec.name
        free = free[n:]
    return out  # type: ignore[return-value]


def _job_profile(name: str, report: CodesignReport,
                 compute_scale: float = 1.0) -> JobProfile:
    """Compress a CodesignReport into the flow scheduler's pulse model.

    The comm burst is the *exposed* communication — the stretch of the
    iteration where the network gates progress.  Overlapped plans hide
    most of ``comm_time`` under compute; using the raw busy time there
    overstated the burst, inflated apparent contention, and mis-staggered
    phases (for serial plans the two are identical).  The compute phase
    is the rest of the iteration, so the period equals the job's solo
    JCT.  ``compute_scale`` > 1 models a straggler (slowed compute, same
    burst — the ``codesign.dynamics`` event)."""
    comm_s = max(min(report.exposed_comm, report.jct), 0.0)
    compute_s = max(report.jct - comm_s, 1e-9) * compute_scale
    return JobProfile(name, compute_s, comm_s)


def plan_cluster(jobs: Sequence[JobSpec], topo: Topology,
                 cost_model: Union[str, CostModel] = "flowsim",
                 grid: int = 8, horizon_iters: int = 12,
                 dt: Optional[float] = None,
                 switch_capacity: Optional[int] = None,
                 max_contended_links: int = 8,
                 meters=None) -> ClusterReport:
    """Plan N jobs sharing one physical cluster and stagger their phases.

    ``dt`` is the flow scheduler's time step (None = 1/400 of the shortest
    job period); ``grid`` the CASSINI phase-search resolution;
    ``max_contended_links`` bounds the per-job demand maps to the hottest
    shared links so the phase search stays cheap.  ``switch_capacity``
    (ATP) is forwarded to per-job selection.  ``meters``
    (``repro.obs.meters``) counts the phase-search evaluations."""
    if not jobs:
        raise ValueError("plan_cluster needs at least one JobSpec")
    names = [s.name for s in jobs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate job names: {names}")

    device_blocks = _carve_devices(jobs, topo)
    n_links = topo.graph.number_of_edges()
    plans: List[JobPlan] = []
    for spec, devs in zip(jobs, device_blocks):
        placement = place_mesh(spec.mesh, topo, "custom", custom=devs)
        report = plan(spec.to_problem(topo, placement, cost_model,
                                      switch_capacity, hotspot_k=n_links))
        if spec.serving is not None:
            # per-batch pulse: the prefill batch graph + KV hand-off +
            # one decode step's comm, pressed whenever a batch is in
            # flight (the burst schedule comes from the arrivals)
            profile = JobProfile(spec.name,
                                 max(report.compute_time, 1e-9),
                                 max(report.comm_time, 0.0))
        else:
            profile = _job_profile(spec.name, report)
        plans.append(JobPlan(
            spec=spec, devices=devs, report=report, profile=profile,
            link_bytes=dict(report.link_hotspots)))
    model_name = plans[0].report.cost_model  # as the driver resolved it
    return _stagger_plans(plans, topo, grid=grid,
                          horizon_iters=horizon_iters, dt=dt,
                          max_contended_links=max_contended_links,
                          cost_model=model_name, meters=meters)


def _detect_contention(plans: Sequence[JobPlan], topo: Topology,
                       max_contended_links: int
                       ) -> Tuple[Dict[Tuple, Dict[str, float]],
                                  List[Dict[Tuple, float]]]:
    """Contended links (>= 2 jobs) + per-job demand fractions over them.
    Pure dict math over the plans' link-byte maps — cheap enough to rerun
    on every dynamics event."""
    contended = shared_link_load(
        {jp.spec.name: jp.link_bytes for jp in plans})
    if len(contended) > max_contended_links:
        hottest = sorted(contended,
                         key=lambda l: -sum(contended[l].values()))
        contended = {l: contended[l] for l in hottest[:max_contended_links]}
    link_demands = []
    for jp in plans:
        comm_s = max(jp.profile.comm_s, 1e-12)
        dem = {}
        for link in contended:
            nbytes = jp.link_bytes.get(link, 0.0)
            if nbytes <= 0:
                continue
            bw = topo.link_bw(*link)
            dem[link] = min(1.0, nbytes / (bw * comm_s))
        link_demands.append(dem)
    return contended, link_demands


def _stagger_plans(plans: List[JobPlan], topo: Topology, grid: int,
                   horizon_iters: int, dt: Optional[float],
                   max_contended_links: int, cost_model: str,
                   phases: Optional[Dict[str, float]] = None,
                   dirty: Optional[Sequence[str]] = None,
                   meters=None) -> ClusterReport:
    """The horizontal layer's back half: contention detection -> demand
    maps -> phase search.  With ``phases``/``dirty`` given, only the
    dirty jobs' phases are searched (the rest stay frozen — incremental
    re-planning); otherwise the full CASSINI grid runs."""
    names = [jp.spec.name for jp in plans]
    contended, link_demands = _detect_contention(plans, topo,
                                                 max_contended_links)
    profiles = [jp.profile for jp in plans]

    if not contended:
        # nothing shared: every job runs at its solo JCT, staggering no-op
        solo = {jp.spec.name: jp.profile.period for jp in plans}
        return ClusterReport(
            jobs=plans, contended={},
            phases={n: 0.0 for n in names},
            naive_jct=dict(solo), staggered_jct=dict(solo),
            cost_model=cost_model,
            link_demands={n: {} for n in names},
            serving={jp.spec.name: _solo_serving_metrics(jp.report)
                     for jp in plans if jp.spec.serving is not None})

    if any(jp.spec.serving is not None for jp in plans):
        # training/serving co-tenancy: bursts are pinned by arrivals, so
        # the phase grid only sweeps the training jobs (stagger_mixed);
        # incremental re-staggering redoes the full mixed grid — the
        # sweep is grid**n_training, already the small side
        return _stagger_mixed_plans(plans, topo, contended, link_demands,
                                    grid, horizon_iters, dt, cost_model,
                                    meters)

    if dt is None:
        dt = min(p.period for p in profiles) / 400.0
    if phases is None:
        best_phases, naive, staggered = stagger_jobs(
            profiles, grid=grid, link_demands=link_demands,
            horizon_iters=horizon_iters, dt=dt, meters=meters)
    else:
        current = [phases.get(n, 0.0) for n in names]
        dirty_set = set(names if dirty is None else dirty)
        free = [i for i, n in enumerate(names) if n in dirty_set]
        if len(free) == len(names) and len(free) > 1:
            # every phase free: a uniform shift of all phases is just a
            # time-origin change, so pin the first job as the reference
            # (as stagger_jobs does) and sweep one fewer grid dimension
            free = free[1:]
        best_phases, naive, staggered = restagger_jobs(
            profiles, current, free, grid=grid,
            link_demands=link_demands, horizon_iters=horizon_iters, dt=dt,
            meters=meters)
    return ClusterReport(
        jobs=plans, contended=contended,
        phases=dict(zip(names, best_phases)),
        naive_jct=naive, staggered_jct=staggered,
        cost_model=cost_model,
        link_demands={jp.spec.name: d
                      for jp, d in zip(plans, link_demands)})


def _solo_serving_metrics(report) -> Dict[str, float]:
    """Serving SLO numbers when co-tenancy changes nothing (no shared
    links): naive == staggered == the tenant's solo report."""
    out = {}
    for k in ("ttft_p99", "tpot_p99", "goodput", "slo_attainment"):
        v = float(getattr(report, k))
        out[f"naive_{k}"] = v
        out[f"staggered_{k}"] = v
    out["naive_burst_stretch"] = 1.0
    out["staggered_burst_stretch"] = 1.0
    return out


def _serving_bursts(jp: JobPlan) -> BurstProfile:
    """The serving tenant as the flow scheduler sees it: one comm burst
    per prefill batch (arrival order, batches of ``prefill_batch``), each
    scheduled when its last member arrives and carrying the per-batch
    comm time; FIFO chaining in the simulator models the busy server."""
    spec = jp.spec.serving
    arrivals = spec.arrivals.sample(spec.horizon_s)
    comm_s = jp.profile.comm_s
    starts = [arrivals[min(i + spec.prefill_batch, len(arrivals)) - 1].t
              for i in range(0, len(arrivals), spec.prefill_batch)]
    return BurstProfile(jp.spec.name,
                        tuple((s, comm_s) for s in starts))


def _serving_under_pulses(jp: JobPlan, topo: Topology, cost_model: str,
                          train_plans: Sequence[JobPlan],
                          train_demands: Sequence[Dict[Tuple, float]],
                          phases: Dict[str, float]):
    """Re-price one serving tenant with every training co-tenant folded
    in as a :class:`serving.CotenantPulse` at the given phases.  The
    pulse's comm window starts where the flow scheduler puts it:
    ``compute_s + phase`` into the iteration."""
    from repro.codesign.serving import CotenantPulse, serving_problem
    pulses = []
    for tjp, dem in zip(train_plans, train_demands):
        prof = tjp.profile
        if prof.comm_s <= 0 or not dem:
            continue
        ph = (prof.compute_s
              + phases.get(tjp.spec.name, 0.0)) % prof.period
        pulses.append(CotenantPulse(tjp.spec.name, prof.period,
                                    prof.comm_s, ph, dict(dem)))
    spec2 = dataclasses.replace(jp.spec.serving, cotenants=tuple(pulses))
    placement = place_mesh(jp.spec.mesh, topo, "custom", custom=jp.devices)
    prob = serving_problem(spec2, topo, cost_model=cost_model)
    space = dataclasses.replace(prob.space, placement=Fixed(placement))
    return plan(dataclasses.replace(prob, space=space))


def _stagger_mixed_plans(plans: List[JobPlan], topo: Topology,
                         contended: Dict[Tuple, Dict[str, float]],
                         link_demands: List[Dict[Tuple, float]],
                         grid: int, horizon_iters: int,
                         dt: Optional[float], cost_model: str,
                         meters=None) -> ClusterReport:
    """The co-tenancy back half: CASSINI over the training phases with
    the serving bursts pinned, then serving SLO numbers re-priced under
    the naive (zero-phase) and chosen training pulse trains."""
    names = [jp.spec.name for jp in plans]
    train = [(i, jp) for i, jp in enumerate(plans)
             if jp.spec.serving is None]
    serve = [(i, jp) for i, jp in enumerate(plans)
             if jp.spec.serving is not None]
    tprofiles = [jp.profile for _, jp in train]
    tdemands = [link_demands[i] for i, _ in train]
    bursts = [_serving_bursts(jp) for _, jp in serve]
    bdemands = [link_demands[i] for i, _ in serve]
    if dt is None:
        dt = min(jp.profile.period for jp in plans) / 400.0
    best_phases, (jct0, st0), (jct1, st1) = stagger_mixed(
        tprofiles, bursts, grid=grid, link_demands=tdemands,
        burst_demands=bdemands, horizon_iters=horizon_iters, dt=dt,
        meters=meters)
    phase_map = {jp.spec.name: ph
                 for (_, jp), ph in zip(train, best_phases)}
    naive_jct = dict(jct0)
    staggered_jct = dict(jct1)
    serving_metrics: Dict[str, Dict[str, float]] = {}
    train_plans = [jp for _, jp in train]
    for (_, jp), burst in zip(serve, bursts):
        n = jp.spec.name
        # serving "JCT" entries are per-batch pulse periods (solo); SLO
        # truth lives in the serving dict below
        naive_jct[n] = jp.profile.period
        staggered_jct[n] = jp.profile.period
        zero = {tjp.spec.name: 0.0 for tjp in train_plans}
        rep0 = _serving_under_pulses(jp, topo, cost_model, train_plans,
                                     tdemands, zero)
        rep1 = _serving_under_pulses(jp, topo, cost_model, train_plans,
                                     tdemands, phase_map)
        serving_metrics[n] = {
            "naive_burst_stretch": st0.get(n, 1.0),
            "staggered_burst_stretch": st1.get(n, 1.0),
        }
        for k in ("ttft_p99", "tpot_p99", "goodput", "slo_attainment"):
            serving_metrics[n][f"naive_{k}"] = float(getattr(rep0, k))
            serving_metrics[n][f"staggered_{k}"] = float(getattr(rep1, k))
    phases = {n: phase_map.get(n, 0.0) for n in names}
    return ClusterReport(
        jobs=plans, contended=contended, phases=phases,
        naive_jct=naive_jct, staggered_jct=staggered_jct,
        cost_model=cost_model,
        link_demands={jp.spec.name: d
                      for jp, d in zip(plans, link_demands)},
        serving=serving_metrics)


def restagger_cluster(plans: List[JobPlan], topo: Topology,
                      phases: Dict[str, float],
                      dirty: Sequence[str], grid: int = 8,
                      horizon_iters: int = 12, dt: Optional[float] = None,
                      max_contended_links: int = 8,
                      cost_model: str = "flowsim",
                      meters=None) -> ClusterReport:
    """Incrementally re-stagger a cluster plan: jobs named in ``dirty``
    get fresh phase offsets, everyone else keeps ``phases``.  This is
    the horizontal half of event-driven re-planning — contention is
    re-detected from the plans' (possibly re-routed) link maps, but the
    phase grid only sweeps the jobs whose demand actually changed, so
    the search is ``grid**len(dirty)`` instead of ``grid**(n-1)``.

    ``naive_jct`` in the returned report is the cluster at the *frozen*
    phases (the do-nothing baseline an event leaves behind), so
    ``stagger_speedup`` measures what the incremental re-stagger
    recovered."""
    if not plans:
        raise ValueError("restagger_cluster needs at least one JobPlan")
    names = {jp.spec.name for jp in plans}
    unknown = set(dirty) - names
    if unknown:
        raise ValueError(f"dirty jobs {sorted(unknown)} not in cluster "
                         f"{sorted(names)}")
    return _stagger_plans(plans, topo, grid=grid,
                          horizon_iters=horizon_iters, dt=dt,
                          max_contended_links=max_contended_links,
                          cost_model=cost_model, phases=phases,
                          dirty=dirty, meters=meters)
