"""Cross-layer co-design engine (paper Sec. II-E / IV-A).

The survey's central observation is that the three layers of the training
communication stack — parallelization strategy, collective communication
library, and network — are "relatively independent", and that *vertical
co-design* across them is the open opportunity.  This package wires them
together:

``placement``
    Maps logical mesh coordinates (``core.types.MeshConfig``) onto the
    physical accelerators of a ``net.Topology`` so every ``CommTask.group``
    names real devices.  Conventions:

    * Logical global ranks are **row-major** over ``MeshConfig.shape``
      with the **model axis innermost** (the MeshConfig default), so
      ``packed`` placement puts each TP communicator on consecutive
      physical devices — one host, on DGX/fat-tree topologies.
    * ``strided`` round-robins ranks across hosts (the anti-pattern
      baseline); ``custom`` takes an explicit rank -> device tuple.
    * The demand builder emits one *representative* communicator per mesh
      axis (all replicas run the same collective concurrently);
      ``CommTask.axis`` ("model" / "data") tells placement which axis a
      group spans, and ``replica=`` selects which concrete communicator
      stands in for it.

``driver``
    ``plan_iteration(cfg, shape, mesh, topo, policy)`` runs demand ->
    placement -> per-task algorithm selection (via ``ccl.select``'s
    CostModel protocol: closed-form ``AlphaBeta`` or topology-priced
    ``FlowSim``) -> ``sched.simulate_iteration``, and returns a
    ``CodesignReport`` with JCT, exposed communication, per-task algorithm
    choices and per-link hot spots.

Not yet integrated (see ROADMAP.md Open items): the "Horizontal" flow
scheduler (multi-job CASSINI staggering happens in ``sched.flows`` but
``plan_iteration`` plans a single job) and "Host-Net" in-network
aggregation (``sched.atp`` models it but the driver does not offer it as a
selection candidate).
"""
from repro.codesign.placement import Placement, place_mesh  # noqa: F401
from repro.codesign.driver import (CodesignReport, TaskChoice,  # noqa: F401
                                   plan_iteration)
