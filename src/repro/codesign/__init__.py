"""Cross-layer co-design engine (paper Sec. II-E / IV-A).

The survey's central observation is that the three layers of the training
communication stack — parallelization strategy, collective communication
library, and network — are "relatively independent", and that *vertical
co-design* across them is the open opportunity.  This package wires them
together behind one declarative surface:

``api``
    :class:`CodesignProblem` = model/shape/mesh/topology plus a
    :class:`PlanSpace` of typed knobs (``repro.core.knobs``): placement,
    per-primitive algorithm, codec error budget, scheduling policy,
    switch capacity — each ``Fixed(v)``, ``Choice(...)`` or
    ``Search()``.  ``plan(problem)`` prices one fully pinned point of
    the space into a :class:`CodesignReport`; ``search(problem,
    budget=N)`` walks the free knobs with one shared memoized cost model
    and returns a :class:`SearchResult` (best plan, explored frontier,
    per-knob attribution of the win).  Both reports serialize to JSON
    (``to_dict``/``from_dict``) so plans can be persisted.

``placement``
    Maps logical mesh coordinates (``core.types.MeshConfig``) onto the
    physical accelerators of a ``net.Topology`` so every ``CommTask.group``
    names real devices.  Conventions:

    * Logical global ranks are **row-major** over ``MeshConfig.shape``
      with the **model axis innermost** (the MeshConfig default), so
      ``packed`` placement puts each TP communicator on consecutive
      physical devices — one host, on DGX/fat-tree topologies.
    * ``strided`` round-robins ranks across hosts (the anti-pattern
      baseline); ``custom`` takes an explicit rank -> device tuple.
    * The demand builder emits one *representative* communicator per mesh
      axis (all replicas run the same collective concurrently);
      ``CommTask.axis`` ("model" / "data") tells placement which axis a
      group spans, and ``replica=`` selects which concrete communicator
      stands in for it.

``placement_search``
    The ROADMAP's TopoOpt-style optimizer behind ``placement=Search()``:
    deterministic heuristic candidates (packed, host-balanced, strided,
    axis permutations) plus a hot-spot-guided swap-neighborhood hill
    climb.  The host-balanced family is the headline: where ``packed``
    straddles a host boundary unevenly (TP-12 over 8-GPU hosts = 8+4),
    the even split restores the equal-size partition the hierarchical
    decomposition needs, and search finds it.

``driver``
    The legacy keyword surface: ``plan_iteration(cfg, shape, mesh, topo,
    ...)`` is an exact kwarg-for-kwarg adapter over
    ``plan(CodesignProblem.from_kwargs(...))``.

``cluster``
    The "Horizontal" arrow: ``plan_cluster(jobs, topo)`` runs every
    tenant's pinned problem (``JobSpec`` either carries a
    ``CodesignProblem`` or the legacy flat fields) through ``plan``,
    asks the network layer which links carry >= 2 jobs' traffic,
    compresses each job into a ``sched.flows`` ``JobProfile`` and
    CASSINI-staggers their iteration phases, returning a
    ``ClusterReport`` (naive vs. staggered per-job JCT, contended links,
    chosen phases).

``serving``
    The inference half of the story: ``ServingSpec`` (model +
    prefill/decode disaggregation + SLO + an open-loop
    ``sched.arrivals`` process) turns ``CodesignProblem`` into a
    serving problem.  ``plan_serving`` prices the prefill batch graph,
    the per-rank KV-cache ``p2p`` hand-off, and the one-token decode
    step through the same CCL/network layers, then replays the arrival
    process through a deterministic queueing simulation with co-tenant
    training pulses contending on shared links.  ``ServingReport``
    speaks TTFT/TPOT percentiles + goodput, registered in the shared
    objective metric registry, so ``search()`` over a ``stagger`` or
    ``placement`` knob returns SLO-feasible serving plans.

``dynamics``
    The cluster as a moving target: ``ClusterDynamics`` consumes a trace
    of ``Event``s (job arrival/departure, link failure/degradation, host
    failure, stragglers) over degradation views of the topology and
    re-plans *incrementally* — vertical re-plans only for jobs whose
    routes the event touched, phase re-search only over the dirty jobs
    (``restagger_cluster``), full ``plan_cluster`` re-search as the
    infeasibility fallback.  Warm-starts from a persisted
    ``ClusterReport``; ``DynamicsReport`` records per-event
    time-to-replan and regret vs. a full re-search.

"Host-Net" in-network aggregation is a first-class selection candidate:
``sched.atp`` exposes the aggregation capability (with the multi-tenant
switch-memory fallback) and both cost models price the ``atp`` all-reduce
against ``hierarchical`` and friends on switched topologies.

So is gradient compression (``repro.compress``): an ``error_budget``
knob admits lossy candidates (``ring+q8``, ``ps+topk``, ...) into
per-task selection — a float for every task or a primitive -> budget
dict — and the ``CodesignReport`` surfaces the chosen codecs
(``codecs_by_primitive``) and the on-wire bytes saved
(``wire_bytes_saved``).  ``JobSpec`` carries the same knob through
``plan_cluster``, where smaller per-tenant flows shrink what the
horizontal layer must stagger.
"""
from repro.core.knobs import Choice, Fixed, Knob, Search  # noqa: F401

from repro.codesign.placement import Placement, place_mesh  # noqa: F401
from repro.codesign.report import CodesignReport, TaskChoice  # noqa: F401
from repro.codesign.api import (Candidate, CodesignProblem,  # noqa: F401
                                Objective, PlanSpace, SearchResult,
                                plan, search)
from repro.codesign.serving import (CotenantPulse, ServingReport,  # noqa: F401
                                    ServingSLO, ServingSpec,
                                    kv_bytes_per_token, plan_serving,
                                    serving_problem)
from repro.codesign.placement_search import (  # noqa: F401
    balanced_placement, heuristic_placements, swap_neighbors)
from repro.codesign.driver import plan_iteration  # noqa: F401
from repro.codesign.cluster import (ClusterReport, JobPlan,  # noqa: F401
                                    JobSpec, plan_cluster,
                                    restagger_cluster)
from repro.codesign.dynamics import (ClusterDynamics,  # noqa: F401
                                     DynamicsReport, Event, EventRecord)
