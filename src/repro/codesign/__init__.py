"""Cross-layer co-design engine (paper Sec. II-E / IV-A).

The survey's central observation is that the three layers of the training
communication stack — parallelization strategy, collective communication
library, and network — are "relatively independent", and that *vertical
co-design* across them is the open opportunity.  This package wires them
together:

``placement``
    Maps logical mesh coordinates (``core.types.MeshConfig``) onto the
    physical accelerators of a ``net.Topology`` so every ``CommTask.group``
    names real devices.  Conventions:

    * Logical global ranks are **row-major** over ``MeshConfig.shape``
      with the **model axis innermost** (the MeshConfig default), so
      ``packed`` placement puts each TP communicator on consecutive
      physical devices — one host, on DGX/fat-tree topologies.
    * ``strided`` round-robins ranks across hosts (the anti-pattern
      baseline); ``custom`` takes an explicit rank -> device tuple.
    * The demand builder emits one *representative* communicator per mesh
      axis (all replicas run the same collective concurrently);
      ``CommTask.axis`` ("model" / "data") tells placement which axis a
      group spans, and ``replica=`` selects which concrete communicator
      stands in for it.

``driver``
    ``plan_iteration(cfg, shape, mesh, topo, policy)`` runs demand ->
    placement -> per-task algorithm selection (via ``ccl.select``'s
    CostModel protocol: closed-form ``AlphaBeta`` or topology-priced
    ``FlowSim``) -> ``sched.simulate_iteration``, and returns a
    ``CodesignReport`` with JCT, exposed communication, per-task algorithm
    choices and per-link hot spots.

``cluster``
    The "Horizontal" arrow: ``plan_cluster(jobs, topo)`` runs every
    tenant's ``plan_iteration``, asks the network layer which links carry
    >= 2 jobs' traffic, compresses each job into a ``sched.flows``
    ``JobProfile`` and CASSINI-staggers their iteration phases, returning a
    ``ClusterReport`` (naive vs. staggered per-job JCT, contended links,
    chosen phases).

"Host-Net" in-network aggregation is a first-class selection candidate:
``sched.atp`` exposes the aggregation capability (with the multi-tenant
switch-memory fallback) and both cost models price the ``atp`` all-reduce
against ``hierarchical`` and friends on switched topologies.

So is gradient compression (``repro.compress``):
``plan_iteration(error_budget=...)`` admits lossy candidates
(``ring+q8``, ``ps+topk``, ...) into per-task selection — a float for
every task or a primitive -> budget dict — and the ``CodesignReport``
surfaces the chosen codecs (``codecs_by_primitive``) and the on-wire
bytes saved (``wire_bytes_saved``).  ``JobSpec.error_budget`` carries the
same knob through ``plan_cluster``, where smaller per-tenant flows shrink
what the horizontal layer must stagger.
"""
from repro.codesign.placement import Placement, place_mesh  # noqa: F401
from repro.codesign.driver import (CodesignReport, TaskChoice,  # noqa: F401
                                   plan_iteration)
from repro.codesign.cluster import (ClusterReport, JobPlan,  # noqa: F401
                                    JobSpec, plan_cluster)
