"""Placement layer: logical mesh coordinates -> physical accelerators.

The seed's ``core.demand_builder`` emits logical rank groups
(``range(tp)`` / ``range(dp)``) that never touched a ``net.Topology``;
here we close that gap.  A :class:`Placement` is a bijection from logical
global ranks (row-major over ``MeshConfig.shape``) to physical device ids
of a topology, so every ``CommTask.group`` can be resolved to real devices
before the CCL layer prices algorithms on real links.

Strategies:
  * ``packed``  — logical rank r -> r-th accelerator.  With the model axis
    innermost (the MeshConfig convention) TP groups land on consecutive
    devices, i.e. inside one host on DGX/fat-tree topologies.
  * ``strided`` — round-robin across hosts: consecutive logical ranks land
    on different hosts.  The anti-pattern baseline that scatters TP groups
    over the NIC tier (what topology-oblivious placement can do to you).
  * ``custom``  — caller-provided rank -> device tuple.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.demand import CommDemand, CommTask
from repro.core.types import MeshConfig
from repro.net.topology import Topology


@dataclass(frozen=True)
class Placement:
    """Maps logical global ranks onto physical device ids."""

    mesh: MeshConfig
    devices: Tuple[int, ...]  # logical rank (row-major) -> physical device
    strategy: str = "packed"
    topology: str = "custom"

    def __post_init__(self):
        if len(self.devices) != self.mesh.num_devices:
            raise ValueError(
                f"placement covers {len(self.devices)} devices but mesh "
                f"{self.mesh.shape} has {self.mesh.num_devices}")
        if len(set(self.devices)) != len(self.devices):
            raise ValueError("placement maps two logical ranks to the same "
                             "physical device")

    # ------------------------------------------------------------------
    def device(self, rank: int) -> int:
        return self.devices[rank]

    def _axis_groups(self, axes: Sequence[str]) -> List[Tuple[int, ...]]:
        """Physical-device groups of the communicators spanning ``axes``
        (one group per assignment of the remaining axes)."""
        mesh = self.mesh
        idx = [mesh.axis_names.index(a) for a in axes]
        other = [i for i in range(len(mesh.shape)) if i not in idx]
        groups: List[Tuple[int, ...]] = []
        for fixed in itertools.product(*[range(mesh.shape[i])
                                         for i in other]):
            members: List[int] = []
            for var in itertools.product(*[range(mesh.shape[i])
                                           for i in idx]):
                coord = [0] * len(mesh.shape)
                for i, v in zip(other, fixed):
                    coord[i] = v
                for i, v in zip(idx, var):
                    coord[i] = v
                rank = 0
                for dim, c in zip(mesh.shape, coord):
                    rank = rank * dim + c
                members.append(self.devices[rank])
            groups.append(tuple(members))
        return groups

    def model_groups(self) -> List[Tuple[int, ...]]:
        """TP communicators (one per data-parallel replica)."""
        return self._axis_groups(self.mesh.model_axes)

    def data_groups(self) -> List[Tuple[int, ...]]:
        """DP communicators (one per model shard)."""
        return self._axis_groups(self.mesh.data_axes)

    # ------------------------------------------------------------------
    def place_group(self, group: Sequence[int],
                    axis: Optional[str] = None,
                    replica: int = 0) -> Tuple[int, ...]:
        """Resolve a logical group to physical devices.

        ``axis`` (from ``CommTask.axis``) disambiguates: "model"/"data"
        pick the ``replica``-th communicator along those mesh axes (the
        demand builder emits one representative group per axis — all
        replicas run the same collective concurrently).  Without an axis
        tag we fall back to size inference, then to rank-wise mapping."""
        p = len(group)
        if axis == "model" or (axis is None and p == self.mesh.tp
                               and p != self.mesh.num_devices):
            cands = self.model_groups()
        elif axis == "data" or (axis is None and p == self.mesh.dp
                                and p != self.mesh.num_devices):
            cands = self.data_groups()
        elif axis in ("all", None) and p == self.mesh.num_devices:
            return tuple(self.devices)
        else:
            cands = None
        if cands is not None:
            g = cands[replica % len(cands)]
            if len(g) != p:
                raise ValueError(
                    f"group of {p} does not match the {axis!r}-axis "
                    f"communicator size {len(g)} of mesh {self.mesh.shape}")
            return g
        if max(group) >= self.mesh.num_devices:
            raise ValueError(
                f"cannot place group {group!r}: ranks exceed mesh size "
                f"{self.mesh.num_devices} and no axis tag was given")
        return tuple(self.devices[r] for r in group)

    def place_task(self, task: CommTask, replica: int = 0) -> CommTask:
        return dataclasses.replace(
            task, group=self.place_group(task.group, task.axis, replica))

    def place_demand(self, demand: CommDemand, replica: int = 0
                     ) -> CommDemand:
        """New CommDemand with every comm task's group resolved to physical
        device ids (compute tasks are device-agnostic and pass through)."""
        placed = CommDemand(comm_tasks=[self.place_task(t, replica)
                                        for t in demand.comm_tasks],
                            compute_tasks=list(demand.compute_tasks),
                            job_id=demand.job_id)
        return placed


def place_mesh(mesh: MeshConfig, topo: Topology, strategy: str = "packed",
               custom: Optional[Sequence[int]] = None) -> Placement:
    """Build a Placement of ``mesh`` onto ``topo``'s accelerators."""
    n = mesh.num_devices
    accel = topo.accelerators
    if n > len(accel):
        raise ValueError(f"mesh {mesh.shape} needs {n} devices but "
                         f"{topo.name} has {len(accel)}")
    if strategy == "custom":
        if custom is None:
            raise ValueError("strategy='custom' requires custom=<devices>")
        devices = tuple(custom)
        bad = set(devices) - set(accel)
        if bad:
            raise ValueError(f"custom placement uses non-accelerator "
                             f"devices {sorted(bad)} on {topo.name}")
    elif strategy == "packed":
        devices = tuple(accel[:n])
    elif strategy == "strided":
        if topo.hosts:
            # round-robin over hosts: rank r -> host r % H
            order = [h for hosts in itertools.zip_longest(*topo.hosts)
                     for h in hosts if h is not None]
        else:
            # hostless fabric: interleave with a stride of the innermost
            # (model) axis size so that communicator is spread apart
            stride = max(1, mesh.shape[-1])
            order = [accel[off + k] for off in range(stride)
                     for k in range(0, len(accel) - off, stride)]
        devices = tuple(order[:n])
    else:
        raise ValueError(f"unknown placement strategy {strategy!r} "
                         f"(packed | strided | custom)")
    return Placement(mesh=mesh, devices=devices, strategy=strategy,
                     topology=topo.name)
