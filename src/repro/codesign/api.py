"""Declarative co-design problems over one searchable plan space.

Three PRs of cross-layer knobs (placement, per-primitive algorithm,
codec/error budget, scheduling policy, switch capacity) grew into an
11-parameter keyword pile on ``plan_iteration``.  The paper's Sec. IV-A
point is that these are *one* joint design space to be searched, not a
flat argument list — so this module makes the space first-class:

``CodesignProblem``
    model/shape/mesh/topology plus a :class:`PlanSpace` of typed knobs
    (``repro.core.knobs``): each knob is ``Fixed(v)`` (pinned),
    ``Choice(...)`` (finite candidates) or ``Search()`` (candidates come
    from an optimizer).  An :class:`Objective` says what to minimize and
    what constrains feasibility.

``plan(problem)``
    all scalar knobs pinned -> one :class:`CodesignReport` (exactly the
    legacy ``plan_iteration`` behaviour; that function is now a thin
    kwarg adapter over this).

``search(problem, budget=N)``
    walks the free knobs — enumerating ``Choice`` options, generating
    placement candidates via ``codesign.placement_search`` (heuristics +
    a hot-spot-guided swap-neighborhood hill climb) for
    ``placement=Search()`` — pricing every candidate with one shared
    memoized cost model, and returns a :class:`SearchResult`: the best
    plan, the explored frontier, and a per-knob attribution of the win.

Per-primitive ``algorithm`` knobs are *constraints*, not enumeration
axes: the CCL selection layer is already a search over algorithms priced
by the same cost model, so ``Fixed`` forces, ``Choice`` whitelists and
``Search`` opens the registry (``ccl.select.select_for_task`` reads the
knob directly).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple, Union

from repro.ccl.select import (AlphaBeta, CostModel, FlowSim, Selection,
                              constraint_from_allow, flows_on_topology,
                              select_for_task)
from repro.ccl.synth import (DEFAULT_SYNTH_CACHE, SYNTHESIZABLE, Sketch,
                             sketch_from_hotspots)
from repro.compress.codec import base_algorithm, codec_spec, split_algorithm
from repro.core.demand_builder import (DECOMPOSABLE_PRIMITIVES, DemandParams,
                                       build_demand, decompose_demand)
from repro.core.knobs import Choice, Fixed, Knob, Search, as_knob, is_free
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig
from repro.net.simulate import link_utilization
from repro.net.topology import Topology
from repro.sched.atp import aggregation_switches
from repro.sched.tasks import Policy, simulate_iteration

from repro.codesign.placement import Placement, place_mesh
from repro.codesign.report import (OBJECTIVE_METRICS, CodesignReport,
                                   TaskChoice, _placement_from_dict,
                                   _placement_to_dict, metric_value)

# the scalar knobs plan() needs pinned and search() may enumerate
# (per-primitive algorithm knobs are selection constraints instead).
# ``stagger`` only matters for serving problems (the co-tenant phase
# offset in seconds); training plans ignore it.
SCALAR_KNOBS = ("placement", "policy", "error_budget", "switch_capacity",
                "bucket_bytes", "decompose", "stagger", "synthesize")


@dataclass(frozen=True)
class PlanSpace:
    """The typed cross-layer design space of one job.

    ``algorithm`` maps a primitive (``"all_reduce"``, ...) to its knob;
    the ``"*"`` key applies to unlisted primitives.  ``Fixed(name)``
    forces (bypassing the error-budget gate, like the legacy single-name
    ``allow``), ``Choice(...)`` whitelists, ``Search()``/absent opens
    the full registry.  ``error_budget`` values may be a float or a
    primitive -> budget dict (the legacy shapes, verbatim).

    The two overlap knobs reshape the demand DAG itself:
    ``bucket_bytes`` (None = legacy per-layer gradient sync; an int =
    fused buckets of that size chained off the backward layer that
    filled them; ``Search()`` generates a geometric ladder from the
    total gradient bytes) and ``decompose`` (False = bulk TP
    collectives; True = rewrite them into collective-matmul ring
    permutes riding under split partial matmuls; a tuple of primitive
    names decomposes just those)."""

    placement: Knob = Fixed("packed")
    algorithm: Mapping[str, Knob] = field(default_factory=dict)
    error_budget: Knob = Fixed(0.0)
    policy: Knob = Fixed("priority")
    switch_capacity: Knob = Fixed(None)
    bucket_bytes: Knob = Fixed(None)
    decompose: Knob = Fixed(False)
    # serving problems only: phase offset (seconds) of this tenant's
    # admission clock against the co-tenant training pulses sharing its
    # fabric — the CASSINI stagger lever, per-tenant.  ``Search()``
    # generates a grid over the co-tenant period.
    stagger: Knob = Fixed(0.0)
    # SCCL/TACCL-style collective synthesis as a plan-space lever: False =
    # registered algorithms only; True = synthesize topology-specific
    # schedules (sketch-guided by this plan's hot-spot map) for the 2
    # hottest synthesizable selection keys and let them compete in
    # ``ccl.select`` under the active cost model; an int raises the
    # top-k.  ``Search()`` walks [False, True] jointly with the other
    # knobs.
    synthesize: Knob = Fixed(False)

    def scalar_knobs(self) -> Dict[str, Knob]:
        return {name: getattr(self, name) for name in SCALAR_KNOBS}

    def free_knobs(self) -> Dict[str, Knob]:
        """The knobs ``search()`` walks (Fixed ones are pinned)."""
        return {n: k for n, k in self.scalar_knobs().items() if is_free(k)}

    def constraint_for(self, primitive: str) -> Optional[Knob]:
        """The algorithm knob the selection layer sees for ``primitive``."""
        knob = self.algorithm.get(primitive)
        return knob if knob is not None else self.algorithm.get("*")

    def pinned(self, **values) -> "PlanSpace":
        """A copy with the named scalar knobs replaced: raw values are
        pinned (wrapped in ``Fixed``), Knob instances are taken as-is —
        so ``pinned(placement=Search())`` re-opens a knob instead of
        nesting it inside a Fixed."""
        for name in values:
            if name not in SCALAR_KNOBS:
                raise ValueError(f"unknown scalar knob {name!r} "
                                 f"(one of {SCALAR_KNOBS})")
        return dataclasses.replace(
            self, **{n: as_knob(v) for n, v in values.items()})


@dataclass(frozen=True)
class Objective:
    """What 'best' means.  ``minimize``/``tie_break`` name metrics from
    the shared registry (``codesign.report.OBJECTIVE_METRICS``) —
    training metrics (``jct``, ``exposed_comm``, ...) and serving
    metrics (``ttft_p99``, ``goodput``, ... registered by
    ``codesign.serving``) share one namespace, so an unknown name fails
    here with the full valid set instead of deep inside ``key()``.
    Bigger-is-better metrics (``wire_bytes_saved``, ``goodput``) are
    negated internally, so naming one always rewards more of it.

    ``constraints`` maps metric names to feasibility bounds: an *upper*
    bound for minimized metrics, a *lower* bound for maximized ones
    (``{"ttft_p99": 0.5, "goodput": 3.0}`` = p99 TTFT within 500 ms AND
    at least 3 req/s of goodput).  ``max_worst_link_bytes`` is the
    legacy spelling of ``constraints={"worst_link_bytes": ...}`` and is
    folded in."""

    minimize: str = "jct"
    tie_break: Tuple[str, ...] = ("exposed_comm", "worst_link_bytes")
    max_worst_link_bytes: Optional[float] = None
    constraints: Mapping[str, float] = field(default_factory=dict)

    # legacy class attrs, kept importable (the registry is the source of
    # truth; serving extends it at import)
    METRICS = ("jct", "exposed_comm", "comm_time", "compute_time",
               "worst_link_bytes", "wire_bytes_saved")
    _MAXIMIZED = ("wire_bytes_saved",)

    def __post_init__(self):
        merged = dict(self.constraints)
        if self.max_worst_link_bytes is not None:
            merged.setdefault("worst_link_bytes", self.max_worst_link_bytes)
        object.__setattr__(self, "constraints", merged)
        for m in (self.minimize, *self.tie_break, *merged):
            if m not in OBJECTIVE_METRICS:
                raise ValueError(
                    f"unknown objective metric {m!r}; valid metrics: "
                    f"{sorted(OBJECTIVE_METRICS)}")

    def key(self, report) -> Tuple[float, ...]:
        """Lexicographic minimization key."""
        return tuple(-metric_value(report, m) if OBJECTIVE_METRICS[m]
                     else metric_value(report, m)
                     for m in (self.minimize, *self.tie_break))

    def infeasible_reason(self, report) -> Optional[str]:
        """Why ``report`` violates the constraints (None = feasible).
        Checked in sorted-metric order so the reported reason is
        deterministic when several constraints fail."""
        for m in sorted(self.constraints):
            bound = self.constraints[m]
            v = metric_value(report, m)
            if OBJECTIVE_METRICS[m]:
                if v < bound:
                    return f"{m} {v:.6g} < required {bound:.6g}"
            elif v > bound:
                return f"{m} {v:.6g} > limit {bound:.6g}"
        return None

    def feasible(self, report) -> bool:
        return self.infeasible_reason(report) is None


@dataclass(frozen=True)
class CodesignProblem:
    """One job's co-design problem: what to train, where, and which
    knobs of the cross-layer space are open."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig
    topo: Topology
    space: PlanSpace = field(default_factory=PlanSpace)
    objective: Objective = field(default_factory=Objective)
    cost_model: Union[str, CostModel] = "flowsim"
    dp_params: Optional[DemandParams] = None
    hotspot_k: int = 8
    # serving problems: a ``codesign.serving.ServingSpec`` makes this an
    # inference workload — ``plan()`` dispatches to ``plan_serving`` and
    # the objective speaks SLO metrics (ttft_p99, goodput, ...) instead
    # of JCT.  ``serving_problem(...)`` is the ergonomic constructor.
    serving: Optional[object] = None

    @classmethod
    def from_kwargs(cls, cfg: ModelConfig, shape: ShapeConfig,
                    mesh: MeshConfig, topo: Topology,
                    policy: Policy = "priority",
                    placement: Union[str, Placement] = "packed",
                    cost_model: Union[str, CostModel] = "flowsim",
                    dp_params: Optional[DemandParams] = None,
                    allow: Optional[Tuple[str, ...]] = None,
                    force: Optional[Dict[str, str]] = None,
                    hotspot_k: int = 8,
                    switch_capacity: Optional[int] = None,
                    error_budget: Union[float, Dict[str, float]] = 0.0,
                    bucket_bytes: Optional[int] = None,
                    decompose: Union[bool, Tuple[str, ...]] = False
                    ) -> "CodesignProblem":
        """The legacy ``plan_iteration`` keyword surface as a problem:
        ``force`` entries become per-primitive ``Fixed`` knobs, ``allow``
        the ``"*"`` wildcard (one name -> ``Fixed`` = forced, several ->
        ``Choice`` = whitelist), everything else a pinned scalar knob."""
        algorithm: Dict[str, Knob] = {}
        if force:
            algorithm.update({p: Fixed(a) for p, a in force.items()})
        if allow:  # empty allow always behaved like None: full registry
            algorithm["*"] = constraint_from_allow(tuple(allow))
        space = PlanSpace(
            placement=Fixed(placement), algorithm=algorithm,
            error_budget=Fixed(error_budget), policy=Fixed(policy),
            switch_capacity=Fixed(switch_capacity),
            bucket_bytes=Fixed(bucket_bytes), decompose=Fixed(decompose))
        return cls(cfg=cfg, shape=shape, mesh=mesh, topo=topo, space=space,
                   cost_model=cost_model, dp_params=dp_params,
                   hotspot_k=hotspot_k)

    def pinned(self, **values) -> "CodesignProblem":
        """A copy with the named scalar knobs pinned (see PlanSpace)."""
        return dataclasses.replace(self, space=self.space.pinned(**values))

    def is_fully_specified(self) -> bool:
        return not self.space.free_knobs()


# ---------------------------------------------------------------------------
# Cost-model resolution
# ---------------------------------------------------------------------------


def _model_capacity(model: CostModel) -> Optional[int]:
    """The in-network aggregation budget a cost model prices ``atp`` with
    (None = unlimited): FlowSim carries ``switch_capacity``, AlphaBeta
    ``params.atp_capacity``."""
    cap = getattr(model, "switch_capacity", None)
    if cap is None:
        cap = getattr(getattr(model, "params", None), "atp_capacity", None)
    return cap


def _resolve_cost_model(cost_model: Union[str, CostModel], topo: Topology,
                        switch_capacity: Optional[int] = None
                        ) -> Tuple[CostModel, str]:
    if not isinstance(cost_model, str):
        if switch_capacity is not None and \
                _model_capacity(cost_model) != switch_capacity:
            raise ValueError(
                "switch_capacity applies to the named cost models "
                "('flowsim' | 'alphabeta'); a CostModel instance must "
                "carry its own aggregation budget (e.g. "
                "FlowSim(topo, switch_capacity=...) or "
                "CostParams(atp_capacity=...))")
        return cost_model, type(cost_model).__name__.lower()
    if cost_model == "flowsim":
        return FlowSim(topo, switch_capacity=switch_capacity), "flowsim"
    if cost_model == "alphabeta":
        ab = AlphaBeta.from_topology(topo)
        if switch_capacity is not None:
            ab = dataclasses.replace(ab, params=dataclasses.replace(
                ab.params, atp_capacity=switch_capacity))
        return ab, "alphabeta"
    raise ValueError(f"unknown cost model {cost_model!r} "
                     f"(flowsim | alphabeta | a CostModel instance)")


# ---------------------------------------------------------------------------
# plan(): all scalar knobs pinned -> one CodesignReport
# ---------------------------------------------------------------------------


def plan(problem: CodesignProblem,
         _resolved: Optional[Tuple[CostModel, str]] = None
         ) -> CodesignReport:
    """Run one training iteration through the full co-design pipeline:

      Para.   build_demand(cfg, shape, mesh)          logical CommDemand
      Place.  place_mesh(mesh, topo).place_demand()   physical groups
      CCL     select_for_task(task, CostModel)        per-task algorithm
      Net.    FlowSim prices candidates on the real topology
      Sched.  simulate_iteration(...)                 JCT + exposed comm

    Every scalar knob of ``problem.space`` must be ``Fixed`` — free
    knobs are ``search()``'s job.  ``_resolved`` lets the search loop
    share one memoized cost model across candidates.

    Serving problems (``problem.serving`` set) dispatch to
    ``codesign.serving.plan_serving``: same knob discipline, but the
    report speaks TTFT/TPOT/goodput under the arrival process."""
    space = problem.space
    free = space.free_knobs()
    if free:
        raise ValueError(
            f"plan() needs every scalar knob Fixed, but "
            f"{sorted(free)} are free ({free}) — use search(problem) "
            f"to walk them")
    if problem.serving is not None:
        from repro.codesign.serving import plan_serving
        return plan_serving(problem, _resolved=_resolved)
    topo = problem.topo
    placement = space.placement.value
    policy: Policy = space.policy.value
    error_budget = space.error_budget.value
    switch_capacity = space.switch_capacity.value
    bucket_bytes = space.bucket_bytes.value
    decompose = space.decompose.value

    pl = placement if isinstance(placement, Placement) else \
        place_mesh(problem.mesh, topo, strategy=placement)
    model, model_name = _resolved if _resolved is not None else \
        _resolve_cost_model(problem.cost_model, topo, switch_capacity)
    # the aggregation budget selection actually priced atp with — an
    # instance cost model carries its own; the hot-spot map must match it
    agg_capacity = switch_capacity if switch_capacity is not None \
        else _model_capacity(model)

    demand = build_demand(problem.cfg, problem.shape, problem.mesh,
                          problem.dp_params, bucket_bytes=bucket_bytes)
    if decompose:
        # rewrite TP collectives into collective-matmul ring permutes
        # BEFORE placement, so axis-tagged replica accounting still works
        prims = DECOMPOSABLE_PRIMITIVES if decompose is True \
            else tuple(decompose)
        demand = decompose_demand(demand, primitives=prims)
    placed = pl.place_demand(demand)

    def budget_of(primitive: str) -> float:
        if isinstance(error_budget, dict):
            return error_budget.get(primitive, 0.0)
        return error_budget

    # Per-task selection, memoized on the selection key — a 40-layer demand
    # repeats a handful of unique (primitive, size, group) combinations.
    sel_memo: Dict[Tuple, Selection] = {}
    choices: Dict[str, TaskChoice] = {}
    for task in placed.comm_tasks:
        key = (task.primitive, task.size_bytes, task.group)
        sel = sel_memo.get(key)
        if sel is None:
            sel = select_for_task(
                task, model, constraint=space.constraint_for(task.primitive),
                error_budget=budget_of(task.primitive))
            sel_memo[key] = sel
        _, codec = split_algorithm(sel.algorithm)
        choices[task.task_id] = TaskChoice(
            task.task_id, task.primitive, task.size_bytes, task.group,
            sel.algorithm, sel.cost, sel.costs, codec=codec,
            wire_ratio=codec_spec(codec).wire_ratio if codec else 1.0)

    def comm_cost(task):
        c = choices[task.task_id]
        return c.cost_s, c.algorithm

    sim = simulate_iteration(placed, comm_cost, policy)

    # Hot-spot map.  The JCT simulation above prices one *representative*
    # communicator per task (all replicas along an axis run the same
    # collective concurrently), but the per-link byte map must cover every
    # replica or whole hosts would look idle.  Flowsets are memoized on the
    # same (primitive, algorithm, size, group) key selection dedups on.
    def replicas_of(task):
        if task.axis == "model":
            return len(pl.model_groups())
        if task.axis == "data":
            return len(pl.data_groups())
        return 1

    def traffic_map(sketch_by_key=None) -> Tuple[Dict[Tuple, float], float]:
        """Per-link byte map + compression wire-byte savings over every
        replica of every task, under the current ``choices``.
        ``sketch_by_key`` maps a selection key (primitive, size, placed
        group) to the sketch its winning schedule was synthesized under
        (None = unbiased) so the second pass replays the schedules that
        actually won, replicas included."""
        util: Dict[Tuple, float] = {}
        fs_memo: Dict[Tuple, object] = {}
        bytes_saved = 0.0
        for ltask, ptask in zip(demand.comm_tasks, placed.comm_tasks):
            choice = choices[ptask.task_id]
            algo = choice.algorithm
            for r in range(replicas_of(ltask)):
                group = ptask.group if r == 0 else \
                    pl.place_group(ltask.group, ltask.axis, replica=r)
                key = (ltask.primitive, algo, ltask.size_bytes, group)
                fs = fs_memo.get(key)
                if fs is None:
                    replica = dataclasses.replace(ptask, group=group)
                    try:
                        if base_algorithm(algo) == "synthesized":
                            sk = (sketch_by_key or {}).get(
                                (ltask.primitive, ltask.size_bytes,
                                 ptask.group))
                            fs = DEFAULT_SYNTH_CACHE.schedule(
                                topo, replica, sk).to_flowset(
                                    wire_ratio=choice.wire_ratio,
                                    algorithm=algo)
                        else:
                            fs = flows_on_topology(topo, replica, algo)
                    except (ValueError, KeyError):
                        # replica-r's group can be shaped differently from
                        # the representative's (irregular placement); skip
                        # rather than mis-attribute its bytes
                        continue
                    fs_memo[key] = fs
                agg = aggregation_switches(topo, group, agg_capacity) \
                    if base_algorithm(algo) == "atp" else None
                for link, nbytes in link_utilization(topo, fs, agg).items():
                    util[link] = util.get(link, 0.0) + nbytes
                if choice.codec:
                    # vs the same schedule uncompressed (the wire-byte win
                    # the compression layer hands the network layer)
                    bytes_saved += fs.bytes_on_wire() \
                        * (1.0 / choice.wire_ratio - 1.0)
        return util, bytes_saved

    util, bytes_saved = traffic_map()

    # Second pass — the synthesis lever (paper Sec. III-B, SCCL/TACCL):
    # rank selection keys by exposed seconds, synthesize sketch-guided
    # schedules for the hottest ones (the sketch's link penalties are
    # THIS plan's hot-spot map, steering chunks off contended uplinks),
    # and let them compete as priced candidates.  Wins re-simulate.
    synthesize = space.synthesize.value
    if synthesize:
        topk = 2 if synthesize is True else int(synthesize)
        sketch = sketch_from_hotspots(topo, util)
        exposure: Dict[Tuple, float] = {}
        rep: Dict[Tuple, object] = {}
        for task in placed.comm_tasks:
            if task.primitive not in SYNTHESIZABLE or len(task.group) < 2:
                continue
            key = (task.primitive, task.size_bytes, task.group)
            exposure[key] = exposure.get(key, 0.0) \
                + sim.task_exposed_s.get(task.task_id, 0.0)
            rep.setdefault(key, task)
        changed = False
        won_sketch: Dict[Tuple, Optional[Sketch]] = {}
        pricer = getattr(model, "cost_flowset", None)
        for key in sorted(exposure, key=lambda k: -exposure[k])[:topk]:
            task = rep[key]
            budget = budget_of(task.primitive)
            # the hot-spot map includes THIS task's own first-pass traffic
            # (the very bytes a win would reroute), so the sketch is a
            # bias, not a mandate: the sketched and unbiased schedules
            # both compete and the active cost model keeps the cheaper
            sched = DEFAULT_SYNTH_CACHE.schedule(topo, task, sketch)
            plain = DEFAULT_SYNTH_CACHE.schedule(topo, task, None)
            won_sketch[key] = sketch
            if sched is not plain and pricer is not None:
                if pricer(task, plain.to_flowset(job_id=task.job_id),
                          algorithm="synthesized") \
                        <= pricer(task, sched.to_flowset(job_id=task.job_id),
                                  algorithm="synthesized"):
                    sched, won_sketch[key] = plain, None
            extras = {"synthesized": sched.to_flowset(job_id=task.job_id)}
            q8 = codec_spec("q8")
            if q8.effective_error <= budget:
                extras["synthesized+q8"] = sched.to_flowset(
                    job_id=task.job_id, wire_ratio=q8.wire_ratio,
                    algorithm="synthesized+q8")
            sel = select_for_task(
                task, model, constraint=space.constraint_for(task.primitive),
                error_budget=budget, extra_flowsets=extras)
            sel_memo[key] = sel
            for t in placed.comm_tasks:
                if (t.primitive, t.size_bytes, t.group) != key:
                    continue
                if choices[t.task_id].algorithm != sel.algorithm:
                    changed = True
                _, codec = split_algorithm(sel.algorithm)
                choices[t.task_id] = TaskChoice(
                    t.task_id, t.primitive, t.size_bytes, t.group,
                    sel.algorithm, sel.cost, sel.costs, codec=codec,
                    wire_ratio=codec_spec(codec).wire_ratio if codec
                    else 1.0)
        if changed:
            sim = simulate_iteration(placed, comm_cost, policy)
            util, bytes_saved = traffic_map(won_sketch)
    hotspots = sorted(util.items(), key=lambda kv: -kv[1])[:problem.hotspot_k]

    return CodesignReport(
        jct=sim.jct, exposed_comm=sim.exposed_comm,
        compute_time=sim.compute_time, comm_time=sim.comm_time,
        policy=policy, cost_model=model_name, placement=pl,
        choices=[choices[t.task_id] for t in placed.comm_tasks],
        link_hotspots=hotspots, sim=sim,
        error_budget=error_budget, wire_bytes_saved=bytes_saved,
        task_exposed_s=dict(sim.task_exposed_s),
        timeline=list(sim.timeline))


# ---------------------------------------------------------------------------
# search(): walk the free knobs
# ---------------------------------------------------------------------------


def _assignment_value_json(v):
    """An assignment value in JSON form (placements as device lists)."""
    if isinstance(v, Placement):
        return _placement_to_dict(v)
    return v


def _assignment_value_from_json(v):
    """Inverse of :func:`_assignment_value_json`: serialized placements
    come back as real Placement objects, so a round-tripped result walks
    and talks like a live one."""
    if isinstance(v, dict) and {"devices", "strategy", "mesh"} <= set(v):
        return _placement_from_dict(v)
    return v


def _assignment_from_json(d: Mapping) -> Dict[str, object]:
    return {n: _assignment_value_from_json(v) for n, v in d.items()}


@dataclass
class Candidate:
    """One explored point of the plan space.  Only the search winner
    keeps its full ``report`` (and live sim trace); runners-up carry the
    headline metrics, their knob assignment, and the per-candidate
    telemetry record (which search phase priced it, why it was ruled
    infeasible, how often deduplication re-served it)."""

    assignment: Dict[str, object]
    jct: float
    exposed_comm: float
    worst_link_bytes: float
    feasible: bool
    report: Optional[CodesignReport] = None
    key: Optional[Tuple[float, ...]] = None  # objective key, not serialized
    # telemetry (repro.obs): infeasibility reason (None = feasible),
    # which search phase first priced this point, and how many times the
    # walk asked for it (1 = priced once, >1 = memo re-served)
    reason: Optional[str] = None
    phase: str = "sweep"
    requests: int = 1

    def to_dict(self) -> Dict:
        return {
            "assignment": {n: _assignment_value_json(v)
                           for n, v in self.assignment.items()},
            "jct": self.jct, "exposed_comm": self.exposed_comm,
            "worst_link_bytes": self.worst_link_bytes,
            "feasible": self.feasible,
            "reason": self.reason, "phase": self.phase,
            "requests": self.requests,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "Candidate":
        return cls(assignment=_assignment_from_json(d["assignment"]),
                   jct=d["jct"], exposed_comm=d["exposed_comm"],
                   worst_link_bytes=d["worst_link_bytes"],
                   feasible=d["feasible"], report=None,
                   reason=d.get("reason"), phase=d.get("phase", "sweep"),
                   requests=d.get("requests", 1))


@dataclass
class SearchResult:
    """What ``search()`` hands back: the winning plan, the frontier it
    explored, and which knob bought how much of the win."""

    best: CodesignReport
    best_assignment: Dict[str, object]
    frontier: List[Candidate]
    # knob -> JCT the best plan saves vs reverting that one knob to its
    # baseline (Choice: the first option; placement Search: "packed")
    attribution: Dict[str, float]
    evaluated: int
    budget: int
    truncated: bool = False  # budget ran out before the walk finished
    # search telemetry (repro.obs.meters): plan evaluations, memo
    # re-serves, and the cost models' cache counters (FlowSim hit/miss
    # per switch-capacity bucket + hit rates)
    telemetry: Dict[str, object] = field(default_factory=dict)

    @property
    def best_jct(self) -> float:
        return self.best.jct

    def to_dict(self) -> Dict:
        return {
            "best": self.best.to_dict(),
            "best_assignment": {n: _assignment_value_json(v)
                                for n, v in self.best_assignment.items()},
            "frontier": [c.to_dict() for c in self.frontier],
            "attribution": dict(self.attribution),
            "evaluated": self.evaluated, "budget": self.budget,
            "truncated": self.truncated,
            "telemetry": dict(self.telemetry),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "SearchResult":
        return cls(best=CodesignReport.from_dict(d["best"]),
                   best_assignment=_assignment_from_json(
                       d["best_assignment"]),
                   frontier=[Candidate.from_dict(c) for c in d["frontier"]],
                   attribution=dict(d["attribution"]),
                   evaluated=d["evaluated"], budget=d["budget"],
                   truncated=d["truncated"],
                   telemetry=dict(d.get("telemetry", {})))

    def to_trace(self, topo=None, **kw):
        """This search as a Perfetto trace: the winner's full tracks plus
        the frontier/telemetry on a search process
        (``repro.obs.trace.trace_from_search``)."""
        from repro.obs.trace import trace_from_search
        return trace_from_search(self.to_dict(), topo=topo, **kw)


def _bucket_candidates(problem: CodesignProblem,
                       seeds: Tuple = ()) -> List[Optional[int]]:
    """Candidate sizes for ``bucket_bytes=Search()``: ``None`` first (the
    legacy per-layer baseline attribution reverts to), then a geometric
    ladder total/2^k over the job's gradient-sync bytes — the classic
    MG-WFBP/ByteScheduler fusion space, whole-model sync down to fine
    buckets.  Deterministic; ``seeds`` appends explicit extra sizes."""
    demand = build_demand(problem.cfg, problem.shape, problem.mesh,
                          problem.dp_params)
    total = sum(t.size_bytes for t in demand.comm_tasks
                if t.axis == "data" and t.before_compute == "opt")
    floor = 1 << 20  # below ~1 MiB per bucket alpha always dominates
    out: List[Optional[int]] = [None]
    if total:
        for k in (1, 2, 4, 8, 16, 32):
            v = max(total // k, floor)
            if v not in out:
                out.append(v)
    for s in seeds or ():
        if s not in out:
            out.append(int(s))
    return out


def _stagger_candidates(problem: CodesignProblem,
                        seeds: Tuple = ()) -> List[float]:
    """Candidate phase offsets for ``stagger=Search()`` on a serving
    problem: 0 first (the naive co-tenant baseline attribution reverts
    to), then an even grid over the first co-tenant training pulse's
    period — the CASSINI insight applied to the serving admission clock.
    Deterministic; ``seeds`` appends explicit extra offsets."""
    spec = problem.serving
    cotenants = getattr(spec, "cotenants", ()) if spec is not None else ()
    out: List[float] = [0.0]
    if cotenants:
        period = cotenants[0].period_s
        grid = 8
        for i in range(1, grid):
            out.append(i * period / grid)
    for s in seeds or ():
        v = float(s)
        if v not in out:
            out.append(v)
    return out


def _canon(value) -> Tuple:
    """Hashable identity of an assignment value (dedup key)."""
    if isinstance(value, Placement):
        return ("placement", value.devices)
    if isinstance(value, dict):
        return ("dict", tuple(sorted(value.items())))
    return ("value", value)


def search(problem: CodesignProblem, budget: int = 32,
           seeds_dir: Optional[str] = None) -> SearchResult:
    """Walk the free knobs of ``problem.space`` and return the best plan.

    ``Choice`` knobs are enumerated (Cartesian product, declaration
    order); ``placement=Search()`` additionally pulls heuristic
    candidates from ``codesign.placement_search`` and, with budget left,
    refines the incumbent with a hot-spot-guided swap-neighborhood hill
    climb.  Every candidate is priced by ``plan()`` through one shared
    cost model per switch-capacity value, so FlowSim memoization spans
    the whole walk.  ``budget`` caps the number of full plan
    evaluations; per-knob attribution baselines are priced on top (at
    most one extra evaluation per free knob).

    ``seeds_dir`` persists searched plans per (topology, model, mesh):
    a previous run's winning assignment is loaded as a warm start (the
    first candidate priced, phase ``"warm_start"``), and this run's
    winner is saved back — ``codesign.seeds``.

    Deterministic by construction: no randomness, stable enumeration and
    neighbor order — the same problem and budget always return the same
    best plan."""
    if budget < 1:
        raise ValueError(f"search budget must be >= 1, got {budget}")
    from repro.codesign.placement_search import (heuristic_placements,
                                                 swap_neighbors)
    space = problem.space
    free = space.free_knobs()
    synth_base = DEFAULT_SYNTH_CACHE.meters.snapshot()

    # candidate values per enumerable knob, declaration order
    axes: Dict[str, List] = {}
    placement_open = False  # Search(): swap-walk refinement after sweep
    for name, knob in free.items():
        if isinstance(knob, Choice):
            axes[name] = list(knob.options)
        elif name == "placement":  # Search
            placement_open = True
            axes[name] = heuristic_placements(problem.mesh, problem.topo,
                                              seeds=knob.seeds)
        elif name == "bucket_bytes":  # Search: geometric bucket ladder
            axes[name] = _bucket_candidates(problem, knob.seeds)
        elif name == "decompose":  # Search: bulk baseline, then rewritten
            axes[name] = [False, True]
        elif name == "stagger":  # Search: grid over the co-tenant period
            axes[name] = _stagger_candidates(problem, knob.seeds)
        elif name == "synthesize":  # Search: registry-only, then + synth
            axes[name] = [False, True]
        else:
            raise ValueError(
                f"knob {name!r} is Search() but only placement, "
                f"bucket_bytes, decompose, stagger and synthesize have "
                f"candidate generators — use Choice(...) for it")
    pinned = {name: knob.value
              for name, knob in space.scalar_knobs().items()
              if name not in axes}

    # one resolved cost model per switch-capacity value: memoization
    # spans every candidate priced under the same aggregation budget
    models: Dict[Tuple, Tuple[CostModel, str]] = {}

    def model_for(cap) -> Tuple[CostModel, str]:
        key = _canon(cap)
        if key not in models:
            models[key] = _resolve_cost_model(problem.cost_model,
                                              problem.topo, cap)
        return models[key]

    objective = problem.objective
    seen: Dict[Tuple, Candidate] = {}
    order: List[Candidate] = []
    state = {"evaluated": 0, "memo_hits": 0}

    def evaluate(assignment: Dict[str, object], charge: bool = True,
                 phase: str = "sweep") -> Candidate:
        key = tuple((n, _canon(assignment[n])) for n in sorted(assignment))
        if key in seen:
            cand = seen[key]
            cand.requests += 1
            state["memo_hits"] += 1
            return cand
        values = dict(pinned)
        values.update(assignment)
        prob = problem.pinned(**values)
        report = plan(prob, _resolved=model_for(values["switch_capacity"]))
        reason = objective.infeasible_reason(report)
        feasible = reason is None
        cand = Candidate(assignment=dict(assignment), jct=report.jct,
                         exposed_comm=report.exposed_comm,
                         worst_link_bytes=report.worst_link_bytes,
                         feasible=feasible, report=report,
                         key=objective.key(report), reason=reason,
                         phase=phase)
        seen[key] = cand
        order.append(cand)
        if charge:
            state["evaluated"] += 1
        return cand

    def better(a: Candidate, b: Optional[Candidate]) -> bool:
        if b is None:
            return True
        if a.feasible != b.feasible:
            return a.feasible
        return a.key < b.key

    best: Optional[Candidate] = None

    def consider(cand: Candidate) -> None:
        """Advance the incumbent; losers drop their full report right
        away so peak memory stays at one live report, not one per
        explored candidate."""
        nonlocal best
        if better(cand, best):
            if best is not None:
                best.report = None
            best = cand
        elif cand is not best:
            cand.report = None

    # --- phase 0: warm start from a persisted seed -----------------------
    names = list(axes)
    truncated = False
    if seeds_dir is not None and names:
        from repro.codesign.seeds import load_seed
        warm = load_seed(seeds_dir, problem)
        if warm is not None and set(warm) == set(names):
            consider(evaluate(warm, phase="warm_start"))

    # --- phase 1: enumerate the Choice/heuristic sweep -------------------
    if names:
        for combo in itertools.product(*(axes[n] for n in names)):
            if state["evaluated"] >= budget:
                truncated = True
                break
            consider(evaluate(dict(zip(names, combo))))
    else:
        best = evaluate({})

    # --- phase 2: swap-neighborhood hill climb on the placement ----------
    if placement_open and best is not None:
        improved = True
        while improved:
            improved = False
            incumbent = best.assignment["placement"]
            if not isinstance(incumbent, Placement):
                incumbent = place_mesh(problem.mesh, problem.topo,
                                       strategy=incumbent)
            for nb in swap_neighbors(incumbent, problem.topo,
                                     report=best.report):
                if state["evaluated"] >= budget:
                    truncated = True
                    break
                prev = best
                consider(evaluate({**best.assignment, "placement": nb},
                                  phase="hillclimb"))
                if best is not prev:
                    improved = True
                    break

    if best is None or not best.feasible:
        hint = "" if best is None else \
            f" (best infeasible plan: {best.reason})"
        raise ValueError(f"search found no feasible plan within "
                         f"budget={budget}{hint}")

    # --- per-knob attribution: revert one knob to its baseline -----------
    baselines: Dict[str, object] = {}
    for name in names:
        knob = free[name]
        # Choice: the declared first option; placement Search: the first
        # heuristic candidate, which heuristic_placements pins to packed
        baselines[name] = knob.options[0] if isinstance(knob, Choice) \
            else axes[name][0]
    attribution: Dict[str, float] = {}
    for name, base_value in baselines.items():
        if _canon(best.assignment[name]) == _canon(base_value):
            attribution[name] = 0.0
            continue
        reverted = evaluate({**best.assignment, name: base_value},
                            charge=False, phase="baseline")
        # objective-primary delta (== JCT delta for the default training
        # objective; TTFT-p99 delta for a latency-SLO serving objective)
        attribution[name] = reverted.key[0] - best.key[0]
        if reverted is not best:
            reverted.report = None

    if seeds_dir is not None and names:
        from repro.codesign.seeds import save_seed
        save_seed(seeds_dir, problem, best.assignment)

    frontier = sorted(order, key=lambda c: (not c.feasible, c.key))
    telemetry = _search_telemetry(state, order, models)
    # synthesis-solver cache counters, as THIS search's delta against the
    # process-wide cache (repeated identical runs then report identical
    # numbers, which the bench guards rely on)
    synth_now = DEFAULT_SYNTH_CACHE.meters.snapshot()
    hits = synth_now.get("synth.hit", 0.0) - synth_base.get("synth.hit", 0.0)
    misses = synth_now.get("synth.miss", 0.0) \
        - synth_base.get("synth.miss", 0.0)
    if hits + misses > 0:
        counters = telemetry["counters"]
        counters["synth.hit"] = hits
        counters["synth.miss"] = misses
        counters["synth.entries"] = \
            DEFAULT_SYNTH_CACHE.cache_stats()["synth.entries"]
        telemetry["synth_hit_rate"] = hits / (hits + misses)
    return SearchResult(
        best=best.report, best_assignment=dict(best.assignment),
        frontier=frontier, attribution=attribution,
        evaluated=state["evaluated"], budget=budget, truncated=truncated,
        telemetry=telemetry)


def _search_telemetry(state: Dict, order: List[Candidate],
                      models: Dict) -> Dict[str, object]:
    """The walk's deterministic counters (``repro.obs``): how many plans
    were priced vs re-served from the assignment memo, the feasibility
    split, and the cost models' cache counters — FlowSim hit/miss per
    switch-capacity bucket plus an overall cost-memo hit rate."""
    counters: Dict[str, float] = {}
    for model, _name in models.values():
        stats = getattr(model, "cache_stats", None)
        if stats is None:
            continue
        # bucket-labelled keys are disjoint across models (one FlowSim
        # per switch capacity), so a plain merge keeps buckets apart
        counters.update(stats())
    hits = sum(v for k, v in counters.items() if k.endswith(".cost.hit"))
    misses = sum(v for k, v in counters.items()
                 if k.endswith(".cost.miss"))
    out: Dict[str, object] = {
        "plan_evals": len(order),
        "charged_evals": state["evaluated"],
        "memo_hits": state["memo_hits"],
        "infeasible": sum(1 for c in order if not c.feasible),
        "counters": {k: counters[k] for k in sorted(counters)},
    }
    if hits + misses > 0:
        out["flowsim_cost_hit_rate"] = hits / (hits + misses)
    return out
