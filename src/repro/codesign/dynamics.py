"""Event-driven cluster dynamics with incremental re-planning.

The paper's "opportunities" (Sec. VII) include elastic and fault-tolerant
training: a production cluster is not a static co-design problem.  Jobs
arrive and depart, links fail or lose bandwidth, hosts drop out, and
stragglers appear — and each such event invalidates only *part* of the
standing plan.  Re-running :func:`plan_cluster` from scratch on every
event re-prices every tenant's collectives and sweeps a ``grid**(n-1)``
phase search; almost all of that work reproduces the previous answer.

:class:`ClusterDynamics` consumes a trace of :class:`Event`s and re-plans
incrementally:

  1. **diff** — an event dirties a set of physical links (the failed or
     degraded link, a dead host's incident links) and thereby the jobs
     whose per-link byte maps touch them; job arrivals/departures dirty
     only the jobs they share links with;
  2. **vertical re-plan** — only jobs whose *topology view* changed under
     their routes (or whose devices died, or that just arrived) are
     re-placed and re-priced on a degradation view of the base topology
     (``Topology.without_link`` / ``without_host`` / ``scaled_bw``);
     clean jobs keep their ``CodesignReport`` verbatim — a job's vertical
     plan is a single-tenant quantity, so other tenants' churn cannot
     invalidate it;
  3. **horizontal re-stagger** — :func:`restagger_cluster` sweeps phase
     offsets of the dirty jobs only, holding everyone else frozen
     (``grid**|dirty|`` instead of ``grid**(n-1)``);
  4. **fallback** — if the incremental plan is infeasible (a job cannot
     be re-placed, no route survives, a JCT diverges) the engine falls
     back to the full from-scratch search on the current view, evicting
     the most recently arrived tenants when the surviving fabric cannot
     hold everyone.

The engine warm-starts from a persisted :class:`ClusterReport` (its JSON
``to_dict``/``from_dict`` round-trip), so a restarted controller does not
re-search a running cluster.  Every event yields an :class:`EventRecord`
with its time-to-replan and — when ``compare_full=True`` — the wall-clock
and worst-stretch *regret* of the incremental answer against a full
re-search on the same view.  :class:`DynamicsReport` aggregates the trace
for the ``replan`` benchmark row.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import networkx as nx

from repro.net.topology import Topology
from repro.obs.meters import Meters
from repro.codesign.api import plan
from repro.codesign.cluster import (ClusterReport, JobPlan, JobSpec,
                                    _carve_devices, _job_profile,
                                    _stagger_plans, restagger_cluster)
from repro.codesign.placement import place_mesh
from repro.codesign.report import _link_key, _parse_link_key

EVENT_KINDS = ("job_arrive", "job_depart", "link_fail", "link_degrade",
               "host_fail", "straggler")


@dataclass(frozen=True)
class Event:
    """One cluster event.  Field use by kind:

    * ``job_arrive``   — ``job`` (the new :class:`JobSpec`);
    * ``job_depart``   — ``name``;
    * ``link_fail``    — ``link`` (a physical ``(u, v)``; both
      orientations fail);
    * ``link_degrade`` — ``link`` + ``factor`` in (0, 1) (bandwidth
      multiplier, compounding across events);
    * ``host_fail``    — ``host`` (index into the *base* topology's
      ``hosts``);
    * ``straggler``    — ``name`` + ``factor`` > 1 (compute slowdown,
      compounding)."""

    kind: str
    time: float = 0.0
    job: Optional[JobSpec] = None
    name: Optional[str] = None
    link: Optional[Tuple] = None
    host: Optional[int] = None
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r} "
                             f"(one of {EVENT_KINDS})")
        need = {"job_arrive": self.job is not None,
                "job_depart": self.name is not None,
                "link_fail": self.link is not None,
                "link_degrade": self.link is not None,
                "host_fail": self.host is not None,
                "straggler": self.name is not None}
        if not need[self.kind]:
            raise ValueError(f"event {self.kind!r} is missing its target "
                             f"field (see Event docstring)")
        if self.kind == "link_degrade" and not 0 < self.factor < 1:
            raise ValueError(f"link_degrade factor must be in (0, 1), got "
                             f"{self.factor} (use link_fail for outage)")
        if self.kind == "straggler" and self.factor <= 1:
            raise ValueError(f"straggler factor must be > 1 (a slowdown), "
                             f"got {self.factor}")

    @property
    def target(self) -> str:
        if self.kind == "job_arrive":
            return self.job.name
        if self.kind in ("job_depart", "straggler"):
            return self.name
        if self.kind == "host_fail":
            return f"host{self.host}"
        return _link_key(self.link)


@dataclass
class EventRecord:
    """What one event cost and what plan it left behind."""

    kind: str
    target: str
    time: float
    mode: str                     # "incremental" | "full"
    dirty_jobs: List[str]         # jobs whose phases were re-searched
    dirty_links: List[Tuple]      # physical links the event touched
    replan_s: float               # wall-clock of the engine's re-plan
    worst_stretch: float          # staggered worst stretch after the event
    jct: Dict[str, float]         # staggered per-job JCT after the event
    full_replan_s: Optional[float] = None  # compare_full: full re-search
    regret: Optional[float] = None         # inc/full worst stretch - 1
    evicted: List[str] = field(default_factory=list)
    # checkpoint-restore bill for tenants this event moved or evicted
    # (modeled data-plane seconds, not controller wall-clock)
    restore_s: float = 0.0

    def to_dict(self) -> Dict:
        d = {"kind": self.kind, "target": self.target, "time": self.time,
             "mode": self.mode, "dirty_jobs": list(self.dirty_jobs),
             "dirty_links": [_link_key(l) for l in self.dirty_links],
             "replan_s": self.replan_s, "worst_stretch": self.worst_stretch,
             "jct": dict(self.jct), "full_replan_s": self.full_replan_s,
             "regret": self.regret, "evicted": list(self.evicted),
             "restore_s": self.restore_s}
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "EventRecord":
        return cls(kind=d["kind"], target=d["target"], time=d["time"],
                   mode=d["mode"], dirty_jobs=list(d["dirty_jobs"]),
                   dirty_links=[_parse_link_key(k)
                                for k in d["dirty_links"]],
                   replan_s=d["replan_s"],
                   worst_stretch=d["worst_stretch"], jct=dict(d["jct"]),
                   full_replan_s=d.get("full_replan_s"),
                   regret=d.get("regret"),
                   evicted=list(d.get("evicted", [])),
                   restore_s=d.get("restore_s", 0.0))


@dataclass
class DynamicsReport:
    """A trace's worth of :class:`EventRecord`s plus the final plan."""

    records: List[EventRecord]
    final: ClusterReport
    # engine telemetry (``repro.obs.meters`` snapshot): replan-mode
    # tallies, dirty-set sizes, phase-search evaluation counts
    telemetry: Dict[str, float] = field(default_factory=dict)

    @property
    def incremental_speedup(self) -> Optional[float]:
        """Aggregate wall-clock win of incremental re-planning: total full
        re-search time over total incremental time, across the events
        where both were measured (``compare_full=True`` runs).  Summing
        before dividing keeps single-event timer noise from dominating."""
        pairs = [(r.full_replan_s, r.replan_s) for r in self.records
                 if r.mode == "incremental" and r.full_replan_s is not None]
        if not pairs:
            return None
        return sum(f for f, _ in pairs) / max(
            sum(i for _, i in pairs), 1e-12)

    @property
    def worst_regret(self) -> Optional[float]:
        rs = [r.regret for r in self.records if r.regret is not None]
        return max(rs) if rs else None

    @property
    def mean_replan_s(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.replan_s for r in self.records) / len(self.records)

    @property
    def total_restore_s(self) -> float:
        """Checkpoint-restore seconds billed across the whole trace —
        the data-plane price of every eviction/re-placement."""
        return sum(r.restore_s for r in self.records)

    def to_dict(self) -> Dict:
        return {"records": [r.to_dict() for r in self.records],
                "final": self.final.to_dict(),
                "telemetry": dict(self.telemetry)}

    @classmethod
    def from_dict(cls, d: Dict, specs: Dict[str, JobSpec]
                  ) -> "DynamicsReport":
        return cls(records=[EventRecord.from_dict(r) for r in d["records"]],
                   final=ClusterReport.from_dict(d["final"], specs),
                   telemetry=dict(d.get("telemetry", {})))

    def to_trace(self, topo=None, **kw):
        """The whole trace as a Perfetto timeline: event/replan/stretch
        tracks for the dynamics run plus the final cluster plan's per-job
        timelines (``repro.obs.trace.trace_from_dynamics``)."""
        from repro.obs.trace import trace_from_dynamics
        return trace_from_dynamics(self.to_dict(), topo=topo, **kw)


def _restore_cost_s(spec: JobSpec, devices: Sequence[int],
                    view: Topology) -> float:
    """Checkpoint-restore bill for moving (or evicting) a tenant: the
    job's full training state (``checkpoint.io.checkpoint_state_bytes``:
    f32 master params + AdamW moments) streamed in over the job's
    ingress bandwidth — each device pulls its shard through its own NIC,
    so ingress is the sum over the job's devices of their slowest
    incident inbound link on the current view."""
    if not devices:
        return 0.0
    from repro.checkpoint.io import checkpoint_state_bytes
    state = checkpoint_state_bytes(spec.cfg)
    ingress = 0.0
    for d in devices:
        if d not in view.graph:
            continue
        bws = [view.link_bw(u, d) for u in view.graph.predecessors(d)]
        if bws:
            ingress += min(bws)
    return state / ingress if ingress > 0 else 0.0


def _respec(spec: JobSpec, devices: Optional[Tuple[int, ...]]) -> JobSpec:
    """A copy of ``spec`` with a different device pin.  (``replace`` can't
    be used: a problem-carrying JobSpec fills its flat fields in
    ``__post_init__``, and passing both back is rejected.)"""
    if spec.problem is not None:
        return JobSpec(spec.name, devices=devices, problem=spec.problem)
    return JobSpec(spec.name, spec.cfg, spec.shape, spec.mesh,
                   devices=devices, policy=spec.policy,
                   dp_params=spec.dp_params, force=spec.force,
                   error_budget=spec.error_budget)


class ClusterDynamics:
    """The event loop: holds the cluster's current plan and failure state,
    applies events, and re-plans incrementally (full search as fallback).

    ``warm_start`` seeds the standing plan — a live :class:`ClusterReport`
    or its ``to_dict()`` JSON — instead of running the initial full
    search; ``compare_full=True`` additionally prices every incremental
    answer against a from-scratch full re-search (for the speedup/regret
    metrics; it does not affect the engine's own state)."""

    def __init__(self, jobs: Sequence[JobSpec], topo: Topology,
                 cost_model: str = "flowsim", grid: int = 8,
                 horizon_iters: int = 12, dt: Optional[float] = None,
                 switch_capacity: Optional[int] = None,
                 max_contended_links: int = 8, compare_full: bool = False,
                 warm_start: Optional[Union[ClusterReport, Dict]] = None,
                 clock=time.perf_counter):
        names = [s.name for s in jobs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate job names: {names}")
        self.base_topo = topo
        self.cost_model = cost_model
        self.grid = grid
        self.horizon_iters = horizon_iters
        self.dt = dt
        self.switch_capacity = switch_capacity
        self.max_contended_links = max_contended_links
        self.compare_full = compare_full
        # injectable clock: tests pass a fake counter to make ``replan_s``
        # / ``full_replan_s`` deterministic; the obs meters share it
        self.clock = clock
        self.meters = Meters(clock=clock)
        self.specs: Dict[str, JobSpec] = {s.name: s for s in jobs}
        self.failed_hosts: Set[int] = set()
        self.failed_links: Set[Tuple] = set()
        self.bw_scale: Dict[Tuple, float] = {}
        self.straggle: Dict[str, float] = {}
        self.records: List[EventRecord] = []
        if warm_start is None:
            self.report, _ = self._plan_full(self._view())
        elif isinstance(warm_start, ClusterReport):
            self.report = warm_start
        else:
            self.report = ClusterReport.from_dict(warm_start, self.specs)

    # ------------------------------------------------------------------
    # Topology view
    # ------------------------------------------------------------------

    def _view(self) -> Topology:
        """The base topology through every failure/degradation so far.
        Host removals go first, highest base index first, so the indices
        recorded at event time stay valid while removing."""
        t = self.base_topo
        for h in sorted(self.failed_hosts, reverse=True):
            t = t.without_host(h)
        for u, v in sorted(self.failed_links, key=str):
            t = t.without_link(u, v)
        scales = {l: f for l, f in self.bw_scale.items()
                  if f != 1.0 and t.graph.has_edge(*l)}
        return t.scaled_bw(scales) if scales else t

    # ------------------------------------------------------------------
    # Planning helpers
    # ------------------------------------------------------------------

    def _plan_job(self, spec: JobSpec, devs: Tuple[int, ...],
                  view: Topology) -> JobPlan:
        placement = place_mesh(spec.mesh, view, "custom", custom=devs)
        report = plan(spec.to_problem(
            view, placement, self.cost_model, self.switch_capacity,
            hotspot_k=view.graph.number_of_edges()))
        prof = _job_profile(spec.name, report,
                            self.straggle.get(spec.name, 1.0))
        return JobPlan(spec=spec, devices=tuple(devs), report=report,
                       profile=prof, link_bytes=dict(report.link_hotspots))

    def _empty_report(self) -> ClusterReport:
        return ClusterReport(jobs=[], contended={}, phases={},
                             naive_jct={}, staggered_jct={},
                             cost_model=str(self.cost_model),
                             link_demands={})

    def _plan_full(self, view: Topology
                   ) -> Tuple[ClusterReport, List[str]]:
        """From-scratch re-plan of every tenant on ``view``.  Device pins
        that no longer exist fall back to first-fit; when the surviving
        fabric cannot hold everyone, the most recently arrived tenants
        are marked for eviction (LIFO) and planned out.  Pure: the
        eviction list is *returned*, not applied — ``apply`` commits it
        only when this plan becomes the standing one."""
        alive = set(view.accelerators)
        names = list(self.specs)
        evicted: List[str] = []
        while names and sum(self.specs[n].mesh.num_devices
                            for n in names) > len(alive):
            evicted.append(names.pop())
        if not names:
            return self._empty_report(), evicted
        devmap = {jp.spec.name: jp.devices
                  for jp in getattr(self, "report", self._empty_report()
                                    ).jobs}
        specs = []
        for n in names:
            spec = self.specs[n]
            devs = devmap.get(n, spec.devices)
            if devs is not None and not set(devs) <= alive:
                devs = None
            specs.append(_respec(spec, tuple(devs) if devs else None))
        blocks = _carve_devices(specs, view)
        plans = [self._plan_job(spec, devs, view)
                 for spec, devs in zip(specs, blocks)]
        rep = _stagger_plans(plans, view, grid=self.grid,
                             horizon_iters=self.horizon_iters, dt=self.dt,
                             max_contended_links=self.max_contended_links,
                             cost_model=plans[0].report.cost_model,
                             meters=getattr(self, "meters", None))
        return rep, evicted

    def _rebuild_plans(self, view: Topology, vertical: Set[str]
                       ) -> List[JobPlan]:
        """Current per-job plans on ``view``: jobs in ``vertical`` (plus
        any without a standing plan) are re-placed and re-priced; clean
        jobs keep their plan, with the profile refreshed so sticky
        straggle factors apply.  Raises ``ValueError`` when a dirty job
        cannot be re-placed — the caller's cue to fall back."""
        old = {jp.spec.name: jp for jp in self.report.jobs}
        alive = set(view.accelerators)
        keep: Dict[str, JobPlan] = {}
        taken: Set[int] = set()
        pending: List[JobSpec] = []
        for name, spec in self.specs.items():
            jp = old.get(name)
            if jp is None or name in vertical:
                pending.append(spec)
                continue
            prof = _job_profile(name, jp.report,
                                self.straggle.get(name, 1.0))
            if prof != jp.profile:
                jp = replace(jp, profile=prof)
            keep[name] = jp
            taken |= set(jp.devices)
        free = [a for a in view.accelerators if a not in taken]
        for spec in pending:
            prev = old[spec.name].devices if spec.name in old \
                else spec.devices
            devs = tuple(prev) if prev is not None else None
            if devs is not None and (not set(devs) <= alive
                                     or set(devs) & taken):
                devs = None   # lost (or re-taken) devices: re-carve
            if devs is None:
                n = spec.mesh.num_devices
                if n > len(free):
                    raise ValueError(
                        f"job {spec.name!r}: {n} devices needed but only "
                        f"{len(free)} remain on {view.name}")
                devs, free = tuple(free[:n]), free[n:]
            taken |= set(devs)
            keep[spec.name] = self._plan_job(spec, devs, view)
        return [keep[n] for n in self.specs]

    # ------------------------------------------------------------------
    # The event loop
    # ------------------------------------------------------------------

    def apply(self, ev: Event) -> EventRecord:
        """Apply one event: update failure state, diff the dirty set,
        re-plan (incrementally if possible), record the cost."""
        link_maps = {jp.spec.name: set(jp.link_bytes)
                     for jp in self.report.jobs}
        old_devs = {jp.spec.name: tuple(jp.devices)
                    for jp in self.report.jobs}
        dirty_links: Set[Tuple] = set()
        vertical: Set[str] = set()      # jobs needing a vertical re-plan
        phase_dirty: Set[str] = set()   # jobs whose phase is re-searched

        if ev.kind == "job_arrive":
            if ev.job.name in self.specs:
                raise ValueError(f"job {ev.job.name!r} already running")
            self.specs[ev.job.name] = ev.job
            vertical.add(ev.job.name)
        elif ev.kind == "job_depart":
            if ev.name not in self.specs:
                raise ValueError(f"job {ev.name!r} not running")
            del self.specs[ev.name]
            self.straggle.pop(ev.name, None)
            dirty_links |= link_maps.pop(ev.name, set())
        elif ev.kind in ("link_fail", "link_degrade"):
            u, v = ev.link
            if ev.kind == "link_fail":
                self.failed_links.add((u, v))
            else:
                self.bw_scale[(u, v)] = (self.bw_scale.get((u, v), 1.0)
                                         * ev.factor)
            dirty_links |= {(u, v), (v, u)}
        elif ev.kind == "host_fail":
            prev = self._view()
            dead = set(self.base_topo.hosts[ev.host])
            for d in dead & set(prev.graph.nodes):
                for nbr in prev.graph.successors(d):
                    dirty_links |= {(d, nbr), (nbr, d)}
            self.failed_hosts.add(ev.host)
            for jp in self.report.jobs:
                if set(jp.devices) & dead and jp.spec.name in self.specs:
                    vertical.add(jp.spec.name)
        else:  # straggler
            if ev.name not in self.specs:
                raise ValueError(f"job {ev.name!r} not running")
            self.straggle[ev.name] = (self.straggle.get(ev.name, 1.0)
                                      * ev.factor)
            phase_dirty.add(ev.name)

        # a topology change under a job's routes invalidates its vertical
        # plan; mere tenant churn (arrive/depart) only re-opens phases —
        # the vertical plan is a single-tenant quantity
        topo_changed = ev.kind in ("link_fail", "link_degrade", "host_fail")
        for name, links in link_maps.items():
            if name in self.specs and links & dirty_links:
                (vertical if topo_changed else phase_dirty).add(name)
        phase_dirty |= vertical

        view = self._view()
        t0 = self.clock()
        report: Optional[ClusterReport] = None
        evicted: List[str] = []
        mode = "incremental"
        if self.specs:
            try:
                plans = self._rebuild_plans(view, vertical)
                if ev.kind == "job_arrive":
                    # now that the arrival is routed, free the phases of
                    # every tenant it shares links with
                    new_links = set(plans[-1].link_bytes) \
                        if plans[-1].spec.name == ev.job.name else set()
                    for jp in plans:
                        if set(jp.link_bytes) & new_links:
                            phase_dirty.add(jp.spec.name)
                report = restagger_cluster(
                    plans, view, phases=self.report.phases,
                    dirty=sorted(phase_dirty & set(self.specs)),
                    grid=self.grid, horizon_iters=self.horizon_iters,
                    dt=self.dt,
                    max_contended_links=self.max_contended_links,
                    cost_model=self.report.cost_model,
                    meters=self.meters)
            except (ValueError, KeyError, nx.NetworkXException):
                report = None
            if report is not None and any(
                    v == float("inf")
                    for v in report.staggered_jct.values()):
                report = None   # diverged under the frozen phases
            if report is None:
                mode = "full"
                report, evicted = self._plan_full(view)
                evicted_specs = {n: self.specs[n] for n in evicted}
                for n in evicted:
                    del self.specs[n]
                    self.straggle.pop(n, None)
        else:
            report = self._empty_report()
        replan_s = self.clock() - t0

        # checkpoint-restore bill: every surviving tenant whose device
        # set moved re-ingests its training state at the new seats;
        # evicted tenants drain theirs through the seats they had left
        restore_s = 0.0
        for jp in report.jobs:
            prev = old_devs.get(jp.spec.name)
            if prev is not None and prev != tuple(jp.devices):
                restore_s += _restore_cost_s(jp.spec, jp.devices, view)
        for n in evicted:
            restore_s += _restore_cost_s(evicted_specs[n],
                                         old_devs.get(n, ()), view)

        full_s = regret = None
        if self.compare_full and mode == "incremental" and self.specs:
            t1 = self.clock()
            full_rep, _ = self._plan_full(view)
            full_s = self.clock() - t1
            if report.jobs and full_rep.jobs:
                regret = (report.staggered_worst_stretch
                          / full_rep.staggered_worst_stretch - 1.0)

        self.meters.incr(f"dynamics.mode.{mode}")
        self.meters.incr(f"dynamics.event.{ev.kind}")
        self.meters.observe("dynamics.dirty_jobs",
                            float(len(phase_dirty & set(self.specs))))
        self.meters.observe("dynamics.dirty_links",
                            float(len(dirty_links)))
        if evicted:
            self.meters.incr("dynamics.evictions", float(len(evicted)))
        if restore_s > 0:
            self.meters.observe("dynamics.restore_s", restore_s)

        self.report = report
        rec = EventRecord(
            kind=ev.kind, target=ev.target, time=ev.time, mode=mode,
            dirty_jobs=sorted(phase_dirty & set(self.specs)),
            dirty_links=sorted(dirty_links, key=str),
            replan_s=replan_s,
            worst_stretch=(report.staggered_worst_stretch
                           if report.jobs else 1.0),
            jct=dict(report.staggered_jct),
            full_replan_s=full_s, regret=regret, evicted=evicted,
            restore_s=restore_s)
        self.records.append(rec)
        return rec

    def run(self, events: Sequence[Event]) -> DynamicsReport:
        """Apply a whole trace (sorted by event time) and aggregate."""
        for ev in sorted(events, key=lambda e: e.time):
            self.apply(ev)
        return DynamicsReport(records=list(self.records),
                              final=self.report,
                              telemetry=self.meters.snapshot())
