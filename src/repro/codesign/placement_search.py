"""Placement search: candidate generators for the placement knob.

The ROADMAP's TopoOpt-style open item: ``codesign.placement`` offered
packed / strided / custom, but nothing *searched* placements against the
FlowSim cost.  This module supplies the candidates ``codesign.api.search``
prices when ``PlanSpace.placement`` is ``Search()``:

  * the named strategies (``packed``, ``strided``);
  * ``balanced`` — host-balanced blocks: each innermost (model-axis)
    communicator is split as evenly as possible across the fewest hosts
    that can hold it.  Where ``packed`` straddles a host boundary
    unevenly (e.g. a TP-12 group over 8-GPU hosts lands 8+4), the even
    6+6 split restores the equal-size host partition the hierarchical
    decomposition needs — the single biggest placement win on
    oversubscribed fat-trees;
  * axis permutations — row-major rank layouts under every permutation
    of the mesh axes (the "which axis is physically innermost" family);
  * a swap neighborhood for local refinement, ordered by the incumbent
    plan's link hot spots (move the ranks pressing the hottest links
    first).

All generators are deterministic (no RNG): the same mesh + topology
always yield the same candidate sequence, which is what makes
``search()`` reproducible.
"""
from __future__ import annotations

import itertools
import math
from typing import Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.types import MeshConfig
from repro.net.topology import Topology

from repro.codesign.placement import Placement, place_mesh
from repro.codesign.report import CodesignReport


def _ravel(coord: Sequence[int], shape: Sequence[int]) -> int:
    idx = 0
    for dim, c in zip(shape, coord):
        idx = idx * dim + c
    return idx


def _unravel(idx: int, shape: Sequence[int]) -> Tuple[int, ...]:
    coord = []
    for dim in reversed(shape):
        coord.append(idx % dim)
        idx //= dim
    return tuple(reversed(coord))


def axis_permuted_placement(mesh: MeshConfig, topo: Topology,
                            perm: Tuple[int, ...]) -> Placement:
    """Lay logical ranks out row-major over the mesh axes reordered by
    ``perm`` — i.e. make ``perm[-1]`` the physically innermost axis."""
    shape = mesh.shape
    pshape = tuple(shape[a] for a in perm)
    accel = topo.accelerators
    devices = []
    for r in range(mesh.num_devices):
        coord = _unravel(r, shape)
        devices.append(accel[_ravel([coord[a] for a in perm], pshape)])
    return Placement(mesh=mesh, devices=tuple(devices),
                     strategy=f"axis_perm{perm}", topology=topo.name)


def balanced_placement(mesh: MeshConfig, topo: Topology
                       ) -> Optional[Placement]:
    """Host-balanced model-axis communicators: split each TP group (size
    ``mesh.tp``) as evenly as possible across the fewest hosts that can
    hold it, preferring the emptiest hosts.  The groups are the mesh's
    actual model-axis communicators — any axis order, not just the
    model-innermost convention.

    Returns None when the topology has no host structure, the mesh is
    pure-DP (group size 1 — packed/strided already cover that family),
    or the cluster cannot hold the groups."""
    g = max(1, mesh.tp)
    n = mesh.num_devices
    if not topo.hosts or g <= 1 or n > len(topo.accelerators):
        return None
    # the model-axis communicators as logical-rank groups: an identity
    # placement's model_groups() are exactly them, for any axis order
    ident = Placement(mesh=mesh, devices=tuple(range(n)),
                      strategy="packed", topology=topo.name)
    free: List[List[int]] = [list(h) for h in topo.hosts]
    devices: List[Optional[int]] = [None] * n
    for group in ident.model_groups():
        order = sorted(range(len(free)), key=lambda h: (-len(free[h]), h))
        max_free = len(free[order[0]])
        if max_free == 0:
            return None
        # fewest hosts that can hold the group under an even split ...
        chosen = order[:math.ceil(g / max_free)]
        if sum(len(free[h]) for h in chosen) < g:
            # ... falling back to a greedy fill when tails are uneven
            chosen = []
            for h in order:
                chosen.append(h)
                if sum(len(free[x]) for x in chosen) >= g:
                    break
            else:
                return None
        # Size the shares largest-host-first: an even ceil split, but never
        # below what the remaining hosts cannot absorb — so a small host
        # capping its share backfills onto the larger ones (free [8, 4]
        # with g=12 must yield 8+4, not a failed 6+6).
        order_desc = sorted(chosen, key=lambda h: (-len(free[h]), h))
        shares: dict = {}
        remaining = g
        for i, h in enumerate(order_desc):
            rest = sum(len(free[x]) for x in order_desc[i + 1:])
            even = -(-remaining // (len(order_desc) - i))  # ceil div
            shares[h] = min(len(free[h]), max(even, remaining - rest))
            remaining -= shares[h]
        if remaining:
            return None
        chosen.sort()  # group members in host order -> minimal crossings
        alloc: List[int] = []
        for h in chosen:
            alloc.extend(free[h][:shares[h]])
            free[h] = free[h][shares[h]:]
        for rank, dev in zip(group, alloc):
            devices[rank] = dev
    return Placement(mesh=mesh, devices=tuple(devices),  # type: ignore
                     strategy="balanced", topology=topo.name)


def heuristic_placements(mesh: MeshConfig, topo: Topology,
                         seeds: Sequence[Union[str, Placement]] = ()
                         ) -> List[Placement]:
    """The deterministic candidate sweep for ``placement=Search()``:
    packed first (the attribution baseline — ties resolve to it), then
    host-balanced, strided, every non-identity axis permutation, and any
    caller seeds.  Duplicates (same device tuple) are dropped."""
    cands: List[Placement] = []
    devsets = set()

    def add(pl: Optional[Placement]) -> None:
        if pl is not None and pl.devices not in devsets:
            devsets.add(pl.devices)
            cands.append(pl)

    add(place_mesh(mesh, topo, "packed"))
    add(balanced_placement(mesh, topo))
    try:
        add(place_mesh(mesh, topo, "strided"))
    except ValueError:
        pass
    if len(mesh.shape) > 1:
        identity = tuple(range(len(mesh.shape)))
        for perm in itertools.permutations(range(len(mesh.shape))):
            if perm != identity:
                add(axis_permuted_placement(mesh, topo, perm))
    for seed in seeds:
        add(seed if isinstance(seed, Placement)
            else place_mesh(mesh, topo, strategy=seed))
    return cands


def swap_neighbors(pl: Placement, topo: Topology,
                   report: Optional[CodesignReport] = None
                   ) -> Iterator[Placement]:
    """The local-refinement neighborhood of ``pl``: first move each rank
    onto an unused accelerator, then exchange rank pairs.  When the
    incumbent's :class:`CodesignReport` is given, ranks whose devices
    touch the hottest links go first — the moves most likely to relieve
    the bottleneck are tried (and charged against the search budget)
    earliest.  Deterministic: ties break on rank index."""
    devices = pl.devices
    n = len(devices)
    used = set(devices)
    unused = [d for d in topo.accelerators if d not in used]

    heat = {}
    if report is not None:
        for (u, v), nbytes in report.link_hotspots:
            for node in (u, v):
                if node in used:
                    heat[node] = heat.get(node, 0.0) + nbytes
    rank_order = sorted(range(n),
                        key=lambda r: (-heat.get(devices[r], 0.0), r))

    for r in rank_order:
        for d in unused:
            nd = list(devices)
            nd[r] = d
            yield Placement(mesh=pl.mesh, devices=tuple(nd),
                            strategy=f"swap(r{r}->{d})",
                            topology=pl.topology)
    for i_pos, i in enumerate(rank_order):
        for j in rank_order[i_pos + 1:]:
            nd = list(devices)
            nd[i], nd[j] = nd[j], nd[i]
            yield Placement(mesh=pl.mesh, devices=tuple(nd),
                            strategy=f"swap(r{i}<->r{j})",
                            topology=pl.topology)
