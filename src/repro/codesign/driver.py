"""Legacy keyword entry point to the co-design engine.

``plan_iteration`` was the original vertical slice through all five
layers of the paper's paradigm (Fig. 5a); the engine itself now lives in
``codesign.api`` behind the declarative :class:`CodesignProblem` /
``plan`` / ``search`` surface, and this module is the exact
kwarg-for-kwarg adapter over it:

  plan_iteration(**kw) == plan(CodesignProblem.from_kwargs(**kw))

Existing callers (tests, benchmarks, ``plan_cluster``) keep working
unchanged; new code should build a :class:`CodesignProblem` and call
``plan``/``search`` directly — that is the surface that exposes the
plan space (placement search, knob whitelists, objectives) this flat
signature cannot.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

from repro.ccl.select import CostModel
from repro.core.demand_builder import DemandParams
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig
from repro.net.topology import Topology
from repro.sched.tasks import Policy

from repro.codesign.api import CodesignProblem, plan
from repro.codesign.placement import Placement
from repro.codesign.report import CodesignReport, TaskChoice  # noqa: F401


def plan_iteration(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
                   topo: Topology, policy: Policy = "priority",
                   placement: Union[str, Placement] = "packed",
                   cost_model: Union[str, CostModel] = "flowsim",
                   dp_params: Optional[DemandParams] = None,
                   allow: Optional[Tuple[str, ...]] = None,
                   force: Optional[Dict[str, str]] = None,
                   hotspot_k: int = 8,
                   switch_capacity: Optional[int] = None,
                   error_budget: Union[float, Dict[str, float]] = 0.0,
                   bucket_bytes: Optional[int] = None,
                   decompose: Union[bool, Tuple[str, ...]] = False
                   ) -> CodesignReport:
    """Run one training iteration through the full co-design pipeline.

    ``placement``: a strategy name (packed/strided) or a pre-built
    Placement.  ``cost_model``: "flowsim" (price candidates on ``topo``),
    "alphabeta" (closed forms with params derived from ``topo``), or any
    CostModel.  ``force``: primitive -> algorithm overrides (e.g.
    ``{"all_reduce": "ring"}`` to measure what topology-blind flat-ring
    selection costs).  ``allow``: whitelist forwarded to selection.
    ``dp_params``: demand-shaping knobs (None = ``DemandParams()``,
    constructed per call).  ``switch_capacity``: per-switch in-network
    aggregation budget for the ``atp`` candidate (None = unlimited; see
    ``sched.atp``).  ``error_budget``: relative-error tolerance that
    admits compressed candidates (``repro.compress``) into selection — a
    float for every task, or a primitive -> budget dict (e.g.
    ``{"all_reduce": 0.01}`` to quantize gradient syncs while keeping
    activation collectives exact).  Default 0 = lossless only.
    ``bucket_bytes``/``decompose``: the overlap knobs — fused gradient
    buckets of that size, and the collective-matmul rewrite of TP
    collectives (see ``core.demand_builder``)."""
    return plan(CodesignProblem.from_kwargs(
        cfg, shape, mesh, topo, policy=policy, placement=placement,
        cost_model=cost_model, dp_params=dp_params, allow=allow,
        force=force, hotspot_k=hotspot_k, switch_capacity=switch_capacity,
        error_budget=error_budget, bucket_bytes=bucket_bytes,
        decompose=decompose))
