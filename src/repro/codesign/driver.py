"""End-to-end co-design driver: demand -> placement -> selection -> JCT.

``plan_iteration`` is the vertical slice through all five layers of the
paper's paradigm (Fig. 5a) with the cross-layer arrows actually wired:

  Para.   build_demand(cfg, shape, mesh)          logical CommDemand
  Place.  place_mesh(mesh, topo).place_demand()   physical device groups
  CCL     select_for_task(task, CostModel)        per-task algorithm
  Net.    FlowSim prices candidates on the real topology
  Sched.  simulate_iteration(...)                 JCT + exposed comm

The result is a :class:`CodesignReport`: JCT, exposed communication,
per-task algorithm choices and per-link hot spots — everything the layers
above and below would need to renegotiate (the paper's Sec. IV-A open
opportunity).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from repro.ccl.select import (AlphaBeta, CostModel, FlowSim, Selection,
                              flows_on_topology, select_for_task)
from repro.compress.codec import base_algorithm, codec_spec, split_algorithm
from repro.core.demand_builder import DemandParams, build_demand
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig
from repro.net.simulate import link_utilization
from repro.net.topology import Topology
from repro.sched.atp import aggregation_switches
from repro.sched.tasks import Policy, SimResult, simulate_iteration

from repro.codesign.placement import Placement, place_mesh


@dataclass
class TaskChoice:
    """One comm task's resolved placement + algorithm selection."""

    task_id: str
    primitive: str
    size_bytes: int
    group: Tuple[int, ...]
    algorithm: str
    cost_s: float
    costs: Dict[str, float] = field(default_factory=dict)
    # compression (repro.compress): the codec riding on the algorithm
    # (None = uncompressed) and its wire-byte ratio
    codec: Optional[str] = None
    wire_ratio: float = 1.0


@dataclass
class CodesignReport:
    """What the co-design pipeline hands back up the stack."""

    jct: float
    exposed_comm: float
    compute_time: float
    comm_time: float
    policy: str
    cost_model: str
    placement: Placement
    choices: List[TaskChoice] = field(default_factory=list)
    link_hotspots: List[Tuple[Tuple, float]] = field(default_factory=list)
    sim: Optional[SimResult] = None
    # compression accounting: the error budget selection ran under
    # (verbatim — a float, or the caller's primitive -> budget dict) and
    # the on-wire bytes saved vs running the same chosen schedules
    # uncompressed (summed over every communicator replica)
    error_budget: Union[float, Dict[str, float]] = 0.0
    wire_bytes_saved: float = 0.0

    @property
    def comm_fraction(self) -> float:
        return self.exposed_comm / self.jct if self.jct else 0.0

    def algorithms_by_primitive(self) -> Dict[str, Dict[str, int]]:
        """primitive -> {algorithm: task count} histogram."""
        out: Dict[str, Dict[str, int]] = {}
        for c in self.choices:
            hist = out.setdefault(c.primitive, {})
            hist[c.algorithm] = hist.get(c.algorithm, 0) + 1
        return out

    def codecs_by_primitive(self) -> Dict[str, Dict[str, int]]:
        """primitive -> {codec or 'none': task count} histogram."""
        out: Dict[str, Dict[str, int]] = {}
        for c in self.choices:
            hist = out.setdefault(c.primitive, {})
            key = c.codec or "none"
            hist[key] = hist.get(key, 0) + 1
        return out


def _model_capacity(model: CostModel) -> Optional[int]:
    """The in-network aggregation budget a cost model prices ``atp`` with
    (None = unlimited): FlowSim carries ``switch_capacity``, AlphaBeta
    ``params.atp_capacity``."""
    cap = getattr(model, "switch_capacity", None)
    if cap is None:
        cap = getattr(getattr(model, "params", None), "atp_capacity", None)
    return cap


def _resolve_cost_model(cost_model: Union[str, CostModel], topo: Topology,
                        switch_capacity: Optional[int] = None
                        ) -> Tuple[CostModel, str]:
    if not isinstance(cost_model, str):
        if switch_capacity is not None and \
                _model_capacity(cost_model) != switch_capacity:
            raise ValueError(
                "switch_capacity applies to the named cost models "
                "('flowsim' | 'alphabeta'); a CostModel instance must "
                "carry its own aggregation budget (e.g. "
                "FlowSim(topo, switch_capacity=...) or "
                "CostParams(atp_capacity=...))")
        return cost_model, type(cost_model).__name__.lower()
    if cost_model == "flowsim":
        return FlowSim(topo, switch_capacity=switch_capacity), "flowsim"
    if cost_model == "alphabeta":
        ab = AlphaBeta.from_topology(topo)
        if switch_capacity is not None:
            ab = dataclasses.replace(ab, params=dataclasses.replace(
                ab.params, atp_capacity=switch_capacity))
        return ab, "alphabeta"
    raise ValueError(f"unknown cost model {cost_model!r} "
                     f"(flowsim | alphabeta | a CostModel instance)")


def plan_iteration(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
                   topo: Topology, policy: Policy = "priority",
                   placement: Union[str, Placement] = "packed",
                   cost_model: Union[str, CostModel] = "flowsim",
                   dp_params: DemandParams = DemandParams(),
                   allow: Optional[Tuple[str, ...]] = None,
                   force: Optional[Dict[str, str]] = None,
                   hotspot_k: int = 8,
                   switch_capacity: Optional[int] = None,
                   error_budget: Union[float, Dict[str, float]] = 0.0
                   ) -> CodesignReport:
    """Run one training iteration through the full co-design pipeline.

    ``placement``: a strategy name (packed/strided) or a pre-built
    Placement.  ``cost_model``: "flowsim" (price candidates on ``topo``),
    "alphabeta" (closed forms with params derived from ``topo``), or any
    CostModel.  ``force``: primitive -> algorithm overrides (e.g.
    ``{"all_reduce": "ring"}`` to measure what topology-blind flat-ring
    selection costs).  ``allow``: whitelist forwarded to selection.
    ``switch_capacity``: per-switch in-network aggregation budget for the
    ``atp`` candidate (None = unlimited; see ``sched.atp``).
    ``error_budget``: relative-error tolerance that admits compressed
    candidates (``repro.compress``) into selection — a float for every
    task, or a primitive -> budget dict (e.g. ``{"all_reduce": 0.01}`` to
    quantize gradient syncs while keeping activation collectives exact).
    Default 0 = lossless only."""
    pl = placement if isinstance(placement, Placement) else \
        place_mesh(mesh, topo, strategy=placement)
    model, model_name = _resolve_cost_model(cost_model, topo,
                                            switch_capacity)
    # the aggregation budget selection actually priced atp with — an
    # instance cost model carries its own; the hot-spot map must match it
    agg_capacity = switch_capacity if switch_capacity is not None \
        else _model_capacity(model)

    demand = build_demand(cfg, shape, mesh, dp_params)
    placed = pl.place_demand(demand)

    def budget_of(primitive: str) -> float:
        if isinstance(error_budget, dict):
            return error_budget.get(primitive, 0.0)
        return error_budget

    # Per-task selection, memoized on the selection key — a 40-layer demand
    # repeats a handful of unique (primitive, size, group) combinations.
    sel_memo: Dict[Tuple, Selection] = {}
    choices: Dict[str, TaskChoice] = {}
    for task in placed.comm_tasks:
        key = (task.primitive, task.size_bytes, task.group)
        sel = sel_memo.get(key)
        if sel is None:
            forced = force.get(task.primitive) if force else None
            task_allow = (forced,) if forced else allow
            sel = select_for_task(task, model, allow=task_allow,
                                  error_budget=budget_of(task.primitive))
            sel_memo[key] = sel
        _, codec = split_algorithm(sel.algorithm)
        choices[task.task_id] = TaskChoice(
            task.task_id, task.primitive, task.size_bytes, task.group,
            sel.algorithm, sel.cost, sel.costs, codec=codec,
            wire_ratio=codec_spec(codec).wire_ratio if codec else 1.0)

    def comm_cost(task):
        c = choices[task.task_id]
        return c.cost_s, c.algorithm

    sim = simulate_iteration(placed, comm_cost, policy)

    # Hot-spot map.  The JCT simulation above prices one *representative*
    # communicator per task (all replicas along an axis run the same
    # collective concurrently), but the per-link byte map must cover every
    # replica or whole hosts would look idle.  Flowsets are memoized on the
    # same (primitive, algorithm, size, group) key selection dedups on.
    def replicas_of(task):
        if task.axis == "model":
            return len(pl.model_groups())
        if task.axis == "data":
            return len(pl.data_groups())
        return 1

    util: Dict[Tuple, float] = {}
    fs_memo: Dict[Tuple, object] = {}
    bytes_saved = 0.0
    for ltask, ptask in zip(demand.comm_tasks, placed.comm_tasks):
        choice = choices[ptask.task_id]
        algo = choice.algorithm
        for r in range(replicas_of(ltask)):
            group = ptask.group if r == 0 else \
                pl.place_group(ltask.group, ltask.axis, replica=r)
            key = (ltask.primitive, algo, ltask.size_bytes, group)
            fs = fs_memo.get(key)
            if fs is None:
                replica = dataclasses.replace(ptask, group=group)
                try:
                    fs = flows_on_topology(topo, replica, algo)
                except ValueError:
                    # replica-r's group can be shaped differently from the
                    # representative's (irregular placement); skip rather
                    # than mis-attribute its bytes
                    continue
                fs_memo[key] = fs
            agg = aggregation_switches(topo, group, agg_capacity) \
                if base_algorithm(algo) == "atp" else None
            for link, nbytes in link_utilization(topo, fs, agg).items():
                util[link] = util.get(link, 0.0) + nbytes
            if choice.codec:
                # vs the same schedule uncompressed (the wire-byte win the
                # compression layer hands the network layer)
                bytes_saved += fs.bytes_on_wire() \
                    * (1.0 / choice.wire_ratio - 1.0)
    hotspots = sorted(util.items(), key=lambda kv: -kv[1])[:hotspot_k]

    return CodesignReport(
        jct=sim.jct, exposed_comm=sim.exposed_comm,
        compute_time=sim.compute_time, comm_time=sim.comm_time,
        policy=policy, cost_model=model_name, placement=pl,
        choices=[choices[t.task_id] for t in placed.comm_tasks],
        link_hotspots=hotspots, sim=sim,
        error_budget=error_budget, wire_bytes_saved=bytes_saved)
