"""Plan artifacts: what the co-design pipeline hands back up the stack.

``TaskChoice`` and ``CodesignReport`` are the result types of
``codesign.api.plan`` (and of the ``plan_iteration`` adapter that wraps
it).  Both serialize to plain JSON — placements as device lists, link
hot spots as ``"u->v"`` string keys — so ``experiments/`` and
``benchmarks/`` can persist plans, and round-trip back via
``from_dict`` (the live ``SimResult`` is the one field that does not
survive the trip; its executed ``timeline`` does, so a loaded report
still renders its Perfetto trace via ``to_trace`` — everything the
layers above need survives).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from repro.core.types import MeshConfig
from repro.sched.tasks import SimResult

from repro.codesign.placement import Placement


# ---------------------------------------------------------------------------
# Shared metric registry (training + serving objectives)
# ---------------------------------------------------------------------------

# metric name -> maximize?  ``Objective`` (codesign.api) validates its
# ``minimize`` / ``tie_break`` / ``constraints`` names against this one
# registry, so training metrics (JCT, exposed comm, ...) and serving
# metrics (TTFT/TPOT percentiles, goodput — registered by
# ``codesign.serving`` at import) share the same namespace and the same
# unknown-metric error.
OBJECTIVE_METRICS: Dict[str, bool] = {
    "jct": False,
    "exposed_comm": False,
    "comm_time": False,
    "compute_time": False,
    "worst_link_bytes": False,
    "wire_bytes_saved": True,
}


def register_metric(name: str, maximize: bool = False) -> None:
    """Register an objective metric (idempotent; re-registering with a
    different direction is an error — one name, one meaning)."""
    prev = OBJECTIVE_METRICS.get(name)
    if prev is not None and prev != maximize:
        raise ValueError(
            f"metric {name!r} already registered with maximize={prev}")
    OBJECTIVE_METRICS[name] = maximize


def metric_value(report, name: str) -> float:
    """Read metric ``name`` off a report object, with the registry's
    unknown-metric error instead of a bare AttributeError."""
    if name not in OBJECTIVE_METRICS:
        raise ValueError(
            f"unknown objective metric {name!r}; valid metrics: "
            f"{sorted(OBJECTIVE_METRICS)}")
    try:
        return float(getattr(report, name))
    except AttributeError:
        raise ValueError(
            f"metric {name!r} is not defined on {type(report).__name__} "
            f"reports (it is registered for a different problem kind)")


@dataclass
class TaskChoice:
    """One comm task's resolved placement + algorithm selection."""

    task_id: str
    primitive: str
    size_bytes: int
    group: Tuple[int, ...]
    algorithm: str
    cost_s: float
    costs: Dict[str, float] = field(default_factory=dict)
    # compression (repro.compress): the codec riding on the algorithm
    # (None = uncompressed) and its wire-byte ratio
    codec: Optional[str] = None
    wire_ratio: float = 1.0

    def to_dict(self) -> Dict:
        return {
            "task_id": self.task_id, "primitive": self.primitive,
            "size_bytes": self.size_bytes, "group": list(self.group),
            "algorithm": self.algorithm, "cost_s": self.cost_s,
            "costs": dict(self.costs), "codec": self.codec,
            "wire_ratio": self.wire_ratio,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "TaskChoice":
        return cls(task_id=d["task_id"], primitive=d["primitive"],
                   size_bytes=d["size_bytes"], group=tuple(d["group"]),
                   algorithm=d["algorithm"], cost_s=d["cost_s"],
                   costs=dict(d["costs"]), codec=d["codec"],
                   wire_ratio=d["wire_ratio"])


def _link_key(link: Tuple) -> str:
    """A link tuple as a JSON object key: ``(0, 'host0')`` -> ``"0->host0"``."""
    return "->".join(str(n) for n in link)


def _parse_link_key(key: str) -> Tuple:
    """Inverse of :func:`_link_key` (integer node ids are restored)."""
    return tuple(int(p) if p.lstrip("-").isdigit() else p
                 for p in key.split("->"))


def _placement_to_dict(pl: Placement) -> Dict:
    m = pl.mesh
    return {
        "strategy": pl.strategy, "topology": pl.topology,
        "devices": list(pl.devices),
        "mesh": {"shape": list(m.shape), "axis_names": list(m.axis_names),
                 "data_axes": list(m.data_axes),
                 "model_axes": list(m.model_axes),
                 "pipeline_axis": m.pipeline_axis},
    }


def _placement_from_dict(d: Dict) -> Placement:
    m = d["mesh"]
    mesh = MeshConfig(shape=tuple(m["shape"]),
                      axis_names=tuple(m["axis_names"]),
                      data_axes=tuple(m["data_axes"]),
                      model_axes=tuple(m["model_axes"]),
                      pipeline_axis=m.get("pipeline_axis"))
    return Placement(mesh=mesh, devices=tuple(d["devices"]),
                     strategy=d["strategy"], topology=d["topology"])


@dataclass
class CodesignReport:
    """What the co-design pipeline hands back up the stack."""

    jct: float
    exposed_comm: float
    compute_time: float
    comm_time: float
    policy: str
    cost_model: str
    placement: Placement
    choices: List[TaskChoice] = field(default_factory=list)
    link_hotspots: List[Tuple[Tuple, float]] = field(default_factory=list)
    sim: Optional[SimResult] = None
    # compression accounting: the error budget selection ran under
    # (verbatim — a float, or the caller's primitive -> budget dict) and
    # the on-wire bytes saved vs running the same chosen schedules
    # uncompressed (summed over every communicator replica)
    error_budget: Union[float, Dict[str, float]] = 0.0
    wire_bytes_saved: float = 0.0
    # per-task exposure attribution from the scheduler: seconds compute
    # stalled waiting on each comm task (sums to ``exposed_comm``) —
    # the per-edge accounting the overlap search optimizes against
    task_exposed_s: Dict[str, float] = field(default_factory=dict)
    # the executed schedule (``SimResult.timeline`` verbatim): persisted —
    # unlike the live ``sim`` — so a from_dict-loaded report still renders
    # its Perfetto trace (``to_trace``)
    timeline: List[Tuple[str, float, float]] = field(default_factory=list)

    @property
    def comm_fraction(self) -> float:
        return self.exposed_comm / self.jct if self.jct else 0.0

    @property
    def worst_link_bytes(self) -> float:
        """Bytes on the hottest link — the load-imbalance metric the
        Objective can minimize or constrain."""
        return self.link_hotspots[0][1] if self.link_hotspots else 0.0

    @property
    def synthesized_choices(self) -> List["TaskChoice"]:
        """The tasks the plan's synthesis pass won (algorithm
        ``synthesized`` or a compressed variant) — what to lower with
        ``ccl.primitives.synthesized_collective``; empty when synthesis
        was off or never beat the registry."""
        return [c for c in self.choices
                if c.algorithm.split("+")[0] == "synthesized"]

    def algorithms_by_primitive(self) -> Dict[str, Dict[str, int]]:
        """primitive -> {algorithm: task count} histogram."""
        out: Dict[str, Dict[str, int]] = {}
        for c in self.choices:
            hist = out.setdefault(c.primitive, {})
            hist[c.algorithm] = hist.get(c.algorithm, 0) + 1
        return out

    def top_exposed_tasks(self, k: int = 8) -> List[Tuple[str, float]]:
        """The k comm tasks compute stalled on longest (hot-task
        attribution, no timeline digging required)."""
        hot = [(t, s) for t, s in self.task_exposed_s.items() if s > 0]
        hot.sort(key=lambda ts: (-ts[1], ts[0]))
        return hot[:k]

    def codecs_by_primitive(self) -> Dict[str, Dict[str, int]]:
        """primitive -> {codec or 'none': task count} histogram."""
        out: Dict[str, Dict[str, int]] = {}
        for c in self.choices:
            hist = out.setdefault(c.primitive, {})
            key = c.codec or "none"
            hist[key] = hist.get(key, 0) + 1
        return out

    # ------------------------------------------------------------------
    # JSON persistence
    # ------------------------------------------------------------------

    def to_dict(self) -> Dict:
        """Plain-JSON form: placement as a device list, hot spots as
        ``"u->v"`` keys (insertion order keeps the hottest-first sort).
        ``sim`` is intentionally dropped — it holds the live task-graph
        trace, not plan state."""
        budget = self.error_budget
        return {
            "jct": self.jct, "exposed_comm": self.exposed_comm,
            "compute_time": self.compute_time, "comm_time": self.comm_time,
            "policy": self.policy, "cost_model": self.cost_model,
            "placement": _placement_to_dict(self.placement),
            "choices": [c.to_dict() for c in self.choices],
            "link_hotspots": {_link_key(l): b
                              for l, b in self.link_hotspots},
            "error_budget": dict(budget) if isinstance(budget, dict)
            else budget,
            "wire_bytes_saved": self.wire_bytes_saved,
            "task_exposed_s": dict(self.task_exposed_s),
            "timeline": [[n, s, e] for n, s, e in self.timeline],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CodesignReport":
        budget = d["error_budget"]
        return cls(
            jct=d["jct"], exposed_comm=d["exposed_comm"],
            compute_time=d["compute_time"], comm_time=d["comm_time"],
            policy=d["policy"], cost_model=d["cost_model"],
            placement=_placement_from_dict(d["placement"]),
            choices=[TaskChoice.from_dict(c) for c in d["choices"]],
            link_hotspots=[(_parse_link_key(k), b)
                           for k, b in d["link_hotspots"].items()],
            sim=None,
            error_budget=dict(budget) if isinstance(budget, dict)
            else budget,
            wire_bytes_saved=d["wire_bytes_saved"],
            task_exposed_s=dict(d.get("task_exposed_s", {})),
            timeline=[(n, s, e) for n, s, e in d.get("timeline", [])])

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def to_trace(self, topo=None, **kw):
        """This plan as a Perfetto-loadable ``repro.obs.trace.Trace``:
        compute / comm / exposed-comm tracks from the persisted timeline,
        plus per-link utilization counters when the live ``Topology`` is
        passed.  Works identically on a ``from_dict``-loaded report."""
        from repro.obs.trace import trace_from_report
        return trace_from_report(self.to_dict(), topo=topo, **kw)
