"""Serving co-design: inference as a first-class workload in the plan space.

The engine so far optimizes training JCT; the survey's workload-dependence
argument (communication scheduling must fit the traffic class) means
latency-SLO serving needs its own demand shape, its own objective, and its
own time base:

  * **Demand** — a request is a *prefill* phase (full-sequence forward on a
    prefill group: TP All-Reduce per layer, MoE All-to-All), a *KV hand-off*
    (each prefill rank ships its KV-cache shard to a decode rank — a ``p2p``
    CommTask routed through ``net.Topology`` like any collective), and a
    *decode* loop (one-token steps on a decode group under continuous
    batching).  Both phase graphs are priced through the same
    ``ccl.select`` / ``sched.tasks`` pipeline as training iterations.
  * **Objective** — TTFT/TPOT percentiles and goodput under an open-loop
    arrival process (``sched.arrivals``), not JCT.  The metrics register
    into the shared registry (``codesign.report.OBJECTIVE_METRICS``) so
    ``Objective(minimize="ttft_p99", constraints={"tpot_p99": ...})`` is
    validated exactly like a training objective.
  * **Time base** — arrivals are open-loop, so ``plan_serving`` runs a
    deterministic queueing simulation: FIFO prefill batching, slot-based
    continuous-batching decode, with co-tenant training pulses
    (:class:`CotenantPulse`) contending on shared links under the same
    rate law as ``sched.flows`` (rate = min over links of 1/total demand).
    The ``stagger`` knob shifts the co-tenant pulses' phase against the
    serving admission clock — the CASSINI lever, now SLO-aware.

``serving_problem(spec, topo)`` builds a ``CodesignProblem`` whose
``plan()``/``search()`` speak :class:`ServingReport` instead of
``CodesignReport``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.ccl.select import CostModel, Selection, flows_on_topology, \
    select_for_task
from repro.compress.codec import codec_spec, split_algorithm
from repro.core.demand import CommDemand, CommTask
from repro.core.demand_builder import DemandParams, build_demand
from repro.core.types import MeshConfig, ModelConfig, ShapeConfig
from repro.net.simulate import link_utilization
from repro.net.topology import Topology
from repro.sched.arrivals import (Arrival, arrivals_from_dict,
                                  arrivals_to_dict, offered_load)
from repro.sched.tasks import simulate_iteration

from repro.codesign.api import (CodesignProblem, Objective, PlanSpace,
                                _resolve_cost_model)
from repro.codesign.placement import Placement, place_mesh
from repro.codesign.report import (CodesignReport, TaskChoice, _link_key,
                                   _parse_link_key, register_metric)

# SLO metrics join the shared objective registry at import (the codesign
# package imports this module, so `Objective(minimize="ttft_p99")` works
# as soon as `repro.codesign` is loaded).  True = bigger-is-better.
SERVING_METRICS: Dict[str, bool] = {
    "ttft_p50": False, "ttft_p95": False, "ttft_p99": False,
    "tpot_p50": False, "tpot_p99": False,
    "goodput": True, "slo_attainment": True,
}
for _name, _maximize in SERVING_METRICS.items():
    register_metric(_name, maximize=_maximize)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServingSLO:
    """Latency targets a request must meet to count toward goodput:
    time-to-first-token and time-per-output-token, both in seconds."""

    ttft_s: float = 0.5
    tpot_s: float = 0.05

    def to_dict(self) -> Dict[str, float]:
        return {"ttft_s": self.ttft_s, "tpot_s": self.tpot_s}

    @classmethod
    def from_dict(cls, d: Mapping[str, float]) -> "ServingSLO":
        return cls(ttft_s=float(d["ttft_s"]), tpot_s=float(d["tpot_s"]))


@dataclass(frozen=True)
class CotenantPulse:
    """A co-tenant training job's periodic communication pulse as the
    serving tenant sees it: every ``period_s`` seconds, for ``comm_s``
    seconds starting at ``phase_s``, the tenant loads the listed links
    with ``demand`` (fraction of link bandwidth, the ``sched.flows``
    convention)."""

    name: str
    period_s: float
    comm_s: float
    phase_s: float = 0.0
    demand: Mapping[Tuple, float] = field(default_factory=dict)

    def __post_init__(self):
        if self.period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {self.period_s}")
        if self.comm_s < 0:
            raise ValueError(f"comm_s must be >= 0, got {self.comm_s}")

    def active_at(self, t: float) -> bool:
        return (t - self.phase_s) % self.period_s < self.comm_s

    def next_boundary(self, t: float) -> float:
        """Next instant the pulse turns on or off after ``t``."""
        u = (t - self.phase_s) % self.period_s
        if u < self.comm_s:
            return t + (self.comm_s - u)
        return t + (self.period_s - u)

    def to_dict(self) -> Dict[str, object]:
        return {"name": self.name, "period_s": self.period_s,
                "comm_s": self.comm_s, "phase_s": self.phase_s,
                "demand": {_link_key(l): f for l, f in self.demand.items()}}

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "CotenantPulse":
        return cls(name=str(d["name"]), period_s=float(d["period_s"]),
                   comm_s=float(d["comm_s"]), phase_s=float(d["phase_s"]),
                   demand={_parse_link_key(k): float(f)
                           for k, f in dict(d["demand"]).items()})


@dataclass(frozen=True)
class ServingSpec:
    """One serving tenant: the model, its prefill/decode disaggregation,
    the offered load, and the SLO it must hold.

    ``prompt_tokens``/``decode_tokens`` are the *representative* request
    mix the phase graphs are priced at (per-request budgets in a trace may
    vary; timing uses each arrival's own decode budget).  ``cotenants``
    are the training pulses sharing this tenant's fabric — ``plan_cluster``
    fills them from the co-scheduled jobs' link demand maps."""

    name: str
    cfg: ModelConfig
    prefill_devices: int
    decode_devices: int
    arrivals: object  # PoissonArrivals | TraceArrivals
    slo: ServingSLO = field(default_factory=ServingSLO)
    prompt_tokens: int = 0   # 0 -> from the arrival process (or 512)
    decode_tokens: int = 0   # 0 -> from the arrival process (or 128)
    prefill_batch: int = 4
    decode_slots: int = 16
    horizon_s: float = 10.0
    cotenants: Tuple[CotenantPulse, ...] = ()
    dp_params: DemandParams = field(default_factory=DemandParams)

    def __post_init__(self):
        if self.prefill_devices < 1 or self.decode_devices < 1:
            raise ValueError("serving needs >=1 prefill and >=1 decode "
                             "device")
        if self.prefill_batch < 1 or self.decode_slots < 1:
            raise ValueError("prefill_batch and decode_slots must be >= 1")
        if not self.prompt_tokens:
            object.__setattr__(self, "prompt_tokens",
                               getattr(self.arrivals, "prompt_tokens", 512))
        if not self.decode_tokens:
            object.__setattr__(self, "decode_tokens",
                               getattr(self.arrivals, "decode_tokens", 128))

    @property
    def num_devices(self) -> int:
        return self.prefill_devices + self.decode_devices

    def mesh(self) -> MeshConfig:
        """The carve mesh: one flat ``serve`` axis over prefill + decode
        devices (the placement layer maps it onto the topology; rank-wise
        groups keep prefill ranks 0..P-1 and decode ranks P..P+D-1)."""
        return MeshConfig(shape=(self.num_devices,), axis_names=("serve",),
                          data_axes=(), model_axes=("serve",))

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.name, "model": self.cfg.name,
            "prefill_devices": self.prefill_devices,
            "decode_devices": self.decode_devices,
            "arrivals": arrivals_to_dict(self.arrivals),
            "slo": self.slo.to_dict(),
            "prompt_tokens": self.prompt_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_batch": self.prefill_batch,
            "decode_slots": self.decode_slots,
            "horizon_s": self.horizon_s,
            "cotenants": [c.to_dict() for c in self.cotenants],
        }


def kv_bytes_per_token(cfg: ModelConfig, act_bytes: int = 2) -> int:
    """KV-cache footprint of one token across the whole stack — the
    payload the prefill->decode hand-off moves per prompt token.  MLA
    caches the compressed latent (+ rope key) per layer; GQA caches
    K and V per kv-head.  Mamba layers keep recurrent state instead of a
    token-indexed cache and contribute nothing per-token."""
    if cfg.attention == "mla":
        per_layer = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    else:
        per_layer = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
    n_attn = sum(1 for s in cfg.layer_specs()
                 if s.mixer in ("attn", "cross_attn"))
    return int(n_attn * per_layer * act_bytes)


# ---------------------------------------------------------------------------
# Phase pricing: a placed serving demand through the CCL + sched layers
# ---------------------------------------------------------------------------


def _price_phase(placed: CommDemand, pl: Placement, model: CostModel,
                 space: PlanSpace, policy: str, model_name: str,
                 topo: Topology, hotspot_k: int,
                 error_budget: Union[float, Dict[str, float]]
                 ) -> Tuple[CodesignReport, Dict[Tuple, float]]:
    """One serving phase graph (prefill batch or decode step) through
    per-task selection, iteration simulation, and link accounting —
    ``codesign.api.plan``'s core, for a pre-built placed demand (serving
    groups are rank-wise, so there is no replica fan-out)."""

    def budget_of(primitive: str) -> float:
        if isinstance(error_budget, dict):
            return error_budget.get(primitive, 0.0)
        return error_budget

    sel_memo: Dict[Tuple, Selection] = {}
    choices: Dict[str, TaskChoice] = {}
    for task in placed.comm_tasks:
        key = (task.primitive, task.size_bytes, task.group)
        sel = sel_memo.get(key)
        if sel is None:
            sel = select_for_task(
                task, model, constraint=space.constraint_for(task.primitive),
                error_budget=budget_of(task.primitive))
            sel_memo[key] = sel
        _, codec = split_algorithm(sel.algorithm)
        choices[task.task_id] = TaskChoice(
            task.task_id, task.primitive, task.size_bytes, task.group,
            sel.algorithm, sel.cost, sel.costs, codec=codec,
            wire_ratio=codec_spec(codec).wire_ratio if codec else 1.0)

    sim = simulate_iteration(
        placed, lambda t: (choices[t.task_id].cost_s,
                           choices[t.task_id].algorithm), policy)

    util: Dict[Tuple, float] = {}
    fs_memo: Dict[Tuple, object] = {}
    for task in placed.comm_tasks:
        algo = choices[task.task_id].algorithm
        key = (task.primitive, algo, task.size_bytes, task.group)
        fs = fs_memo.get(key)
        if fs is None:
            fs = flows_on_topology(topo, task, algo)
            fs_memo[key] = fs
        for link, nbytes in link_utilization(topo, fs).items():
            util[link] = util.get(link, 0.0) + nbytes
    hotspots = sorted(util.items(), key=lambda kv: -kv[1])[:hotspot_k]

    report = CodesignReport(
        jct=sim.jct, exposed_comm=sim.exposed_comm,
        compute_time=sim.compute_time, comm_time=sim.comm_time,
        policy=policy, cost_model=model_name, placement=pl,
        choices=[choices[t.task_id] for t in placed.comm_tasks],
        link_hotspots=hotspots, sim=sim, error_budget=error_budget,
        task_exposed_s=dict(sim.task_exposed_s),
        timeline=list(sim.timeline))
    return report, util


# ---------------------------------------------------------------------------
# Contention-aware time advance (the sched.flows rate law, open-loop)
# ---------------------------------------------------------------------------


def _advance(t: float, compute_s: float, comm_s: float,
             demand: Mapping[Tuple, float],
             pulses: Sequence[CotenantPulse]) -> float:
    """Finish time of one serving work item started at ``t``: compute
    first (never contended), then ``comm_s`` of communication slowed by
    whichever co-tenant pulses are active on shared links.  Same rate law
    as ``sched.flows._simulate_links``: rate = min over the phase's links
    of min(1, 1 / total demand), piecewise-constant between pulse
    boundaries."""
    t += compute_s
    remaining = comm_s
    if remaining <= 0.0:
        return t
    live = [p for p in pulses
            if p.comm_s > 0 and any(l in demand for l in p.demand)]
    if not live or not demand:
        return t + remaining
    guard = 0
    while remaining > 1e-12:
        guard += 1
        if guard > 1_000_000:
            raise RuntimeError("serving contention advance livelock")
        rate = 1.0
        for link, f in demand.items():
            tot = f
            for p in live:
                if link in p.demand and p.active_at(t):
                    tot += p.demand[link]
            if tot > 1.0:
                rate = min(rate, 1.0 / tot)
        nb = min(p.next_boundary(t) for p in live)
        # fp guard: a boundary can land on t to within rounding, which
        # would advance neither t nor remaining — force progress
        nb = max(nb, t + max(abs(t), 1.0) * 1e-12)
        if t + remaining / rate <= nb + 1e-15:
            return t + remaining / rate
        remaining -= (nb - t) * rate
        t = nb
    return t


def _percentile(vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not vals:
        return 0.0
    s = sorted(vals)
    k = max(0, min(len(s) - 1, math.ceil(q * len(s)) - 1))
    return s[k]


# ---------------------------------------------------------------------------
# ServingReport
# ---------------------------------------------------------------------------


@dataclass
class ServingReport:
    """What ``plan_serving`` hands back: SLO metrics under the arrival
    process, per-request lifecycle spans, and the priced phase reports.

    Exposes the registered serving metrics (``ttft_p99``, ``goodput``,
    ...) plus the training-metric names the search bookkeeping reads
    (``jct`` = mean end-to-end request latency — the documented stand-in;
    ``exposed_comm``; ``worst_link_bytes``), so a serving problem drops
    into ``search()`` unchanged."""

    name: str
    cost_model: str
    slo: ServingSLO
    stagger_s: float
    horizon_s: float
    offered_rps: float
    goodput_rps: float
    slo_attainment: float
    ttft: Dict[str, float]
    tpot: Dict[str, float]
    kv_bytes_per_request: int
    # per-request lifecycle: rid, t_arrive, t_prefill (admission into the
    # prefill batch), t_first (first output token), t_finish, ttft, tpot,
    # slo_ok — the spans trace_from_serving renders
    requests: List[Dict[str, object]] = field(default_factory=list)
    prefill: Optional[CodesignReport] = None
    decode: Optional[CodesignReport] = None
    link_hotspots: List[Tuple[Tuple, float]] = field(default_factory=list)

    # -- registered serving metrics ------------------------------------
    @property
    def ttft_p50(self) -> float:
        return self.ttft["p50"]

    @property
    def ttft_p95(self) -> float:
        return self.ttft["p95"]

    @property
    def ttft_p99(self) -> float:
        return self.ttft["p99"]

    @property
    def tpot_p50(self) -> float:
        return self.tpot["p50"]

    @property
    def tpot_p99(self) -> float:
        return self.tpot["p99"]

    @property
    def goodput(self) -> float:
        return self.goodput_rps

    # -- training-metric views for the shared search bookkeeping -------
    @property
    def jct(self) -> float:
        """Mean end-to-end request latency (arrival -> last token) — the
        closest JCT analogue an open-loop workload has."""
        if not self.requests:
            return 0.0
        return sum(r["t_finish"] - r["t_arrive"] for r in self.requests) \
            / len(self.requests)

    @property
    def exposed_comm(self) -> float:
        pf = self.prefill.exposed_comm if self.prefill else 0.0
        dc = self.decode.exposed_comm if self.decode else 0.0
        return pf + dc

    @property
    def comm_time(self) -> float:
        pf = self.prefill.comm_time if self.prefill else 0.0
        dc = self.decode.comm_time if self.decode else 0.0
        return pf + dc

    @property
    def compute_time(self) -> float:
        pf = self.prefill.compute_time if self.prefill else 0.0
        dc = self.decode.compute_time if self.decode else 0.0
        return pf + dc

    @property
    def worst_link_bytes(self) -> float:
        return self.link_hotspots[0][1] if self.link_hotspots else 0.0

    def slo_violations(self) -> List[Dict[str, object]]:
        """The requests that missed the SLO (for traces and debugging)."""
        return [r for r in self.requests if not r["slo_ok"]]

    # -- JSON persistence ----------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "name": self.name, "cost_model": self.cost_model,
            "slo": self.slo.to_dict(), "stagger_s": self.stagger_s,
            "horizon_s": self.horizon_s, "offered_rps": self.offered_rps,
            "goodput_rps": self.goodput_rps,
            "slo_attainment": self.slo_attainment,
            "ttft": dict(self.ttft), "tpot": dict(self.tpot),
            "kv_bytes_per_request": self.kv_bytes_per_request,
            "requests": [dict(r) for r in self.requests],
            "prefill": self.prefill.to_dict() if self.prefill else None,
            "decode": self.decode.to_dict() if self.decode else None,
            "link_hotspots": {_link_key(l): b
                              for l, b in self.link_hotspots},
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ServingReport":
        return cls(
            name=d["name"], cost_model=d["cost_model"],
            slo=ServingSLO.from_dict(d["slo"]),
            stagger_s=d["stagger_s"], horizon_s=d["horizon_s"],
            offered_rps=d["offered_rps"], goodput_rps=d["goodput_rps"],
            slo_attainment=d["slo_attainment"],
            ttft=dict(d["ttft"]), tpot=dict(d["tpot"]),
            kv_bytes_per_request=d["kv_bytes_per_request"],
            requests=[dict(r) for r in d["requests"]],
            prefill=CodesignReport.from_dict(d["prefill"])
            if d.get("prefill") else None,
            decode=CodesignReport.from_dict(d["decode"])
            if d.get("decode") else None,
            link_hotspots=[(_parse_link_key(k), b)
                           for k, b in d["link_hotspots"].items()])

    def to_trace(self, topo=None, **kw):
        """Request-lifetime spans + SLO-violation instants as a
        Perfetto-loadable ``repro.obs.trace.Trace``."""
        from repro.obs.trace import trace_from_serving
        return trace_from_serving(self.to_dict(), topo=topo, **kw)


# ---------------------------------------------------------------------------
# plan_serving
# ---------------------------------------------------------------------------


def plan_serving(problem: CodesignProblem,
                 _resolved: Optional[Tuple[CostModel, str]] = None
                 ) -> ServingReport:
    """Price one serving plan end to end:

      1. carve prefill + decode groups from the placement knob;
      2. build + price the prefill batch graph (TP collectives, MoE
         All-to-All, per-rank KV ``p2p`` hand-off) and the one-token
         decode step graph through the shared CCL/network layers;
      3. replay the arrival process through a deterministic queueing
         simulation — FIFO prefill batching, slot-based continuous
         decode — with co-tenant pulses contending on shared links
         (shifted by the ``stagger`` knob);
      4. fold per-request TTFT/TPOT into percentiles, goodput, and SLO
         attainment."""
    spec = problem.serving
    if spec is None:
        raise ValueError("plan_serving needs problem.serving "
                         "(a ServingSpec); use serving_problem(...)")
    space = problem.space
    free = space.free_knobs()
    if free:
        raise ValueError(
            f"plan_serving() needs every scalar knob Fixed, but "
            f"{sorted(free)} are free — use search(problem)")
    topo = problem.topo
    placement = space.placement.value
    policy = space.policy.value
    error_budget = space.error_budget.value
    switch_capacity = space.switch_capacity.value
    stagger = float(space.stagger.value or 0.0)

    P, D = spec.prefill_devices, spec.decode_devices
    mesh = spec.mesh()
    pl = placement if isinstance(placement, Placement) else \
        place_mesh(mesh, topo, strategy=placement)
    if len(pl.devices) != P + D:
        raise ValueError(
            f"serving placement covers {len(pl.devices)} devices but spec "
            f"{spec.name} needs {P}+{D}")
    model, model_name = _resolved if _resolved is not None else \
        _resolve_cost_model(problem.cost_model, topo, switch_capacity)
    prefill_dev = pl.devices[:P]
    decode_dev = pl.devices[P:]

    # --- phase graphs -----------------------------------------------------
    pf_mesh = MeshConfig(shape=(P,), axis_names=("model",), data_axes=(),
                         model_axes=("model",))
    pf_shape = ShapeConfig(f"{spec.name}-prefill", spec.prompt_tokens,
                           spec.prefill_batch, "prefill")
    pf_demand = build_demand(spec.cfg, pf_shape, pf_mesh, spec.dp_params)
    pf_pl = Placement(mesh=pf_mesh, devices=prefill_dev,
                      strategy=pl.strategy, topology=topo.name)
    pf_placed = pf_pl.place_demand(pf_demand)
    pf_placed.comm_tasks = [dataclasses.replace(t, phase="prefill")
                            for t in pf_placed.comm_tasks]
    kv_req = spec.prompt_tokens * kv_bytes_per_token(
        spec.cfg, spec.dp_params.act_bytes)
    kv_batch = spec.prefill_batch * kv_req
    for i in range(P):
        src, dst = prefill_dev[i], decode_dev[i % D]
        pf_placed.comm_tasks.append(CommTask(
            f"kv{i}", "p2p", max(1, kv_batch // P), (src, dst),
            after_compute=("head",), job_id=pf_placed.job_id, phase="kv"))

    dec_mesh = MeshConfig(shape=(D,), axis_names=("model",), data_axes=(),
                          model_axes=("model",))
    dec_shape = ShapeConfig(f"{spec.name}-decode", 1, spec.decode_slots,
                            "decode")
    dec_demand = build_demand(spec.cfg, dec_shape, dec_mesh, spec.dp_params)
    dec_pl = Placement(mesh=dec_mesh, devices=decode_dev,
                       strategy=pl.strategy, topology=topo.name)
    dec_placed = dec_pl.place_demand(dec_demand)
    dec_placed.comm_tasks = [dataclasses.replace(t, phase="decode")
                             for t in dec_placed.comm_tasks]

    prefill_report, pf_util = _price_phase(
        pf_placed, pf_pl, model, space, policy, model_name, topo,
        problem.hotspot_k, error_budget)
    decode_report, dec_util = _price_phase(
        dec_placed, dec_pl, model, space, policy, model_name, topo,
        problem.hotspot_k, error_budget)

    # --- per-phase link demand fractions (the sched.flows convention) -----
    def fracs(util: Dict[Tuple, float], comm_s: float) -> Dict[Tuple, float]:
        out: Dict[Tuple, float] = {}
        for link, nbytes in util.items():
            bw = topo.link_bw(*link)
            if comm_s > 0 and bw > 0:
                out[link] = min(1.0, nbytes / (bw * comm_s))
        return out

    pf_comm = min(prefill_report.comm_time, prefill_report.jct)
    pf_compute = max(0.0, prefill_report.jct - pf_comm)
    pf_fracs = fracs(pf_util, pf_comm)
    dec_comm = min(decode_report.comm_time, decode_report.jct)
    dec_compute = max(0.0, decode_report.jct - dec_comm)
    dec_fracs = fracs(dec_util, dec_comm)

    pulses = tuple(dataclasses.replace(p, phase_s=p.phase_s + stagger)
                   for p in spec.cotenants)

    # --- open-loop queueing simulation ------------------------------------
    arrivals = tuple(spec.arrivals.sample(spec.horizon_s))
    recs: Dict[str, Dict[str, object]] = {}

    # prefill: FIFO server, batches of up to prefill_batch
    pending: List[Arrival] = []
    done_prefill: List[Tuple[float, Arrival]] = []
    i = 0
    t_free = 0.0
    while i < len(arrivals) or pending:
        if not pending:
            t_free = max(t_free, arrivals[i].t)
        while i < len(arrivals) and arrivals[i].t <= t_free + 1e-12:
            pending.append(arrivals[i])
            i += 1
        batch = pending[:spec.prefill_batch]
        del pending[:len(batch)]
        finish = _advance(t_free, pf_compute, pf_comm, pf_fracs, pulses)
        for a in batch:
            recs[a.rid] = {"rid": a.rid, "t_arrive": a.t,
                           "t_prefill": t_free, "t_first": None,
                           "t_finish": None}
            done_prefill.append((finish, a))
        t_free = finish

    # decode: slot-based continuous batching, variable step duration
    done_prefill.sort(key=lambda fa: (fa[0], fa[1].rid))
    active: Dict[str, int] = {}
    started: Dict[str, float] = {}
    j = 0
    t = 0.0
    while j < len(done_prefill) or active:
        if not active:
            t = max(t, done_prefill[j][0])
        while j < len(done_prefill) and \
                done_prefill[j][0] <= t + 1e-12 and \
                len(active) < spec.decode_slots:
            ready, a = done_prefill[j]
            active[a.rid] = max(1, a.decode_tokens)
            started[a.rid] = t
            j += 1
        step_end = _advance(t, dec_compute, dec_comm, dec_fracs, pulses)
        for rid in list(active):
            rec = recs[rid]
            if rec["t_first"] is None:
                rec["t_first"] = step_end
            active[rid] -= 1
            if active[rid] == 0:
                rec["t_finish"] = step_end
                del active[rid]
        t = step_end

    # --- SLO accounting ---------------------------------------------------
    requests: List[Dict[str, object]] = []
    ttfts: List[float] = []
    tpots: List[float] = []
    ok = 0
    for a in arrivals:
        rec = recs[a.rid]
        ttft = rec["t_first"] - rec["t_arrive"]
        steps = max(1, a.decode_tokens)
        tpot = (rec["t_finish"] - started[a.rid]) / steps
        slo_ok = ttft <= spec.slo.ttft_s and tpot <= spec.slo.tpot_s
        rec.update(ttft=ttft, tpot=tpot, slo_ok=slo_ok)
        requests.append(rec)
        ttfts.append(ttft)
        tpots.append(tpot)
        ok += int(slo_ok)

    def dist(vals: List[float]) -> Dict[str, float]:
        return {"mean": sum(vals) / len(vals) if vals else 0.0,
                "p50": _percentile(vals, 0.50),
                "p95": _percentile(vals, 0.95),
                "p99": _percentile(vals, 0.99)}

    util: Dict[Tuple, float] = dict(pf_util)
    for link, nbytes in dec_util.items():
        util[link] = util.get(link, 0.0) + nbytes
    hotspots = sorted(util.items(),
                      key=lambda kv: -kv[1])[:problem.hotspot_k]

    return ServingReport(
        name=spec.name, cost_model=model_name, slo=spec.slo,
        stagger_s=stagger, horizon_s=spec.horizon_s,
        offered_rps=offered_load(arrivals, spec.horizon_s),
        goodput_rps=ok / spec.horizon_s if spec.horizon_s > 0 else 0.0,
        slo_attainment=ok / len(arrivals) if arrivals else 1.0,
        ttft=dist(ttfts), tpot=dist(tpots),
        kv_bytes_per_request=kv_req, requests=requests,
        prefill=prefill_report, decode=decode_report,
        link_hotspots=hotspots)


def serving_problem(spec: ServingSpec, topo: Topology,
                    space: Optional[PlanSpace] = None,
                    objective: Optional[Objective] = None,
                    cost_model: Union[str, CostModel] = "flowsim",
                    hotspot_k: int = 8) -> CodesignProblem:
    """A ``CodesignProblem`` for one serving tenant.  The default
    objective minimizes p99 TTFT (tie-broken by p99 TPOT then goodput)
    under the spec's SLO as feasibility constraints, so ``search()``
    returns SLO-feasible plans or raises with the binding constraint."""
    if objective is None:
        objective = Objective(
            minimize="ttft_p99", tie_break=("tpot_p99", "goodput"),
            constraints={"ttft_p99": spec.slo.ttft_s,
                         "tpot_p99": spec.slo.tpot_s})
    shape = ShapeConfig(f"{spec.name}-serve", spec.prompt_tokens,
                        spec.prefill_batch, "prefill")
    return CodesignProblem(
        cfg=spec.cfg, shape=shape, mesh=spec.mesh(), topo=topo,
        space=space if space is not None else PlanSpace(),
        objective=objective, cost_model=cost_model, hotspot_k=hotspot_k,
        serving=spec)
