"""Executable collective primitives: ring algorithms as shard_map programs.

The flow-schedule generators in ``algorithms.py`` describe traffic; this
module *executes* the same algorithms with ``jax.lax.ppermute`` so the CCL
layer is a real, swappable implementation (validated against ``psum`` /
``all_gather`` in tests, on a multi-device host platform).

On a TPU torus these manual schedules are also how the §Perf collective-
matmul overlap is built: the per-step ppermute structure gives XLA's
latency-hiding scheduler independent chunks to overlap with compute.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def _pad_to(x: jax.Array, p: int):
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % p
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n, pad


def ring_all_reduce(x: jax.Array, axis_name: str, axis_size: int
                    ) -> jax.Array:
    """Ring All-Reduce: (p-1) reduce-scatter + (p-1) all-gather ppermute
    steps.  Per-rank wire bytes: 2 n (p-1)/p — bandwidth-optimal."""
    p = axis_size
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    flat, n, _ = _pad_to(x, p)
    chunks = flat.reshape(p, -1)
    right = [(i, (i + 1) % p) for i in range(p)]

    # ---- reduce-scatter ----
    buf = jnp.take(chunks, idx, axis=0)
    for s in range(p - 1):
        buf = lax.ppermute(buf, axis_name, right) \
            + jnp.take(chunks, (idx - s - 1) % p, axis=0)
    # buf = fully-reduced chunk (idx + 1) % p

    # ---- all-gather ----
    out = jnp.zeros_like(chunks)
    out = _dyn_set(out, (idx + 1) % p, buf)
    g = buf
    for s in range(p - 1):
        g = lax.ppermute(g, axis_name, right)
        out = _dyn_set(out, (idx - s) % p, g)
    return out.reshape(-1)[:n].reshape(x.shape)


def _dyn_set(arr, i, val):
    return lax.dynamic_update_slice_in_dim(arr, val[None], i, axis=0)


def bidir_ring_all_reduce(x: jax.Array, axis_name: str, axis_size: int
                          ) -> jax.Array:
    """Two opposite half-rings (NCCL dual-channel): halves the per-link
    bytes, using both directions of a torus link."""
    p = axis_size
    if p == 1:
        return x
    flat = x.reshape(-1)
    half = flat.size // 2
    a = ring_all_reduce(flat[:half], axis_name, p)
    b = _ring_all_reduce_left(flat[half:], axis_name, p)
    return jnp.concatenate([a, b]).reshape(x.shape)


def _ring_all_reduce_left(x, axis_name, p):
    idx = lax.axis_index(axis_name)
    flat, n, _ = _pad_to(x, p)
    chunks = flat.reshape(p, -1)
    left = [(i, (i - 1) % p) for i in range(p)]
    buf = jnp.take(chunks, idx, axis=0)
    for s in range(p - 1):
        buf = lax.ppermute(buf, axis_name, left) \
            + jnp.take(chunks, (idx + s + 1) % p, axis=0)
    out = jnp.zeros_like(chunks)
    out = _dyn_set(out, (idx - 1) % p, buf)
    g = buf
    for s in range(p - 1):
        g = lax.ppermute(g, axis_name, left)
        out = _dyn_set(out, (idx + s) % p, g)
    return out.reshape(-1)[:n].reshape(x.shape)


def ring_all_gather(x: jax.Array, axis_name: str, axis_size: int
                    ) -> jax.Array:
    """All-Gather via p-1 neighbor passes; result stacked on a new axis 0."""
    p = axis_size
    idx = lax.axis_index(axis_name)
    out = jnp.zeros((p, *x.shape), x.dtype)
    out = _dyn_set(out, idx, x)
    right = [(i, (i + 1) % p) for i in range(p)]
    g = x
    for s in range(p - 1):
        g = lax.ppermute(g, axis_name, right)
        out = _dyn_set(out, (idx - s - 1) % p, g)
    return out


def ring_reduce_scatter(x: jax.Array, axis_name: str, axis_size: int
                        ) -> jax.Array:
    """x: (p, ...) per-peer chunks; returns this rank's OWN reduced chunk
    (rank i ends holding sum_j x_j[i])."""
    p = axis_size
    if p == 1:
        return x[0]
    idx = lax.axis_index(axis_name)
    right = [(i, (i + 1) % p) for i in range(p)]
    # chunk index decrements by one per hop; to finish at chunk ``idx``
    # after p-1 hops, start at chunk idx-1 and add chunk idx-2-s per step.
    buf = jnp.take(x, (idx - 1) % p, axis=0)
    for s in range(p - 1):
        buf = lax.ppermute(buf, axis_name, right) \
            + jnp.take(x, (idx - 2 - s) % p, axis=0)
    return buf


def compressed_ring_all_reduce(x: jax.Array, axis_name: str, axis_size: int,
                               bits: int = 8) -> jax.Array:
    """Quantized ring All-Reduce (the executable face of the ``ring+q8`` /
    ``ring+q4`` selection candidates): every reduce-scatter hop quantizes
    its chunk to ``bits`` (uniform symmetric, per-chunk fp32 scale),
    ppermutes the int8 payload + scale, and dequant-accumulates; the
    all-gather phase encodes the reduced chunk once and forwards the
    compressed payload hop to hop.

    Wire bytes drop to ~``bits/32`` of the fp32 ring (plus one scale per
    chunk per hop) — ``bits=4`` payloads are nibble-packed, two values per
    byte, so the saving is real on the wire, not just in the dtype.
    Accuracy: each of the ``p-1`` accumulation hops re-quantizes the
    partial sum, so the result matches ``psum`` within
    ~``p * absmax / (2^(bits-1) - 1)`` per element — the codec tolerance
    the multi-device parity test asserts, and the bias the error-feedback
    codecs (``repro.compress``) remove across iterations."""
    from repro.kernels.compress.ref import wire_codec

    p = axis_size
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    flat, n, _ = _pad_to(x, p)
    chunks = flat.reshape(p, -1).astype(jnp.float32)
    clen = chunks.shape[1]
    right = [(i, (i + 1) % p) for i in range(p)]
    encode, decode = wire_codec(bits, clen)

    def send(v):
        q, scale = encode(v)
        q = lax.ppermute(q, axis_name, right)
        scale = lax.ppermute(scale, axis_name, right)
        return decode(q, scale)

    # ---- reduce-scatter: dequant-accumulate each hop ----
    buf = jnp.take(chunks, idx, axis=0)
    for s in range(p - 1):
        buf = send(buf) + jnp.take(chunks, (idx - s - 1) % p, axis=0)

    # ---- all-gather: encode once, forward the compressed payload ----
    q, scale = encode(buf)
    out = jnp.zeros_like(chunks)
    out = _dyn_set(out, (idx + 1) % p, decode(q, scale))
    for s in range(p - 1):
        q = lax.ppermute(q, axis_name, right)
        scale = lax.ppermute(scale, axis_name, right)
        out = _dyn_set(out, (idx - s) % p, decode(q, scale))
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def latency_bound_all_reduce(x: jax.Array, axis_name: str, axis_size: int
                             ) -> jax.Array:
    """Recursive doubling: log2(p) exchanges of the FULL payload.
    Latency-optimal for tiny payloads (the crossover NCCL exploits)."""
    p = axis_size
    assert p & (p - 1) == 0, "recursive doubling needs power-of-two"
    acc = x
    dist = 1
    while dist < p:
        perm = [(i, i ^ dist) for i in range(p)]
        acc = acc + lax.ppermute(acc, axis_name, perm)
        dist *= 2
    return acc


def torus2d_all_reduce(x: jax.Array, row_axis: str, col_axis: str,
                       rows: int, cols: int) -> jax.Array:
    """Dimension-ordered 2D-torus All-Reduce: ring AR along rows, then
    along columns — the executable counterpart of
    ``ccl.algorithms.torus2d_all_reduce`` (matches the production mesh's
    two ICI dimensions)."""
    x = ring_all_reduce(x, row_axis, rows)
    return ring_all_reduce(x, col_axis, cols)


# ---------------------------------------------------------------------------
# Synthesized schedules: generic move-list interpreter
# ---------------------------------------------------------------------------


def _schedule_program(schedule) -> list:
    """Compile a ``ccl.synth.SynthSchedule`` move list into static
    ``ppermute`` sub-batches.

    One ``lax.ppermute`` is a partial permutation — every rank sends at
    most one payload and receives at most one — so each synthesis step
    (whose moves may fan several arrivals into one rank on disjoint
    links) is split first-fit into sub-batches with each rank appearing
    at most once as source and once as destination.  First-fit preserves
    emission order for a repeated destination, which is exactly the
    accumulation order the replay semantics define.  Correctness of
    reading the *current* buffer inside a step rests on the synthesizer's
    wave invariant: a chunk delivered at step ``s`` is never forwarded
    before step ``s+1``, so same-step cross-sub-batch dependencies are
    only same-destination accumulations (associative).

    Returns a list of ``(perm, send_chunk, recv_chunk, recv_mask,
    reduce_mask)`` tuples over *group-rank* indices (the mesh axis
    position of each device in ``schedule.group``)."""
    rank = {dev: i for i, dev in enumerate(schedule.group)}
    p = len(schedule.group)
    by_step: dict = {}
    for m in schedule.moves:
        by_step.setdefault(m.step, []).append(m)
    program = []
    for step in sorted(by_step):
        batches: list = []
        for m in by_step[step]:
            s, d = rank[m.src], rank[m.dst]
            for b in batches:
                if s not in b["srcs"] and d not in b["dsts"]:
                    break
            else:
                b = {"moves": [], "srcs": set(), "dsts": set()}
                batches.append(b)
            b["moves"].append((s, d, m.chunk, m.reduce))
            b["srcs"].add(s)
            b["dsts"].add(d)
        for b in batches:
            send_chunk = [0] * p
            recv_chunk = [0] * p
            recv_mask = [False] * p
            reduce_mask = [False] * p
            perm = []
            for s, d, chunk, red in b["moves"]:
                perm.append((s, d))
                send_chunk[s] = chunk
                recv_chunk[d] = chunk
                recv_mask[d] = True
                reduce_mask[d] = red
            program.append((perm, send_chunk, recv_chunk, recv_mask,
                            reduce_mask))
    return program


def synthesized_collective(x: jax.Array, axis_name: str, axis_size: int,
                           schedule, bits: int = None) -> jax.Array:
    """Execute a synthesized schedule (``ccl.synth``) as a ``shard_map``
    program: one ``lax.ppermute`` per compiled sub-batch, a
    ``num_chunks``-slot buffer per rank, reduce moves accumulating and
    gather moves overwriting — the executable lowering of the move list
    both cost models priced.

    ``bits`` enables the quantize-in-the-send-loop codec (the executable
    face of the ``synthesized+q8`` / ``+q4`` candidates, sharing
    ``kernels.compress.ref.wire_codec`` with the compressed ring): each
    sub-batch's payload is quantized before the permute and
    dequantized after, so reduce hops re-quantize partial sums with the
    same ``~hops * absmax / (2^(bits-1)-1)`` tolerance envelope.

    Supported primitives: ``all_reduce`` (mirrored-tree schedules with
    ``num_chunks == p`` and single-slot ATP schedules alike — rank
    ``i``'s input is split into ``num_chunks`` equal slices),
    ``broadcast`` (every rank returns the root's payload), and
    ``all_gather`` (returns the ``(p, ...)`` stack)."""
    p = axis_size
    if len(schedule.group) != p:
        raise ValueError(
            f"schedule group size {len(schedule.group)} != mesh axis size "
            f"{p}")
    program = _schedule_program(schedule)
    idx = lax.axis_index(axis_name)
    nc = schedule.num_chunks
    if schedule.primitive in ("all_reduce", "broadcast"):
        flat, n, _ = _pad_to(x, nc)
        buf = flat.reshape(nc, -1).astype(jnp.float32)
    elif schedule.primitive == "all_gather":
        buf = jnp.zeros((nc, x.size), jnp.float32)
        buf = _dyn_set(buf, idx, x.reshape(-1).astype(jnp.float32))
        n = x.size
    else:
        raise KeyError(
            f"no executable lowering for synthesized {schedule.primitive}")
    clen = buf.shape[1]
    if bits:
        from repro.kernels.compress.ref import wire_codec
        encode, decode = wire_codec(bits, clen)
    for perm, send_chunk, recv_chunk, recv_mask, reduce_mask in program:
        payload = jnp.take(buf, jnp.asarray(send_chunk)[idx], axis=0)
        if bits:
            q, scale = encode(payload)
            q = lax.ppermute(q, axis_name, perm)
            scale = lax.ppermute(scale, axis_name, perm)
            payload = decode(q, scale)
        else:
            payload = lax.ppermute(payload, axis_name, perm)
        c = jnp.asarray(recv_chunk)[idx]
        cur = jnp.take(buf, c, axis=0)
        new = jnp.where(jnp.asarray(reduce_mask)[idx], cur + payload,
                        payload)
        new = jnp.where(jnp.asarray(recv_mask)[idx], new, cur)
        buf = _dyn_set(buf, c, new)
    if schedule.primitive == "all_gather":
        return buf.reshape(nc, *x.shape).astype(x.dtype)
    return buf.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def make_synthesized(schedule, mesh, axis_name: str, bits: int = None
                     ) -> Callable:
    """Wrap a synthesized all-reduce/broadcast schedule as a jitted
    global-array function (shape-preserving primitives only — all-gather
    changes the output sharding, call ``synthesized_collective`` inside
    your own ``shard_map`` for that)."""
    if schedule.primitive == "all_gather":
        raise KeyError("make_synthesized is shape-preserving; lower "
                       "all_gather schedules inside an explicit shard_map")
    size = mesh.shape[axis_name]

    def body(x):
        return synthesized_collective(x, axis_name, size, schedule,
                                      bits=bits)

    def wrapped(x):
        spec = P(axis_name, *([None] * (x.ndim - 1)))
        return jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=spec, out_specs=spec))(x)

    return wrapped


IMPLEMENTATIONS: dict = {
    "ring": ring_all_reduce,
    "bidir_ring": bidir_ring_all_reduce,
    "recursive_doubling": latency_bound_all_reduce,
    "ring_q8": functools.partial(compressed_ring_all_reduce, bits=8),
    "ring_q4": functools.partial(compressed_ring_all_reduce, bits=4),
}

# executable implementation -> the algorithm name the cost models price
# it as (``ccl.cost.algo_cost`` / the selection registry), so measured
# wall-clock spans (``repro.obs.probe``) line up against the right
# model-predicted spans
MODEL_EQUIVALENTS: dict = {
    "ring": "ring",
    "bidir_ring": "bidir_ring",
    "recursive_doubling": "halving_doubling",
    "ring_q8": "ring+q8",
    "ring_q4": "ring+q4",
}


def make_all_reduce(impl: str, mesh, axis_name: str) -> Callable:
    """Wrap an implementation as a jitted global-array function."""
    size = mesh.shape[axis_name]
    fn = IMPLEMENTATIONS[impl]

    def body(x):
        return fn(x, axis_name, size)

    n_axes = None

    def wrapped(x):
        spec = P(axis_name, *([None] * (x.ndim - 1)))
        return jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=spec, out_specs=spec))(x)

    return wrapped
