"""Collective Communication Library layer (paper Sec. II-C / III-B).

Three faces of the same layer:
  * ``algorithms``  — collective algorithms as explicit flow schedules
                      (ring, bidirectional ring, recursive halving/doubling,
                      tree, direct all-to-all) usable by the network simulator
  * ``primitives``  — the same algorithms as executable JAX programs
                      (shard_map + ppermute), validated against jax.lax psum
  * ``cost``        — alpha-beta cost models; ``select`` does NCCL-style
                      auto-selection; ``synth`` does TACCL-style sketch-guided
                      synthesis on an arbitrary topology
"""
from repro.ccl.algorithms import ALGORITHMS, generate_flows  # noqa: F401
from repro.ccl.cost import algo_cost, CostParams  # noqa: F401
from repro.ccl.select import (AlphaBeta, CostModel, FlowSim,  # noqa: F401
                              Selection, select_algorithm, select_for_task)
