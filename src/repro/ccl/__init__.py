"""Collective Communication Library layer (paper Sec. II-C / III-B).

Three faces of the same layer:
  * ``algorithms``  — collective algorithms as explicit flow schedules
                      (ring, bidirectional ring, recursive halving/doubling,
                      tree, direct all-to-all) usable by the network
                      simulator, plus compressed candidates (``ring+q8``,
                      ``ps+topk``, ...) wrapping any base schedule with a
                      ``repro.compress`` codec's wire-byte ratio
  * ``primitives``  — the same algorithms as executable JAX programs
                      (shard_map + ppermute), validated against jax.lax
                      psum — including the quantized compressed ring
  * ``cost``        — alpha-beta cost models; ``select`` does NCCL-style
                      auto-selection (with an ``error_budget`` gate for
                      lossy candidates); ``synth`` does TACCL-style
                      sketch-guided synthesis on an arbitrary topology
"""
from repro.ccl.algorithms import (ALGORITHMS,  # noqa: F401
                                  COMPRESSED_CANDIDATES, generate_flows)
from repro.ccl.cost import algo_cost, CostParams  # noqa: F401
from repro.ccl.select import (AlphaBeta, CostModel, FlowSim,  # noqa: F401
                              Selection, select_algorithm, select_for_task)
