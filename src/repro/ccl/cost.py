"""Alpha-beta cost models for collective algorithms.

cost = num_steps * alpha + wire_bytes_on_critical_path / beta_effective.

These closed forms are the classical ones (Thakur et al.; NCCL docs) and
are validated in tests against the flow-schedule generators in
``repro.ccl.algorithms`` (the per-step max-link bytes of the generated
schedule must equal the closed form's bandwidth term).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class CostParams:
    alpha: float = 5e-6          # per-step latency (s)
    link_bw: float = 50e9        # bytes/s per link (intra-host when hierarchical)
    reduce_flops_bw: float = 0.0  # 0 = ignore reduction compute
    # hierarchy (the "Intra-Inter" setting): 0 = flat single-tier fabric.
    # When gpus_per_host > 1, link_bw is the intra-host (NVLink) bandwidth
    # and inter_bw the per-host NIC bandwidth, enabling the `hierarchical`
    # all-reduce closed form.
    inter_bw: float = 0.0        # bytes/s across hosts (0 = link_bw)
    gpus_per_host: int = 0       # accelerators per host (0 = no hierarchy)
    # in-network aggregation (ATP): max group size a programmable switch can
    # aggregate concurrently; None = unlimited, 0 = switch memory exhausted
    # (same convention as sched.atp.aggregation_switches).  Groups beyond it
    # degrade to host PS aggregation (the multi-tenant fallback).
    atp_capacity: Optional[int] = None
    # gradient compression (repro.compress): encode/decode modeled as
    # ``spec.passes`` full-payload memory passes at ``codec_bw`` bytes/s
    # plus a fixed ``codec_alpha`` launch latency per algorithm step — the
    # term that makes compression lose in the latency regime even though
    # it always shrinks the bandwidth term.
    codec_bw: float = 200e9
    codec_alpha: float = 2e-6


def algo_cost(primitive: str, algorithm: str, size_bytes: int, p: int,
              cp: CostParams) -> float:
    """Predicted completion time (seconds) of one collective.

    Compressed candidates (``"<base>+<codec>"``, e.g. ``ring+q8``) are
    priced as: base latency term + base bandwidth term scaled by the
    codec's wire ratio + encode/decode overhead (``codec_bw`` /
    ``codec_alpha``)."""
    n = float(size_bytes)
    a, b = cp.alpha, cp.link_bw
    if p <= 1:
        return 0.0
    if "+" in algorithm:
        import dataclasses

        from repro.compress.codec import base_algorithm, split_algorithm
        from repro.compress.codec import codec_spec

        _, codec_name = split_algorithm(algorithm)
        base = base_algorithm(algorithm)
        spec = codec_spec(codec_name)
        lat = algo_cost(primitive, base, 0, p, cp)
        full = algo_cost(primitive, base, size_bytes, p, cp)
        # step count: every closed form's latency term is linear in alpha
        # (alpha * steps), so lat(alpha=ref)/ref recovers it exactly — also
        # when the caller's alpha is 0, where the per-step codec launch
        # latency must still be charged
        a_ref = a if a > 0 else 1e-6
        lat_ref = lat if a > 0 else algo_cost(
            primitive, base, 0, p, dataclasses.replace(cp, alpha=a_ref))
        steps = lat_ref / a_ref
        return lat + (full - lat) * spec.wire_ratio \
            + steps * cp.codec_alpha + spec.passes * n / cp.codec_bw
    if primitive == "all_reduce":
        if algorithm == "ring":
            return 2 * (p - 1) * a + 2 * (p - 1) / p * n / b
        if algorithm == "bidir_ring":
            return 2 * (p - 1) * a + (p - 1) / p * n / b
        if algorithm == "halving_doubling":
            return 2 * math.log2(p) * a + 2 * (p - 1) / p * n / b
        if algorithm == "tree":
            return 2 * math.ceil(math.log2(p)) * (a + n / b)
        if algorithm == "torus2d":
            # dimension-ordered on a sqrt(p) x sqrt(p) torus: same wire
            # bytes as ring, far fewer latency steps
            r = max(int(math.isqrt(p)), 1)
            c = p // r
            steps = 2 * (r - 1) + 2 * (c - 1)
            return steps * a + 2 * (p - 1) / p * n / b
        if algorithm == "hierarchical":
            # intra-host ring reduce-scatter -> shard relay to the host
            # leader -> ring all-reduce over one leader per host on the NIC
            # tier -> relay back -> intra-host ring all-gather.
            m = cp.gpus_per_host
            if m <= 1 or p <= m or p % m:
                raise KeyError(
                    f"hierarchical all-reduce needs gpus_per_host dividing "
                    f"p with >=2 hosts; got p={p}, gpus_per_host={m}")
            hcount = p // m
            b_inter = cp.inter_bw or b
            intra = 2 * ((m - 1) * a + (m - 1) / m * n / b)     # RS + AG
            relay = 2 * (a + (m - 1) / m * n / b)               # to/from leader
            inter = 2 * (hcount - 1) * a \
                + 2 * (hcount - 1) / hcount * n / b_inter       # leader ring AR
            return intra + relay + inter
        if algorithm == "atp":
            # In-network aggregation (ATP): workers push the full gradient
            # up, programmable switches merge same-task flows, the sum
            # multicasts back — 2 latency steps, each fabric link carrying
            # ~n once.  Needs a switched inter-host tier to aggregate on.
            b_inter = cp.inter_bw
            if not b_inter:
                raise KeyError(
                    "atp all-reduce needs a switched inter-host tier "
                    "(CostParams.inter_bw); flat fabrics have no "
                    "aggregation point")
            if cp.atp_capacity is not None and p > cp.atp_capacity:
                # switch memory exhausted -> host PS aggregation: all p
                # unmerged flows converge on the PS's NIC, both directions
                return 2 * a + 2 * p * n / b_inter
            return 2 * a + 2 * n / b_inter
    if primitive in ("all_gather", "reduce_scatter"):
        # n = TOTAL payload (the gathered size / the pre-reduce size)
        if algorithm == "ring":
            return (p - 1) * a + (p - 1) / p * n / b
    if primitive == "permute":
        # one neighbor-exchange step of a decomposed collective: every
        # participant sends size_bytes to its ring successor concurrently
        if algorithm == "ring":
            return a + n / b
    if primitive == "broadcast":
        if algorithm == "binomial":
            return math.ceil(math.log2(p)) * (a + n / b)
    if primitive == "all_to_all":
        if algorithm == "direct":
            # p-1 simultaneous flows share the NIC: serialized on egress
            return a + (p - 1) / p * n / b
        if algorithm == "ring":
            return (p - 1) * a + (p - 1) / p * n / b
    if primitive == "p2p":
        # single point-to-point transfer (pipeline hand-off, KV-cache shard
        # migration): one latency step, the whole payload on one link
        if algorithm == "direct":
            return a + n / b
    raise KeyError(f"no cost model for {primitive}/{algorithm}")


def cost_terms(primitive: str, algorithm: str, size_bytes: int, p: int,
               cp: CostParams) -> dict:
    """:func:`algo_cost` split into its alpha-beta terms:
    ``{"latency_s", "bandwidth_s", "codec_s", "total_s"}``.

    The latency term is the size-0 cost of the (base) algorithm, the
    bandwidth term what payload adds on the wire, and ``codec_s`` the
    compressed candidates' encode/decode overhead (0 for lossless).
    This is the model-side breakdown ``repro.obs.probe`` puts next to
    measured wall-clock spans, so calibration can see *which* term
    drifts."""
    total = algo_cost(primitive, algorithm, size_bytes, p, cp)
    if p <= 1:
        return {"latency_s": 0.0, "bandwidth_s": 0.0, "codec_s": 0.0,
                "total_s": 0.0}
    if "+" in algorithm:
        from repro.compress.codec import (base_algorithm, codec_spec,
                                          split_algorithm)
        base = base_algorithm(algorithm)
        _, codec_name = split_algorithm(algorithm)
        lat = algo_cost(primitive, base, 0, p, cp)
        full = algo_cost(primitive, base, size_bytes, p, cp)
        bw = (full - lat) * codec_spec(codec_name).wire_ratio
        return {"latency_s": lat, "bandwidth_s": bw,
                "codec_s": total - lat - bw, "total_s": total}
    lat = algo_cost(primitive, algorithm, 0, p, cp)
    return {"latency_s": lat, "bandwidth_s": total - lat, "codec_s": 0.0,
            "total_s": total}
