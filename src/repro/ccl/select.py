"""NCCL-style algorithm auto-selection behind a CostModel protocol.

NCCL "dynamically selects established algorithms based on different
situations" (paper Sec. III-B): small payloads favour latency-optimal
algorithms (tree / halving-doubling), large payloads favour bandwidth-
optimal rings.  The seed reproduced that with flat alpha-beta closed forms;
this module generalizes pricing behind a :class:`CostModel` protocol so the
CCL layer can consult the network layer (the paper's Sec. II-E co-design
gap):

  * :class:`AlphaBeta` — the original closed forms (`repro.ccl.cost`),
    kept exact, optionally hierarchy-aware via ``CostParams.gpus_per_host``;
  * :class:`FlowSim`  — generates the candidate algorithm's actual flow
    schedule (`repro.ccl.algorithms`) and prices it on a real
    ``net.Topology`` with ``net.simulate.simulate_flowset``, memoized on
    ``(primitive, algorithm, size, group)`` so selection over a 40-layer
    demand stays sub-second.

``select_algorithm`` keeps the seed's signature (AlphaBeta under the hood);
``select_for_task`` is the topology-aware entry point the codesign driver
uses.

The "Host-Net" arrow (paper Sec. IV-B) runs through here too: the ``atp``
in-network-aggregation all-reduce competes like any other candidate on
switched topologies, with ``sched.atp.aggregation_switches`` supplying the
aggregation capability and the multi-tenant switch-memory fallback.

So does the compression lever (``repro.compress``): ``"<base>+<codec>"``
candidates such as ``ring+q8`` compete on wire-scaled schedules plus
encode/decode overhead, gated by ``select_for_task``'s ``error_budget``
(default 0 = lossless only).

Decomposed TP collectives (``core.demand_builder.decompose_demand``)
arrive here as ``permute`` tasks — one ring neighbor-exchange step each.
They price through the same path (closed form ``alpha + n/beta``, or the
one-step flowset on the real topology), and both models' memoization
collapses the 2(p-1) identical steps per layer to a single evaluation.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Protocol, Tuple

from repro.ccl.algorithms import ALGORITHMS, generate_flows
from repro.ccl.cost import CostParams, algo_cost
from repro.compress.codec import (SPECS, base_algorithm, codec_spec,
                                  split_algorithm)
from repro.core.demand import CommTask, FlowSet
from repro.core.knobs import Choice, Fixed, Knob, Search
from repro.obs.meters import Meters
from repro.net.simulate import simulate_flowset
from repro.net.topology import Topology
from repro.sched.atp import aggregation_switches


# ---------------------------------------------------------------------------
# Eligibility guards (structural: independent of the cost model)
# ---------------------------------------------------------------------------


def is_square(p: int) -> bool:
    """Exact perfect-square test.  ``int(p ** 0.5)`` mis-rounds for large
    perfect squares (float sqrt of a non-representable int); ``math.isqrt``
    is exact."""
    return p >= 0 and math.isqrt(p) ** 2 == p


def structurally_eligible(algorithm: str, p: int) -> bool:
    """Group-shape guards that hold regardless of how costs are computed.
    Compressed candidates (``ring+q8``) inherit their base's guards."""
    base = base_algorithm(algorithm)
    if base == "halving_doubling" and p & (p - 1):
        return False  # needs power-of-two
    if base == "torus2d" and not is_square(p):
        return False  # needs a square grid layout
    return True


# ---------------------------------------------------------------------------
# CostModel protocol + implementations
# ---------------------------------------------------------------------------


class CostModel(Protocol):
    """What the selection layer needs from a pricing backend."""

    def supports(self, task: CommTask, algorithm: str) -> bool:
        """Model-specific eligibility (beyond the structural guards)."""
        ...

    def cost(self, task: CommTask, algorithm: str) -> float:
        """Predicted completion time (seconds) of ``algorithm`` on ``task``."""
        ...


# When a flat algorithm's group spans hosts on a hierarchical fabric, its
# crossing traffic is bottlenecked by the per-host NIC, shared by this many
# concurrent crossing flows per step (None = one per host GPU, i.e.
# gpus_per_host): a bidirectional ring crosses each NIC twice; recursive
# halving/doubling and direct all-to-all cross with every host member at
# once, and a 2D torus's parallel sub-rings each cross on the column phase.
# Algorithms not listed cross once per step (plain rings, trees).
_NIC_SHARING = {"bidir_ring": 2.0, "halving_doubling": None, "direct": None,
                "torus2d": None}


def _hierarchical_partition_ok(topo: Topology, group: Tuple[int, ...]
                               ) -> bool:
    """The hierarchical decomposition needs the (placed) group to split
    into >=2 equal-size hosts of >=2 members each."""
    hosts = topo.host_groups(group)
    sizes = {len(h) for h in hosts}
    return len(hosts) > 1 and len(sizes) == 1 and sizes != {1}


@dataclass(frozen=True)
class AlphaBeta:
    """Closed-form alpha-beta pricing.  For flat ``CostParams`` this is the
    seed's behaviour, kept exact.  With hierarchy params set
    (``gpus_per_host``/``inter_bw``), flat algorithms whose group spans
    hosts are priced at the NIC-tier bottleneck (divided by the
    algorithm's NIC-sharing factor) instead of the intra-host bandwidth —
    otherwise the closed forms would never let ``hierarchical`` win."""

    params: CostParams = CostParams()
    # set by from_topology: enables the physical host-partition eligibility
    # check for groups that are already placed onto real devices (the
    # divisibility heuristic alone would accept e.g. a 16-rank group strided
    # over 3 hosts, which the flow generator then rejects)
    topo: Optional[Topology] = None

    def supports(self, task: CommTask, algorithm: str) -> bool:
        base = base_algorithm(algorithm)  # compressed names inherit base's
        if base == "hierarchical":
            if self.topo is not None:
                return _hierarchical_partition_ok(self.topo, task.group)
            m = self.params.gpus_per_host
            p = len(task.group)
            return m > 1 and p > m and p % m == 0
        if base == "atp":
            # in-network aggregation needs programmable switches on the
            # fabric; with only closed-form params, a switched inter-host
            # tier (inter_bw) is the eligibility proxy
            if self.topo is not None:
                return bool(self.topo.switch_nodes())
            return self.params.inter_bw > 0
        return True

    def cost(self, task: CommTask, algorithm: str) -> float:
        cp = self.params
        p = len(task.group)
        base = base_algorithm(algorithm)
        if task.primitive == "p2p" and p == 2:
            # a point-to-point transfer runs at its actual path bottleneck
            # (a KV-cache shard hop may cross the NIC tier even though
            # p=2 never trips the group-spans-hosts heuristic below)
            u, v = task.group
            if self.topo is not None and u != v:
                bw = min(self.topo.link_bw(a, b)
                         for a, b in self.topo.path_links(u, v))
                cp = dataclasses.replace(cp, link_bw=bw)
            elif cp.inter_bw and cp.gpus_per_host > 1 \
                    and u // cp.gpus_per_host != v // cp.gpus_per_host:
                cp = dataclasses.replace(cp, link_bw=cp.inter_bw)
            return algo_cost(task.primitive, algorithm, task.size_bytes, p,
                             cp)
        if base == "atp" and not cp.inter_bw:
            # switched but non-hierarchical fabric (e.g. one NIC per host):
            # the aggregation tier runs at the bottleneck link bandwidth
            cp = dataclasses.replace(cp, inter_bw=cp.link_bw)
        if base == "hierarchical" and self.topo is not None:
            # the placed group's actual per-host size, not the nominal one
            m = len(self.topo.host_groups(task.group)[0])
            if m != cp.gpus_per_host:
                cp = dataclasses.replace(cp, gpus_per_host=m)
        elif (base not in ("hierarchical", "atp")
                and cp.gpus_per_host > 1
                and p > cp.gpus_per_host and cp.inter_bw):
            share = _NIC_SHARING.get(base, 1.0) or cp.gpus_per_host
            cp = dataclasses.replace(cp, link_bw=cp.inter_bw / share)
        return algo_cost(task.primitive, algorithm, task.size_bytes, p, cp)

    def cost_flowset(self, task: CommTask, fs: FlowSet,
                     algorithm: Optional[str] = None) -> float:
        """Closed-form pricing of an *explicit* flow schedule (a synthesized
        move list, not a registered name): per step, one alpha plus the
        busiest endpoint's serialized bytes over the tier bandwidth it
        talks across (``inter_bw`` when the flow crosses hosts — resolved
        through the topology when attached, else the
        ``gpus_per_host``-contiguous heuristic).  This is the step-count
        alpha-beta analogue of the ring/tree closed forms, so synthesized
        candidates compete under *both* cost models, not just FlowSim.

        Compressed variants (``synthesized+q8``) hand in wire-scaled
        flowsets; the codec's encode/decode overhead is charged here from
        the algorithm name, mirroring :func:`repro.ccl.cost.algo_cost`."""
        cp = self.params
        if len(task.group) <= 1 or not fs.flows:
            return 0.0
        if self.topo is not None:
            host_of = self.topo.host_of

            def crossing(u, v):
                return host_of(u) != host_of(v)
        elif cp.gpus_per_host > 1:
            m = cp.gpus_per_host

            def crossing(u, v):
                return u // m != v // m
        else:
            def crossing(u, v):
                return False
        inter_bw = cp.inter_bw or cp.link_bw
        by_step: Dict[int, List] = {}
        for f in fs.flows:
            by_step.setdefault(f.step, []).append(f)
        total = 0.0
        for flows in by_step.values():
            # serialization point: a node's egress (or ingress) NIC sends
            # (receives) its step bytes back-to-back on each tier
            load: Dict[Tuple, float] = {}
            for f in flows:
                bw = inter_bw if crossing(f.src, f.dst) else cp.link_bw
                for end in ((f.src, "tx"), (f.dst, "rx")):
                    load[end] = load.get(end, 0.0) + f.size_bytes / bw
            total += cp.alpha + max(load.values(), default=0.0)
        name = algorithm or fs.algorithm
        _, codec = split_algorithm(name)
        if codec is not None:
            spec = codec_spec(codec)
            total += len(by_step) * cp.codec_alpha \
                + spec.passes * task.size_bytes / cp.codec_bw
        return total

    @classmethod
    def from_topology(cls, topo: Topology, alpha: float = None) -> "AlphaBeta":
        """Derive flat-or-hierarchical CostParams from a Topology: intra
        bandwidth = bottleneck link between two co-hosted accelerators,
        inter bandwidth = bottleneck across hosts.  Topologies without host
        structure get the bottleneck bandwidth of an adjacent pair."""
        accel = topo.accelerators
        if len(accel) < 2:
            return cls(CostParams())

        def bottleneck(u, v) -> float:
            return min(topo.link_bw(a, b) for a, b in topo.path_links(u, v))

        def lat(u, v) -> float:
            return sum(topo.graph[a][b]["lat"]
                       for a, b in topo.path_links(u, v))

        sizes = {len(h) for h in topo.hosts}
        if topo.hosts and sizes == {len(topo.hosts[0])} \
                and len(topo.hosts) > 1 and len(topo.hosts[0]) > 1:
            h0, h1 = topo.hosts[0], topo.hosts[1]
            intra_bw = bottleneck(h0[0], h0[1])
            inter_bw = bottleneck(h0[0], h1[0])
            a = alpha if alpha is not None else max(lat(h0[0], h1[0]), 1e-7)
            return cls(CostParams(alpha=a, link_bw=intra_bw,
                                  inter_bw=inter_bw,
                                  gpus_per_host=len(h0)), topo=topo)
        a = alpha if alpha is not None else max(lat(accel[0], accel[1]), 1e-7)
        return cls(CostParams(alpha=a,
                              link_bw=bottleneck(accel[0], accel[1])),
                   topo=topo)


class FlowSim:
    """Prices a candidate algorithm by generating its FlowSet and simulating
    it on the actual topology — the CCL layer asking the network layer
    instead of assuming a flat link (the paper's vertical co-design arrow).

    Both the generated flowsets and the simulated costs are memoized on
    ``(primitive, algorithm, size_bytes, group)``: a 40-layer demand repeats
    a handful of unique (size, group) keys, so end-to-end selection stays
    sub-second.

    ``switch_capacity`` is the per-switch in-network aggregation budget
    (ATP's multi-tenant constraint, forwarded to
    ``sched.atp.aggregation_switches``): groups larger than it lose the
    aggregation discount and the ``atp`` candidate is priced as degraded
    host PS aggregation.

    Compressed candidates (``ring+q8``, ``ps+topk``, ...) are simulated on
    their wire-scaled flowsets plus encode/decode overhead:
    ``codec_alpha`` per schedule step and ``spec.passes`` full-payload
    passes at ``codec_bw`` bytes/s (same model as ``CostParams``)."""

    def __init__(self, topo: Topology, switch_capacity: Optional[int] = None,
                 codec_bw: float = 200e9, codec_alpha: float = 2e-6,
                 meters: Optional[Meters] = None):
        self.topo = topo
        self.switch_capacity = switch_capacity
        self.codec_bw = codec_bw
        self.codec_alpha = codec_alpha
        self._cost_memo: Dict[Tuple, float] = {}
        self._flow_memo: Dict[Tuple, FlowSet] = {}
        # memoization telemetry (repro.obs): counter names carry the
        # switch-capacity bucket since one FlowSim exists per aggregation
        # budget, so merged snapshots keep the buckets apart
        self.meters = meters if meters is not None else Meters()
        self._bucket = f"flowsim[cap={switch_capacity}]"

    def _key(self, task: CommTask, algorithm: str) -> Tuple:
        return (task.primitive, algorithm, task.size_bytes, task.group)

    def cache_stats(self) -> Dict[str, float]:
        """This model's memoization counters plus the hit rates (the
        headline numbers ``search()`` telemetry floors on)."""
        m = self.meters
        out = m.snapshot()
        for kind in ("cost", "flow"):
            rate = m.ratio(f"{self._bucket}.{kind}.hit",
                           f"{self._bucket}.{kind}.miss")
            if rate is not None:
                out[f"{self._bucket}.{kind}.hit_rate"] = rate
        out[f"{self._bucket}.cost.entries"] = float(len(self._cost_memo))
        return out

    def supports(self, task: CommTask, algorithm: str) -> bool:
        base = base_algorithm(algorithm)  # compressed names inherit base's
        if base == "hierarchical":
            return _hierarchical_partition_ok(self.topo, task.group)
        if base == "atp":
            # needs programmable switches below a host structure (fat-tree /
            # DGX NIC tier); pure ICI fabrics have no aggregation point
            return bool(self.topo.hosts) and bool(self.topo.switch_nodes())
        return True

    def flowset(self, task: CommTask, algorithm: str) -> FlowSet:
        key = self._key(task, algorithm)
        if key not in self._flow_memo:
            self.meters.incr(f"{self._bucket}.flow.miss")
            self._flow_memo[key] = flows_on_topology(
                self.topo, task, algorithm)
        else:
            self.meters.incr(f"{self._bucket}.flow.hit")
        return self._flow_memo[key]

    def cost(self, task: CommTask, algorithm: str) -> float:
        key = self._key(task, algorithm)
        if key in self._cost_memo:
            self.meters.incr(f"{self._bucket}.cost.hit")
            return self._cost_memo[key]
        self.meters.incr(f"{self._bucket}.cost.miss")
        agg = None
        if base_algorithm(algorithm) == "atp":
            agg = aggregation_switches(self.topo, task.group,
                                       self.switch_capacity)
        fs = self.flowset(task, algorithm)
        t = simulate_flowset(self.topo, fs, aggregate_at=agg)
        _, codec = split_algorithm(algorithm)
        if codec is not None:
            spec = codec_spec(codec)
            t += fs.num_steps * self.codec_alpha \
                + spec.passes * task.size_bytes / self.codec_bw
        self._cost_memo[key] = t
        return t

    def cost_flowset(self, task: CommTask, fs: FlowSet,
                     algorithm: Optional[str] = None) -> float:
        """Price an *explicit* flow schedule (a synthesized move list) by
        simulating it on the topology — the same path registered
        algorithms take, minus the generator.  Memoized alongside
        :meth:`cost` under a schedule fingerprint (same schedule handed
        in twice — e.g. a lossless and a wire-scaled variant share a
        solver run but not flows — prices once each).  Compressed names
        (``synthesized+q8``) add the codec overhead; their flowsets are
        expected to already carry wire-scaled bytes."""
        name = algorithm or fs.algorithm
        fp = hash(tuple((f.src, f.dst, f.size_bytes, f.step)
                        for f in fs.flows))
        key = (task.primitive, name, task.size_bytes, task.group, fp)
        if key in self._cost_memo:
            self.meters.incr(f"{self._bucket}.cost.hit")
            return self._cost_memo[key]
        self.meters.incr(f"{self._bucket}.cost.miss")
        t = simulate_flowset(self.topo, fs)
        _, codec = split_algorithm(name)
        if codec is not None:
            spec = codec_spec(codec)
            t += fs.num_steps * self.codec_alpha \
                + spec.passes * task.size_bytes / self.codec_bw
        self._cost_memo[key] = t
        return t


def flows_on_topology(topo: Topology, task: CommTask,
                      algorithm: str) -> FlowSet:
    """`generate_flows`, but topology-aware: hierarchical algorithms (plain
    or compressed) get the physical host partition of the (placed) group."""
    if base_algorithm(algorithm) == "hierarchical":
        return generate_flows(task, algorithm,
                              hosts=topo.host_groups(task.group))
    return generate_flows(task, algorithm)


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------


@dataclass
class Selection:
    """Outcome of pricing every eligible candidate for one task."""

    algorithm: str
    cost: float
    costs: Dict[str, float] = field(default_factory=dict)
    excluded: List[str] = field(default_factory=list)


def constraint_from_allow(allow: Optional[Tuple[str, ...]]) -> Knob:
    """The legacy ``allow`` tuple as a knob: None (or empty, which always
    behaved like None) opens the full registry, a single name is a force
    (``Fixed``), several names a whitelist."""
    if not allow:
        return Search()
    if len(allow) == 1:
        return Fixed(allow[0])
    return Choice(*allow)


def select_for_task(task: CommTask, model: CostModel,
                    allow: Optional[Tuple[str, ...]] = None,
                    error_budget: float = 0.0,
                    constraint: Optional[Knob] = None,
                    extra_flowsets: Optional[Mapping[str, FlowSet]] = None
                    ) -> Selection:
    """Pick the cheapest eligible algorithm for ``task`` under ``model``.

    ``constraint`` is the plan-space knob for this task's primitive
    (``repro.core.knobs``): ``Search()`` opens every registered candidate
    (the default), ``Choice(...)`` whitelists, and ``Fixed(name)`` forces
    one algorithm.  The legacy ``allow`` tuple is accepted as shorthand
    and normalized via :func:`constraint_from_allow` (None -> Search,
    one name -> Fixed, several -> Choice); passing both is an error.

    ``error_budget`` gates compressed candidates: a ``"<base>+<codec>"``
    name competes only if the codec's effective relative error (see
    ``CodecSpec.effective_error``) fits the budget.  The default budget of
    0 excludes all lossy candidates — exactness is opt-in per task.  Only
    a ``Fixed`` constraint (a force, e.g. the driver's ``force=`` path)
    bypasses the budget — forcing one compressed algorithm is an explicit
    accuracy decision; a ``Choice`` whitelist still respects the budget.

    ``extra_flowsets`` maps candidate names to *explicit* flow schedules
    (synthesized move lists from ``ccl.synth``) that compete alongside the
    registry: each is priced through the model's ``cost_flowset`` (both
    ``AlphaBeta`` and ``FlowSim`` implement it; models without it skip the
    extras).  Extras bypass the structural/``supports`` guards — an
    explicit schedule *is* its own feasibility proof — but compressed
    extras (``synthesized+q8``) still face the error budget, and a
    ``Choice``/``Fixed`` constraint whitelists extras by name exactly
    like registered candidates."""
    if constraint is None:
        constraint = constraint_from_allow(allow)
    elif allow is not None:
        raise ValueError("pass either allow= or constraint=, not both")
    forced = isinstance(constraint, Fixed)
    allowed: Optional[Tuple[str, ...]] = None
    if forced:
        allowed = (constraint.value,)
    elif isinstance(constraint, Choice):
        allowed = constraint.options
    elif not isinstance(constraint, Search):
        raise TypeError(f"constraint must be a Fixed/Choice/Search knob, "
                        f"got {constraint!r}")
    p = len(task.group)
    costs: Dict[str, float] = {}
    excluded: List[str] = []
    names = list(ALGORITHMS[task.primitive])
    if allowed:
        # ad hoc "<base>+<codec>" combos beyond the canonical registry are
        # explicitly allowable (generate_flows/algo_cost compose them)
        for name in allowed:
            if name not in names and "+" in name:
                base, codec = split_algorithm(name)
                if base_algorithm(name) in ALGORITHMS[task.primitive] \
                        and codec in SPECS:
                    names.append(name)
    for name in names:
        if allowed and name not in allowed:
            continue
        _, codec = split_algorithm(name)
        if codec is not None and not forced and \
                codec_spec(codec).effective_error > error_budget:
            excluded.append(name)
            continue
        if not structurally_eligible(name, p) or \
                not model.supports(task, name):
            excluded.append(name)
            continue
        costs[name] = model.cost(task, name)
    if extra_flowsets:
        pricer = getattr(model, "cost_flowset", None)
        for name, fs in extra_flowsets.items():
            if pricer is None or (allowed and name not in allowed):
                continue
            _, codec = split_algorithm(name)
            if codec is not None and not forced and \
                    codec_spec(codec).effective_error > error_budget:
                excluded.append(name)
                continue
            costs[name] = pricer(task, fs, algorithm=name)
    if not costs:
        raise ValueError(
            f"no eligible algorithm for primitive {task.primitive!r} with "
            f"group size p={p}: registered="
            f"{list(ALGORITHMS[task.primitive])}, allow={allowed}, "
            f"excluded by eligibility guards={excluded}")
    best = min(costs, key=costs.get)
    return Selection(best, costs[best], costs, excluded)


def select_algorithm(primitive: str, size_bytes: int, p: int,
                     cp: CostParams,
                     allow: Optional[Tuple[str, ...]] = None
                     ) -> Tuple[str, float, Dict[str, float]]:
    """Seed-compatible entry point: alpha-beta pricing over a logical
    ``range(p)`` group.  Returns (best_algorithm, predicted_cost, all_costs)."""
    task = CommTask("select", primitive, size_bytes, tuple(range(p)))
    sel = select_for_task(task, AlphaBeta(cp), allow=allow)
    return sel.algorithm, sel.cost, sel.costs
