"""NCCL-style algorithm auto-selection.

NCCL "dynamically selects established algorithms based on different
situations" (paper Sec. III-B): small payloads favour latency-optimal
algorithms (tree / halving-doubling), large payloads favour bandwidth-
optimal rings.  We reproduce that behaviour with the alpha-beta models and
expose the crossover — benchmarks/collectives.py plots it per topology.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.ccl.algorithms import ALGORITHMS
from repro.ccl.cost import CostParams, algo_cost


def select_algorithm(primitive: str, size_bytes: int, p: int,
                     cp: CostParams,
                     allow: Optional[Tuple[str, ...]] = None
                     ) -> Tuple[str, float, Dict[str, float]]:
    """Returns (best_algorithm, predicted_cost, all_costs)."""
    costs = {}
    for name in ALGORITHMS[primitive]:
        if allow and name not in allow:
            continue
        if name == "halving_doubling" and p & (p - 1):
            continue  # needs power-of-two
        if name == "torus2d" and int(p ** 0.5) ** 2 != p:
            continue  # needs a square grid layout
        costs[name] = algo_cost(primitive, name, size_bytes, p, cp)
    best = min(costs, key=costs.get)
    return best, costs[best], costs
