"""Collective algorithms as explicit flow schedules.

Each generator takes a CommTask and emits the point-to-point flows of a
concrete algorithm, step by step — the "CCL generates communication
traffic" layer of the paper's paradigm.  The network layer (repro.net)
simulates these flows on a topology; repro.ccl.primitives executes the same
schedules as shard_map+ppermute JAX programs.

Conventions: ``size_bytes`` on the input task is the per-participant payload
(e.g. the gradient shard size for All-Reduce).  Flows carry actual wire
bytes per step.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, List, Sequence

from repro.core.demand import CommTask, Flow, FlowSet


def _ring_neighbors(group: Sequence[int]):
    p = len(group)
    return [(group[i], group[(i + 1) % p]) for i in range(p)]


# ---------------------------------------------------------------------------
# All-Reduce algorithms
# ---------------------------------------------------------------------------


def ring_all_reduce(task: CommTask) -> FlowSet:
    """Classic ring: (p-1) reduce-scatter steps + (p-1) all-gather steps,
    chunk = n/p per step.  Wire bytes per node: 2 n (p-1)/p."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="ring")
    if p == 1:
        return fs
    chunk = task.size_bytes // p
    step = 0
    for phase in range(2):  # 0 = reduce-scatter, 1 = all-gather
        for s in range(p - 1):
            for src, dst in _ring_neighbors(group):
                fs.flows.append(Flow(src, dst, chunk, task.task_id, step,
                                     task.job_id))
            step += 1
    fs.num_steps = step
    return fs


def bidir_ring_all_reduce(task: CommTask) -> FlowSet:
    """Two half-size rings in opposite directions (NCCL-style channels)."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="bidir_ring")
    if p == 1:
        return fs
    chunk = task.size_bytes // (2 * p)
    step = 0
    for phase in range(2):
        for s in range(p - 1):
            for src, dst in _ring_neighbors(group):
                fs.flows.append(Flow(src, dst, chunk, task.task_id, step,
                                     task.job_id))
                fs.flows.append(Flow(dst, src, chunk, task.task_id, step,
                                     task.job_id))
            step += 1
    fs.num_steps = step
    return fs


def halving_doubling_all_reduce(task: CommTask) -> FlowSet:
    """Recursive halving (reduce-scatter) + doubling (all-gather):
    2*log2(p) steps, latency-optimal for small payloads."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="halving_doubling")
    if p == 1:
        return fs
    assert p & (p - 1) == 0, "halving-doubling requires power-of-two group"
    step = 0
    # reduce-scatter: exchange halves at distance p/2, p/4, ...
    dist = p // 2
    size = task.size_bytes // 2
    while dist >= 1:
        for i, node in enumerate(group):
            peer = group[i ^ dist]
            fs.flows.append(Flow(node, peer, size, task.task_id, step,
                                 task.job_id))
        dist //= 2
        size //= 2
        step += 1
    # all-gather: reverse
    dist = 1
    size = task.size_bytes // p
    while dist < p:
        for i, node in enumerate(group):
            peer = group[i ^ dist]
            fs.flows.append(Flow(node, peer, size, task.task_id, step,
                                 task.job_id))
        dist *= 2
        size *= 2
        step += 1
    fs.num_steps = step
    return fs


def tree_all_reduce(task: CommTask) -> FlowSet:
    """Binary-tree reduce + broadcast: 2*ceil(log2 p) steps of full payload.
    Latency-friendly; bandwidth cost n*log(p) at the root links."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="tree")
    if p == 1:
        return fs
    depth = math.ceil(math.log2(p))
    step = 0
    # reduce towards group[0]
    stride = 1
    for _ in range(depth):
        for i in range(0, p, stride * 2):
            j = i + stride
            if j < p:
                fs.flows.append(Flow(group[j], group[i], task.size_bytes,
                                     task.task_id, step, task.job_id))
        stride *= 2
        step += 1
    # broadcast back down
    stride = 2 ** (depth - 1)
    for _ in range(depth):
        for i in range(0, p, stride * 2):
            j = i + stride
            if j < p:
                fs.flows.append(Flow(group[i], group[j], task.size_bytes,
                                     task.task_id, step, task.job_id))
        stride //= 2
        step += 1
    fs.num_steps = step
    return fs


# ---------------------------------------------------------------------------
# All-Gather / Reduce-Scatter / Broadcast / All-to-All
# ---------------------------------------------------------------------------


def ring_all_gather(task: CommTask) -> FlowSet:
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="ring_ag")
    chunk = task.size_bytes // max(p, 1)  # size_bytes = TOTAL payload
    for s in range(p - 1):
        for src, dst in _ring_neighbors(group):
            fs.flows.append(Flow(src, dst, chunk, task.task_id, s,
                                 task.job_id))
    fs.num_steps = max(p - 1, 0)
    return fs


def ring_reduce_scatter(task: CommTask) -> FlowSet:
    fs = ring_all_gather(task)
    fs.algorithm = "ring_rs"
    return fs


def binomial_broadcast(task: CommTask) -> FlowSet:
    """Binomial-tree broadcast from group[0]: log2(p) steps."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="binomial_bcast")
    have = [group[0]]
    step = 0
    rest = list(group[1:])
    while rest:
        senders = list(have)
        for s in senders:
            if not rest:
                break
            dst = rest.pop(0)
            fs.flows.append(Flow(s, dst, task.size_bytes, task.task_id, step,
                                 task.job_id))
            have.append(dst)
        step += 1
    fs.num_steps = step
    return fs


def direct_all_to_all(task: CommTask) -> FlowSet:
    """Every pair exchanges n/p directly in one logical step (switch fabric)
    — the MoE dispatch pattern."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="direct_a2a")
    chunk = task.size_bytes // max(p, 1)
    for src in group:
        for dst in group:
            if src != dst:
                fs.flows.append(Flow(src, dst, chunk, task.task_id, 0,
                                     task.job_id))
    fs.num_steps = 1
    return fs


def ring_all_to_all(task: CommTask) -> FlowSet:
    """p-1 rounds of neighbor exchange (torus-friendly A2A)."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="ring_a2a")
    chunk = task.size_bytes // max(p, 1)
    for s in range(p - 1):
        for src, dst in _ring_neighbors(group):
            # at round s the payload is everything still in flight: send the
            # chunk destined s+1 hops away; wire bytes stay n/p per step
            fs.flows.append(Flow(src, dst, chunk, task.task_id, s,
                                 task.job_id))
    fs.num_steps = max(p - 1, 0)
    return fs


def ring_permute(task: CommTask) -> FlowSet:
    """One collective-permute step: every participant sends its chunk to
    the next ring neighbor.  This is the unit step of a *decomposed*
    collective (``parallel/collective_matmul.py``): an All-Gather is p-1
    such permutes interleaved with p partial matmuls, a Reduce-Scatter
    p-1 permutes of the running accumulator — which is what lets the
    scheduler hide each step under the adjacent compute chunk."""
    group = task.group
    fs = FlowSet(task_id=task.task_id, algorithm="ring")
    if len(group) <= 1:
        return fs
    for src, dst in _ring_neighbors(group):
        fs.flows.append(Flow(src, dst, task.size_bytes, task.task_id, 0,
                             task.job_id))
    fs.num_steps = 1
    return fs


def torus2d_all_reduce(task: CommTask, rows: int = 0) -> FlowSet:
    """Dimension-ordered 2D-torus All-Reduce (what XLA emits on a TPU pod):
    ring reduce-scatter along rows, then along columns on the 1/rows
    shard, then all-gather back in reverse.  Wire bytes/node match the 1D
    ring (2n(p-1)/p) but the step count drops from 2(p-1) to
    2(rows-1) + 2(cols-1), and row/column phases use disjoint torus link
    dimensions.  Assumes ``group`` is laid out row-major rows x cols."""
    group = task.group
    p = len(group)
    if rows <= 0:
        rows = int(math.isqrt(p))
    cols = p // rows
    assert rows * cols == p, (rows, p)
    fs = FlowSet(task_id=task.task_id, algorithm="torus2d")
    if p == 1:
        return fs
    step = 0

    def ring_pass(groups, chunk, phases, step0):
        s = step0
        for _ in range(phases):
            for g in groups:
                for i in range(len(g)):
                    fs.flows.append(Flow(g[i], g[(i + 1) % len(g)], chunk,
                                         task.task_id, s, task.job_id))
            s += 1
        return s

    row_groups = [[group[r * cols + c] for c in range(cols)]
                  for r in range(rows)]
    col_groups = [[group[r * cols + c] for r in range(rows)]
                  for c in range(cols)]
    # RS along rows: chunks n/cols
    step = ring_pass(row_groups, task.size_bytes // cols, cols - 1, step)
    # RS along cols on the row-shard: chunks n/(cols*rows)
    step = ring_pass(col_groups, task.size_bytes // p, rows - 1, step)
    # AG along cols, then AG along rows
    step = ring_pass(col_groups, task.size_bytes // p, rows - 1, step)
    step = ring_pass(row_groups, task.size_bytes // cols, cols - 1, step)
    fs.num_steps = step
    return fs


def hierarchical_all_reduce(task: CommTask,
                            hosts: Sequence[Sequence[int]] = None) -> FlowSet:
    """The paper's "Intra-Inter" co-designed All-Reduce (Sec. IV-B; Horovod /
    BlueConnect-style): keep bulk traffic on the fast intra-host fabric and
    cross the slow NIC tier only once per host, via a leader.

      1. intra-host ring reduce-scatter   (m-1 steps, chunks n/m)
      2. shard relay to the host leader    (1 step; leader holds the host sum)
      3. ring all-reduce over the H leaders (2(H-1) steps on the NIC tier)
      4. shard relay back from the leader  (1 step)
      5. intra-host ring all-gather        (m-1 steps)

    NIC bytes per host drop from ~2n (flat ring crossing) to 2n(H-1)/H.
    ``hosts`` partitions ``task.group`` into equal-size hosts (first member
    = leader); default: contiguous blocks of 8 (the DGX convention)."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="hierarchical")
    if p == 1:
        return fs
    if hosts is None:
        if p > 8 and p % 8 == 0:
            hosts = [group[i:i + 8] for i in range(0, p, 8)]
        else:
            raise ValueError(
                f"cannot infer host partition for group of {p}; pass hosts=")
    hosts = [tuple(h) for h in hosts]
    sizes = {len(h) for h in hosts}
    hcount = len(hosts)
    if hcount < 2 or len(sizes) != 1 or sum(map(len, hosts)) != p:
        raise ValueError(
            f"hierarchical all-reduce needs >=2 equal-size hosts covering "
            f"the group; got sizes {sorted(map(len, hosts))} for p={p}")
    m = sizes.pop()
    if m == 1:
        return ring_all_reduce(task)  # every device its own host: flat ring
    n = task.size_bytes
    chunk = n // m
    step = 0

    def intra_ring_pass(phases: int, step0: int) -> int:
        s = step0
        for _ in range(phases):
            for h in hosts:
                for i in range(m):
                    fs.flows.append(Flow(h[i], h[(i + 1) % m], chunk,
                                         task.task_id, s, task.job_id))
            s += 1
        return s

    def relay(to_leader: bool, step0: int) -> int:
        for h in hosts:
            for dev in h[1:]:
                src, dst = (dev, h[0]) if to_leader else (h[0], dev)
                fs.flows.append(Flow(src, dst, chunk, task.task_id, step0,
                                     task.job_id))
        return step0 + 1

    step = intra_ring_pass(m - 1, step)          # reduce-scatter
    step = relay(True, step)                     # shards -> leader
    leaders = [h[0] for h in hosts]
    inter_chunk = n // hcount
    for _ in range(2):                           # leader ring AR (RS + AG)
        for _ in range(hcount - 1):
            for i in range(hcount):
                fs.flows.append(Flow(leaders[i], leaders[(i + 1) % hcount],
                                     inter_chunk, task.task_id, step,
                                     task.job_id))
            step += 1
    step = relay(False, step)                    # leader -> shards
    step = intra_ring_pass(m - 1, step)          # all-gather
    fs.num_steps = step
    return fs


def atp_all_reduce(task: CommTask, ps: int = None) -> FlowSet:
    """In-network aggregation All-Reduce (paper Sec. IV-B "Host-Net", ATP
    [15] / SwitchML-style): every worker pushes its full gradient toward an
    aggregation point and receives the sum back — two steps total.

    The flow schedule is a parameter-server pattern (workers -> ``ps``,
    ``ps`` -> workers; ``ps`` defaults to the group leader); the in-network
    part happens at simulation time: pricing it with
    ``aggregate_at=<programmable switches>`` merges the upstream flows at
    the first shared switch and multicasts the downstream ones, so each
    fabric link carries the payload once.  Without aggregation-capable
    switches this degrades to plain host PS aggregation — the multi-tenant
    switch-memory fallback."""
    group = task.group
    p = len(group)
    fs = FlowSet(task_id=task.task_id, algorithm="atp")
    if p == 1:
        return fs
    if ps is None:
        ps = group[0]
    for w in group:
        if w != ps:
            fs.flows.append(Flow(w, ps, task.size_bytes, task.task_id, 0,
                                 task.job_id))
    for w in group:
        if w != ps:
            fs.flows.append(Flow(ps, w, task.size_bytes, task.task_id, 1,
                                 task.job_id))
    fs.num_steps = 2
    return fs


def direct_p2p(task: CommTask) -> FlowSet:
    """Point-to-point transfer: one flow from ``group[0]`` to ``group[1]``
    (pipeline-parallel activation hand-off, serving KV-cache shard
    migration from a prefill rank to a decode rank).  Degenerate as a
    "collective", but routing it through the same FlowSet machinery means
    p2p traffic shows up in link utilization maps and contends in FlowSim
    like everything else."""
    group = task.group
    fs = FlowSet(task_id=task.task_id, algorithm="direct")
    if len(group) < 2 or group[0] == group[1]:
        return fs
    fs.flows.append(Flow(group[0], group[1], task.size_bytes, task.task_id,
                         0, task.job_id))
    fs.num_steps = 1
    return fs


# ---------------------------------------------------------------------------
# Compressed candidates (repro.compress): same schedule, fewer wire bytes
# ---------------------------------------------------------------------------


def compressed_flows(task: CommTask, base: str, codec_name: str,
                     **kwargs) -> FlowSet:
    """Wrap a base algorithm's schedule with a codec: every flow carries
    ``wire_ratio`` of its uncompressed bytes (encode before the wire,
    decode-accumulate after — the executable analogue is
    ``ccl.primitives.compressed_ring_all_reduce``).  ``base`` may be
    ``ps``, the parameter-server alias for the ``atp`` flow pattern.

    Approximation: the ratio is applied uniformly per step.  For top-k
    that understates later reduce-scatter steps (partial sums densify);
    the nominal ``CodecSpec.wire_ratio`` already includes index overhead
    to compensate."""
    from repro.compress.codec import base_algorithm, codec_spec

    spec = codec_spec(codec_name)
    gen = ALGORITHMS[task.primitive][base_algorithm(base)]
    fs = gen(task, **kwargs)
    fs.algorithm = f"{base}+{codec_name}"
    fs.flows = [
        dataclasses.replace(f, size_bytes=max(int(f.size_bytes
                                                  * spec.wire_ratio), 1))
        for f in fs.flows]
    return fs


# The canonical compressed all-reduce candidates selection prices (any
# "<base>+<codec>" pair also works ad hoc through generate_flows):
COMPRESSED_CANDIDATES = ("ring+q8", "bidir_ring+q8", "hierarchical+q8",
                         "ring+topk", "ps+topk")


def _compressed_registry() -> Dict[str, Callable[[CommTask], FlowSet]]:
    out: Dict[str, Callable[[CommTask], FlowSet]] = {}
    for name in COMPRESSED_CANDIDATES:
        base, codec = name.split("+", 1)
        out[name] = functools.partial(compressed_flows, base=base,
                                      codec_name=codec)
    return out


ALGORITHMS: Dict[str, Dict[str, Callable[[CommTask], FlowSet]]] = {
    "all_reduce": {
        "ring": ring_all_reduce,
        "bidir_ring": bidir_ring_all_reduce,
        "halving_doubling": halving_doubling_all_reduce,
        "tree": tree_all_reduce,
        "torus2d": torus2d_all_reduce,
        "hierarchical": hierarchical_all_reduce,
        "atp": atp_all_reduce,
        **_compressed_registry(),
    },
    "all_gather": {"ring": ring_all_gather},
    "reduce_scatter": {"ring": ring_reduce_scatter},
    "broadcast": {"binomial": binomial_broadcast},
    "all_to_all": {"direct": direct_all_to_all, "ring": ring_all_to_all},
    "permute": {"ring": ring_permute},
    "p2p": {"direct": direct_p2p},
}


def generate_flows(task: CommTask, algorithm: str, **kwargs) -> FlowSet:
    """Generate ``algorithm``'s flow schedule for ``task``.  Extra kwargs go
    to the generator (e.g. ``hosts=`` for hierarchical, ``rows=`` for
    torus2d).  ``"<base>+<codec>"`` names not in the canonical registry are
    composed on the fly (any base algorithm x registered codec)."""
    prims = ALGORITHMS[task.primitive]
    if algorithm not in prims:
        if "+" in algorithm:
            from repro.compress.codec import base_algorithm

            base, codec = algorithm.split("+", 1)
            if base_algorithm(algorithm) in prims:
                return compressed_flows(task, base, codec, **kwargs)
        raise KeyError(f"{algorithm!r} not available for {task.primitive}; "
                       f"have {list(prims)}")
    return prims[algorithm](task, **kwargs)
