"""TACCL-style sketch-guided collective synthesis (paper Sec. III-B, [5]).

Full synthesis is an NP-hard MILP (SCCL); TACCL's insight is that human
*communication sketches* (logical topology, switch hyper-edges, symmetry)
shrink the search to tractable size.  We reproduce that structure with a
greedy earliest-finish list scheduler over chunk-transfer moves:

  * the collective is a demand set: (chunk, src, dst) triples — plus, for
    All-Reduce, a reduce phase where every rank's *contribution* to a
    chunk must reach the chunk's owner before the reduced chunk fans out;
  * a ``Sketch`` restricts which links may carry chunks, how data routes
    through intermediate hops (e.g. "enter a host through GPU 0"), and —
    the plan-space hook — carries per-link *penalties* derived from a
    placement's hot-spot map, biasing chunk routes off contended uplinks;
  * chunks are scheduled along sketch-allowed shortest paths, tracking
    each link's busy time; ties broken by symmetry (rotated chunk order).

Output is a :class:`SynthSchedule` — an explicit move list that (a)
flattens to a step-indexed ``FlowSet`` both cost models price against the
registered ring/tree algorithms (``ccl.select``), and (b) lowers to an
executable ``shard_map`` program (``ccl.primitives.synthesized_collective``).
:class:`SynthCache` memoizes solver runs per (topology fingerprint,
primitive, group, size bucket, sketch) so repeated ``search()`` candidates
and ``ClusterDynamics`` re-plans re-use schedules, with ``cache_stats()``
telemetry like ``FlowSim``'s.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

import networkx as nx

from repro.core.demand import CommTask, Flow, FlowSet
from repro.net.topology import Topology
from repro.obs.meters import Meters

# primitives the synthesizer can produce schedules for
SYNTHESIZABLE = ("all_reduce", "all_gather", "broadcast", "all_to_all")


@dataclass(frozen=True)
class Sketch:
    """Designer hints that constrain the synthesis search space.

    ``allowed_links`` names *physical* links: permission is
    orientation-free, so listing ``(u, v)`` also admits ``(v, u)`` when
    the topology has the reverse edge (an asymmetric sketch used to
    KeyError when a shortest path traversed a link against its listed
    orientation).

    ``link_penalty`` maps a directed link to extra seconds charged per
    traversal *when choosing routes* (actual link occupancy stays
    physical): the TACCL-style soft constraint ``sketch_from_hotspots``
    builds from a placement's hot-spot map, steering chunks off links
    other traffic already contends on."""

    allowed_links: Optional[Set[Tuple]] = None   # None = all
    entry_nodes: Optional[Dict[str, int]] = None  # host tag -> preferred gpu
    rotational_symmetry: bool = True
    max_hops: int = 6
    link_penalty: Optional[Mapping[Tuple, float]] = None


def sketch_from_hotspots(topo: Topology,
                         util: Mapping[Tuple, float],
                         scale: float = 1.0,
                         max_hops: int = 6) -> Sketch:
    """A sketch whose link penalties are the seconds each link is already
    busy with *other* traffic (``bytes / bw``, scaled) — the codesign
    layer hands its per-link byte map here so synthesis routes the hot
    task's chunks around the links the rest of the plan contends on."""
    penalty: Dict[Tuple, float] = {}
    for (u, v), nbytes in util.items():
        if nbytes > 0 and topo.graph.has_edge(u, v):
            penalty[(u, v)] = scale * nbytes / topo.graph[u][v]["bw"]
    return Sketch(max_hops=max_hops, link_penalty=penalty or None)


@dataclass(frozen=True)
class Move:
    """One chunk transfer of a synthesized schedule: endpoint-level
    (``src`` holds the chunk, the fabric routes it), step-indexed for
    concurrency.  ``reduce`` marks a contribution being accumulated into
    the destination's chunk slot (All-Reduce reduce phase / in-switch
    aggregation analogue); gather moves overwrite."""

    chunk: int
    src: int
    dst: int
    step: int
    size_bytes: int
    reduce: bool = False


@dataclass
class SynthSchedule:
    """A synthesized collective as an explicit move list.

    ``num_chunks`` is the number of buffer slots the executable lowering
    needs per rank (= chunks the payload is split into).  ``moves`` are in
    list-scheduler emission order; within a step, earlier moves may feed
    later sub-batches of the same step only through *reduce*
    accumulation (never forwarding — the wave assignment guarantees a
    chunk received at step ``s`` is forwarded at step ``> s``)."""

    task_id: str
    primitive: str
    group: Tuple[int, ...]
    size_bytes: int
    chunk_bytes: int
    num_chunks: int
    moves: List[Move] = field(default_factory=list)
    num_steps: int = 0
    makespan: float = 0.0
    algorithm: str = "synthesized"

    def to_flowset(self, task_id: Optional[str] = None,
                   job_id: str = "job0", wire_ratio: float = 1.0,
                   algorithm: Optional[str] = None) -> FlowSet:
        """The move list as the step-indexed FlowSet the cost models
        price.  ``wire_ratio`` scales each flow's wire bytes for
        compressed variants (``synthesized+q8``)."""
        tid = task_id if task_id is not None else self.task_id
        fs = FlowSet(task_id=tid, algorithm=algorithm or self.algorithm)
        for m in self.moves:
            nbytes = max(int(m.size_bytes * wire_ratio), 1)
            fs.flows.append(Flow(m.src, m.dst, nbytes, tid, m.step, job_id))
        fs.num_steps = self.num_steps
        fs.makespan = self.makespan
        return fs

    def rescaled(self, size_bytes: int) -> "SynthSchedule":
        """The same routing structure at a different payload size (the
        cache's size-bucket hit path).  Move bytes scale exactly; the
        recorded makespan scales linearly — an approximation (latency
        terms don't scale), fine because pricing re-simulates the
        flowset and never reads ``makespan``."""
        if size_bytes == self.size_bytes:
            return self
        ratio = size_bytes / max(self.size_bytes, 1)
        chunk = max(int(round(self.chunk_bytes * ratio)), 1)
        moves = [dataclasses.replace(
                     m, size_bytes=max(int(round(m.size_bytes * ratio)), 1))
                 for m in self.moves]
        return dataclasses.replace(
            self, size_bytes=size_bytes, chunk_bytes=chunk, moves=moves,
            makespan=self.makespan * ratio)

    def wire_bytes(self) -> int:
        return sum(m.size_bytes for m in self.moves)


@dataclass(order=True)
class _Move:  # retained for backward import compatibility
    ready: float
    chunk: int = field(compare=False)
    at: int = field(compare=False)


def _demands_for(task: CommTask) -> List[Tuple[int, int, int]]:
    """(chunk_id, src, dst) triples for the collective."""
    g = list(task.group)
    p = len(g)
    out = []
    if task.primitive == "all_gather":
        for ci, src in enumerate(g):
            for dst in g:
                if dst != src:
                    out.append((ci, src, dst))
    elif task.primitive == "broadcast":
        for dst in g[1:]:
            out.append((0, g[0], dst))
    elif task.primitive == "all_to_all":
        cid = 0
        for src in g:
            for dst in g:
                if dst != src:
                    out.append((cid, src, dst))
                    cid += 1
    else:
        raise KeyError(f"synthesis supports AR/AG/bcast/A2A, not "
                       f"{task.primitive}")
    return out


def _sketch_graph(topo: Topology, sketch: Sketch):
    graph = topo.graph
    if sketch.allowed_links is not None:
        # sketches name physical links; admit both orientations that
        # exist so paths may traverse a listed link in reverse
        allowed = set()
        for u, v in sketch.allowed_links:
            for a, b in ((u, v), (v, u)):
                if topo.graph.has_edge(a, b):
                    allowed.add((a, b))
        graph = graph.edge_subgraph(allowed).copy()
    return graph


class _Router:
    """Greedy earliest-finish chunk router: shared link-occupancy clock,
    concurrency-wave step assignment, hot-link penalties for route
    *choice* (physical times stay unpenalized)."""

    def __init__(self, graph, chunk_bytes: int, sketch: Sketch):
        self.graph = graph
        self.sketch = sketch
        self.chunk_bytes = chunk_bytes
        self.tx = {(u, v): chunk_bytes / d["bw"] + d["lat"]
                   for u, v, d in graph.edges(data=True)}
        self.penalty = dict(sketch.link_penalty or {})
        self.link_free: Dict[Tuple, float] = {}
        # concurrency waves: transfers that share no link and whose chunk
        # is already in place run in the same step, so FlowSim prices the
        # greedy list schedule's real overlap, not a serialized chain.
        # Each link tracks the exact set of waves it is busy in, so a move
        # takes the *smallest* causally-valid wave free on every link of
        # its path (bumping a single max counter wasted waves badly on
        # star-shaped host fabrics, where a GPU's one ingress link is the
        # p-1 lower bound every schedule shares).
        self.link_used: Dict[Tuple, Set[int]] = {}
        self.chunk_wave: Dict[Tuple[int, int], int] = {}
        self.moves: List[Move] = []
        if self.penalty:
            pen = self.penalty

            def weight(u, v, d):
                return d["lat"] + pen.get((u, v), 0.0)

            self._weight = weight
        else:
            self._weight = "lat"

    def best_route(self, have: Mapping[int, float], dst):
        """Cheapest (finish time + penalty) source/path for reaching
        ``dst`` from any current holder; None when unreachable.

        Ties prefer the *newest* copy: freshly-delivered holders have idle
        egress links, so equal-finish choices spread sends across holders
        — a doubling tree (log-depth fan-out) instead of a star chained on
        the root's one egress link."""
        best = None
        holders = sorted(have.items(), key=lambda kv: kv[1], reverse=True)
        for holder, t_avail in holders:
            try:
                path = nx.shortest_path(self.graph, holder, dst,
                                        weight=self._weight)
            except nx.NetworkXNoPath:
                continue
            if len(path) - 1 > self.sketch.max_hops:
                continue
            # simulate link occupancy along the path
            t = t_avail
            pen = 0.0
            for u, v in zip(path[:-1], path[1:]):
                start = max(t, self.link_free.get((u, v), 0.0))
                t = start + self.tx[(u, v)]
                pen += self.penalty.get((u, v), 0.0)
            if best is None or t + pen < best[0]:
                best = (t + pen, t, holder, path)
        return best

    def commit(self, chunk: int, holder, dst, path, t_avail: float,
               reduce: bool = False, min_step: int = 0) -> Tuple[float, int]:
        """Occupy the path's links, assign the move's concurrency wave,
        and record the move.  Returns (arrival time, step)."""
        path_links = list(zip(path[:-1], path[1:]))
        # the move's wave: after the chunk reached the holder, in the
        # first wave no link of its path already carries another move
        step = max(self.chunk_wave.get((chunk, holder), 0), min_step)
        used = [self.link_used.setdefault(link, set())
                for link in path_links]
        while any(step in u for u in used):
            step += 1
        t = t_avail
        for (u, v), waves in zip(path_links, used):
            start = max(t, self.link_free.get((u, v), 0.0))
            t = start + self.tx[(u, v)]
            self.link_free[(u, v)] = t
            waves.add(step)
        self.chunk_wave[(chunk, dst)] = step + 1
        self.moves.append(Move(chunk, holder, dst, step, self.chunk_bytes,
                               reduce))
        return t, step

    @property
    def makespan(self) -> float:
        return max(self.link_free.values(), default=0.0)

    @property
    def num_steps(self) -> int:
        return max((m.step for m in self.moves), default=-1) + 1


def _route_pending(router: _Router, demands, have, max_hops_guard=None):
    """The list-scheduler loop: repeatedly route every still-unsatisfied
    (chunk, src, dst) demand from its earliest-available holder, letting
    delivered copies become forwarding sources."""
    pending = list(demands)
    max_rounds = len(pending) * 4
    rounds = 0
    while pending and rounds < max_rounds:
        rounds += 1
        progressed = []
        for (ci, src, dst) in pending:
            if dst in have[ci]:
                progressed.append((ci, src, dst))
                continue
            best = router.best_route(have[ci], dst)
            if best is None:
                continue
            _, _, holder, path = best
            t, _ = router.commit(ci, holder, dst, path, have[ci][holder])
            have[ci][dst] = t
            progressed.append((ci, src, dst))
        pending = [d for d in pending if d not in progressed]
        if not progressed:
            break


def _synthesize_gather_like(topo: Topology, task: CommTask,
                            sketch: Sketch) -> SynthSchedule:
    g = list(task.group)
    p = len(g)
    # size_bytes = TOTAL payload; one chunk = one node's contribution
    chunk_bytes = (task.size_bytes // max(p, 1)
                   if task.primitive in ("all_gather", "all_to_all")
                   else task.size_bytes)
    chunk_bytes = max(chunk_bytes, 1)
    demands = _demands_for(task)
    graph = _sketch_graph(topo, sketch)
    router = _Router(graph, chunk_bytes, sketch)
    have: Dict[int, Dict[int, float]] = {}
    for ci, src, _ in demands:
        have.setdefault(ci, {})[src] = 0.0
    # order demands for symmetry: rotate through sources round-robin
    if sketch.rotational_symmetry:
        demands = sorted(demands, key=lambda d: (d[0] % p, d[0], d[1]))
    _route_pending(router, demands, have)
    num_chunks = len(have)
    return SynthSchedule(
        task_id=task.task_id, primitive=task.primitive, group=tuple(g),
        size_bytes=task.size_bytes, chunk_bytes=chunk_bytes,
        num_chunks=num_chunks, moves=router.moves,
        num_steps=router.num_steps, makespan=router.makespan)


def _synthesize_all_reduce(topo: Topology, task: CommTask,
                           sketch: Sketch) -> SynthSchedule:
    """Mirrored-tree synthesis: chunk ``c`` is owned by rank ``group[c]``.
    The router synthesizes a fan-*out* forwarding tree per chunk (owner ->
    everyone, the all-gather structure); the reduce phase is that tree
    *reversed* — leaves push partial sums toward the owner, interior
    ranks accumulate before forwarding (``Move.reduce``), so each
    contribution crosses every tree edge exactly once.  Wire bytes are
    ``2 n (p-1)/p`` per rank on average — exactly the ring's — but the
    routes follow the topology (and the sketch's hot-link penalties)
    instead of a fixed neighbor order.

    Causality of the reversal: a fan-out edge delivered at wave ``w``
    becomes a reduce edge at wave ``S-1-w``; every child edge has
    ``w_child > w_parent`` in the fan-out, so in reverse each rank sends
    its partial sum strictly after all its children's arrive — the
    ordering the executable lowering (and the replay property test)
    relies on."""
    g = list(task.group)
    p = len(g)
    chunk_bytes = max(task.size_bytes // max(p, 1), 1)
    graph = _sketch_graph(topo, sketch)
    router = _Router(graph, chunk_bytes, sketch)

    # --- synthesize the fan-out trees (the gather phase) ----------------
    have: Dict[int, Dict[int, float]] = {c: {g[c]: 0.0} for c in range(p)}
    demands = [(c, g[c], dst) for c in range(p) for dst in g if dst != g[c]]
    if sketch.rotational_symmetry:
        demands = sorted(demands, key=lambda d: (d[0] % p, d[0], d[1]))
    _route_pending(router, demands, have)
    gather = router.moves
    span = max((m.step for m in gather), default=-1) + 1

    # --- reduce phase = the same trees, reversed ------------------------
    reduce_moves = [
        dataclasses.replace(m, src=m.dst, dst=m.src,
                            step=span - 1 - m.step, reduce=True)
        for m in gather]
    reduce_moves.sort(key=lambda m: m.step)
    moves = reduce_moves + [dataclasses.replace(m, step=m.step + span)
                            for m in gather]
    return SynthSchedule(
        task_id=task.task_id, primitive="all_reduce", group=tuple(g),
        size_bytes=task.size_bytes, chunk_bytes=chunk_bytes, num_chunks=p,
        moves=moves, num_steps=2 * span,
        makespan=2 * router.makespan)


def synthesize_schedule(topo: Topology, task: CommTask,
                        sketch: Optional[Sketch] = None) -> SynthSchedule:
    """Greedy earliest-finish chunk routing under sketch constraints,
    returning the full move-list schedule (price it, lower it, or
    flatten it with ``to_flowset``)."""
    sketch = sketch or Sketch()
    if task.primitive == "all_reduce":
        return _synthesize_all_reduce(topo, task, sketch)
    return _synthesize_gather_like(topo, task, sketch)


def synthesize(topo: Topology, task: CommTask,
               sketch: Optional[Sketch] = None) -> FlowSet:
    """Greedy earliest-finish chunk routing under sketch constraints
    (the FlowSet view of :func:`synthesize_schedule`)."""
    return synthesize_schedule(topo, task, sketch).to_flowset(
        job_id=task.job_id)


def atp_schedule(task: CommTask, ps: Optional[int] = None) -> SynthSchedule:
    """The priced ``atp`` candidate as a synthesizable schedule: every
    worker's full payload converges on the aggregation point (reduce
    moves — in-network the switches merge them; as an executable program
    the aggregation point accumulates), then the sum multicasts back.
    One chunk slot, two steps: the executable analogue of
    ``ccl.algorithms.atp_all_reduce``, lowered by
    ``ccl.primitives.synthesized_collective``."""
    g = list(task.group)
    if ps is None:
        ps = g[0]
    n = max(task.size_bytes, 1)
    moves = [Move(0, w, ps, 0, n, reduce=True) for w in g if w != ps]
    moves += [Move(0, ps, w, 1, n) for w in g if w != ps]
    return SynthSchedule(
        task_id=task.task_id, primitive="all_reduce", group=tuple(g),
        size_bytes=task.size_bytes, chunk_bytes=n, num_chunks=1,
        moves=moves, num_steps=2, makespan=0.0, algorithm="synthesized_atp")


# ---------------------------------------------------------------------------
# Memoization: (topology, primitive, group, size bucket, sketch) -> schedule
# ---------------------------------------------------------------------------


def topology_fingerprint(topo: Topology) -> str:
    """Stable (cross-process) identity of a topology's wiring: name,
    hosts, and every directed link with its bandwidth/latency.  Memoized
    on the instance — degradation views (``without_link`` / ``scaled_bw``)
    are fresh objects and fingerprint differently, exactly as re-planning
    needs."""
    cached = topo.__dict__.get("_fingerprint")
    if cached is None:
        edges = sorted((str(u), str(v), f"{d['bw']:.6e}", f"{d['lat']:.6e}")
                       for u, v, d in topo.graph.edges(data=True))
        payload = repr((topo.name, tuple(topo.accelerators),
                        tuple(topo.hosts), edges))
        cached = hashlib.sha1(payload.encode()).hexdigest()[:16]
        topo.__dict__["_fingerprint"] = cached
    return cached


def _sketch_key(sketch: Optional[Sketch]) -> Tuple:
    if sketch is None:
        return ()
    links = tuple(sorted(map(str, sketch.allowed_links))) \
        if sketch.allowed_links is not None else None
    entries = tuple(sorted(sketch.entry_nodes.items())) \
        if sketch.entry_nodes else None
    penalty = tuple(sorted((str(k), round(v, 12))
                           for k, v in sketch.link_penalty.items())) \
        if sketch.link_penalty else None
    return (links, entries, sketch.rotational_symmetry, sketch.max_hops,
            penalty)


def _size_bucket(size_bytes: int) -> int:
    """Power-of-two size bucket: schedules for 3 MiB and 3.9 MiB share
    routing structure, so the cache re-serves one rescaled schedule."""
    return int(size_bytes).bit_length()


class SynthCache:
    """Memoizes :func:`synthesize_schedule` per (topology fingerprint,
    primitive, group, size bucket, sketch key).  Hits at a different
    exact size inside the same power-of-two bucket are rescaled (same
    routes, proportional bytes).  ``cache_stats()`` mirrors
    ``FlowSim.cache_stats()`` so ``search()`` telemetry merges both."""

    def __init__(self, meters: Optional[Meters] = None):
        self._memo: Dict[Tuple, SynthSchedule] = {}
        self.meters = meters if meters is not None else Meters()

    def schedule(self, topo: Topology, task: CommTask,
                 sketch: Optional[Sketch] = None) -> SynthSchedule:
        key = (topology_fingerprint(topo), task.primitive, task.group,
               _size_bucket(task.size_bytes), _sketch_key(sketch))
        sched = self._memo.get(key)
        if sched is None:
            self.meters.incr("synth.miss")
            sched = synthesize_schedule(topo, task, sketch)
            self._memo[key] = sched
        else:
            self.meters.incr("synth.hit")
        if sched.size_bytes != task.size_bytes:
            sched = sched.rescaled(task.size_bytes)
        if sched.task_id != task.task_id:
            sched = dataclasses.replace(sched, task_id=task.task_id)
        return sched

    def cache_stats(self) -> Dict[str, float]:
        out = self.meters.snapshot()
        rate = self.meters.ratio("synth.hit", "synth.miss")
        if rate is not None:
            out["synth.hit_rate"] = rate
        out["synth.entries"] = float(len(self._memo))
        return out


#: the process-wide solver cache ``codesign.plan`` routes through, so a
#: search's candidates and an event-driven re-plan share synthesized
#: schedules across calls
DEFAULT_SYNTH_CACHE = SynthCache()


def synthesized_time(topo: Topology, task: CommTask,
                     sketch: Optional[Sketch] = None) -> float:
    """Predicted completion time of the synthesized schedule (the link-
    occupancy makespan computed during synthesis)."""
    sketch = sketch or Sketch()
    # re-run synthesis, tracking makespan
    fs = synthesize(topo, task, sketch)
    # makespan proxy: serial per-link occupancy — recompute via simulate
    from repro.net.simulate import link_utilization
    util = link_utilization(topo, fs)
    t = 0.0
    for (u, v), nbytes in util.items():
        if topo.graph.has_edge(u, v):
            t = max(t, nbytes / topo.graph[u][v]["bw"])
    return t
