"""TACCL-style sketch-guided collective synthesis (paper Sec. III-B, [5]).

Full synthesis is an NP-hard MILP (SCCL); TACCL's insight is that human
*communication sketches* (logical topology, switch hyper-edges, symmetry)
shrink the search to tractable size.  We reproduce that structure with a
greedy earliest-finish list scheduler over chunk-transfer moves:

  * the collective is a demand set: (chunk, src, dst) triples;
  * a ``Sketch`` restricts which links may carry chunks and how data should
    route through intermediate hops (e.g. "enter a host through GPU 0");
  * chunks are scheduled along sketch-allowed shortest paths, tracking each
    link's busy time; ties broken by symmetry (rotated chunk order).

Output is a step-indexed FlowSet comparable (and compared, in benchmarks)
against the fixed ring/tree algorithms on heterogeneous topologies.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import networkx as nx

from repro.core.demand import CommTask, Flow, FlowSet
from repro.net.topology import Topology


@dataclass(frozen=True)
class Sketch:
    """Designer hints that constrain the synthesis search space.

    ``allowed_links`` names *physical* links: permission is
    orientation-free, so listing ``(u, v)`` also admits ``(v, u)`` when
    the topology has the reverse edge (an asymmetric sketch used to
    KeyError when a shortest path traversed a link against its listed
    orientation)."""

    allowed_links: Optional[Set[Tuple]] = None   # None = all
    entry_nodes: Optional[Dict[str, int]] = None  # host tag -> preferred gpu
    rotational_symmetry: bool = True
    max_hops: int = 6


@dataclass(order=True)
class _Move:
    ready: float
    chunk: int = field(compare=False)
    at: int = field(compare=False)


def _demands_for(task: CommTask) -> List[Tuple[int, int, int]]:
    """(chunk_id, src, dst) triples for the collective."""
    g = list(task.group)
    p = len(g)
    out = []
    if task.primitive == "all_gather":
        for ci, src in enumerate(g):
            for dst in g:
                if dst != src:
                    out.append((ci, src, dst))
    elif task.primitive == "broadcast":
        for dst in g[1:]:
            out.append((0, g[0], dst))
    elif task.primitive == "all_to_all":
        cid = 0
        for src in g:
            for dst in g:
                if dst != src:
                    out.append((cid, src, dst))
                    cid += 1
    else:
        raise KeyError(f"synthesis supports AG/bcast/A2A, not "
                       f"{task.primitive}")
    return out


def synthesize(topo: Topology, task: CommTask,
               sketch: Optional[Sketch] = None) -> FlowSet:
    """Greedy earliest-finish chunk routing under sketch constraints."""
    sketch = sketch or Sketch()
    g = list(task.group)
    p = len(g)
    # size_bytes = TOTAL payload; one chunk = one node's contribution
    chunk_bytes = (task.size_bytes // max(p, 1)
                   if task.primitive in ("all_gather", "all_to_all")
                   else task.size_bytes)
    demands = _demands_for(task)

    graph = topo.graph
    if sketch.allowed_links is not None:
        # sketches name physical links; admit both orientations that
        # exist so paths may traverse a listed link in reverse
        allowed = set()
        for u, v in sketch.allowed_links:
            for a, b in ((u, v), (v, u)):
                if topo.graph.has_edge(a, b):
                    allowed.add((a, b))
        graph = graph.edge_subgraph(allowed).copy()

    link_free: Dict[Tuple, float] = {}
    have: Dict[int, Dict[int, float]] = {}  # chunk -> node -> time available
    for ci, src, _ in demands:
        have.setdefault(ci, {})[src] = 0.0

    # order demands for symmetry: rotate through sources round-robin
    if sketch.rotational_symmetry:
        demands = sorted(demands, key=lambda d: (d[0] % p, d[0], d[1]))

    fs = FlowSet(task_id=task.task_id, algorithm="synthesized")
    tx_time = {}
    for u, v, d in graph.edges(data=True):
        tx_time[(u, v)] = chunk_bytes / d["bw"] + d["lat"]
    # concurrency rounds: transfers that share no link and whose chunk is
    # already in place run in the same step, so FlowSim prices the greedy
    # list schedule's real overlap instead of a fully serialized chain
    link_wave: Dict[Tuple, int] = {}
    chunk_wave: Dict[Tuple[int, int], int] = {}

    pending = list(demands)
    max_rounds = len(pending) * 4
    rounds = 0
    events: List[Tuple[float, int, int]] = []
    while pending and rounds < max_rounds:
        rounds += 1
        progressed = []
        for (ci, src, dst) in pending:
            if dst in have[ci]:
                progressed.append((ci, src, dst))
                continue
            # route from the earliest-available holder along shortest path
            best = None
            for holder, t_avail in have[ci].items():
                try:
                    path = nx.shortest_path(graph, holder, dst, weight="lat")
                except nx.NetworkXNoPath:
                    continue
                if len(path) - 1 > sketch.max_hops:
                    continue
                # simulate link occupancy along the path
                t = t_avail
                for u, v in zip(path[:-1], path[1:]):
                    start = max(t, link_free.get((u, v), 0.0))
                    t = start + tx_time[(u, v)]
                if best is None or t < best[0]:
                    best = (t, holder, path)
            if best is None:
                continue
            t_final, holder, path = best
            t = have[ci][holder]
            path_links = list(zip(path[:-1], path[1:]))
            # the move's round: after the chunk reached the holder, and
            # after every earlier occupant of the links it crosses
            step = chunk_wave.get((ci, holder), 0)
            for link in path_links:
                step = max(step, link_wave.get(link, 0))
            for u, v in path_links:
                start = max(t, link_free.get((u, v), 0.0))
                t = start + tx_time[(u, v)]
                link_free[(u, v)] = t
                link_wave[(u, v)] = step + 1
            have[ci][dst] = t
            chunk_wave[(ci, dst)] = step + 1
            # endpoint-level flow (the simulator re-routes along the path)
            fs.flows.append(Flow(holder, dst, chunk_bytes, task.task_id,
                                 step, task.job_id))
            progressed.append((ci, src, dst))
        pending = [d for d in pending if d not in progressed]
        if not progressed:
            break
    fs.num_steps = max((f.step for f in fs.flows), default=-1) + 1
    # the greedy list schedule's own makespan (link-occupancy tracking)
    fs.makespan = max(link_free.values(), default=0.0)
    return fs


def synthesized_time(topo: Topology, task: CommTask,
                     sketch: Optional[Sketch] = None) -> float:
    """Predicted completion time of the synthesized schedule (the link-
    occupancy makespan computed during synthesis)."""
    sketch = sketch or Sketch()
    # re-run synthesis, tracking makespan
    fs = synthesize(topo, task, sketch)
    # makespan proxy: serial per-link occupancy — recompute via simulate
    from repro.net.simulate import link_utilization
    util = link_utilization(topo, fs)
    t = 0.0
    for (u, v), nbytes in util.items():
        if topo.graph.has_edge(u, v):
            t = max(t, nbytes / topo.graph[u][v]["bw"])
    return t
