from repro.checkpoint.io import (checkpoint_state_bytes,  # noqa: F401
                                 restore_checkpoint, save_checkpoint)
