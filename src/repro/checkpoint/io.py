"""Checkpointing: msgpack-framed numpy pytree save/restore with step metadata.

Layout: <dir>/step_<n>/{manifest.msgpack, arrays.npz}.  Arrays are gathered
to host (fine at the model sizes the examples train); the manifest stores
the pytree structure so restore rebuilds the exact pytree.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np


def checkpoint_state_bytes(cfg, param_bytes: int = 4,
                           moment_bytes: int = 4, moments: int = 2) -> int:
    """Bytes a tenant re-ingests on checkpoint-restore: f32 master params
    plus the optimizer moments (AdamW: two f32 tensors per param), 12
    bytes/param by default.  ZeRO-1 sharding changes who holds which
    shard, not the total that must cross the job's ingress links, so the
    estimate is sharding-independent.  Pure arithmetic over
    ``ModelConfig.param_counts()`` — usable by the cluster-dynamics
    planner without touching the filesystem."""
    total = cfg.param_counts()["total"]
    return int(total * (param_bytes + moments * moment_bytes))


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for kp, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        flat[key] = np.asarray(leaf)
    return flat, jax.tree_util.tree_structure(tree)


def save_checkpoint(ckpt_dir: str, step: int, params: Any,
                    opt_state: Optional[Any] = None,
                    extra: Optional[Dict] = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    blobs = {}
    manifest: Dict[str, Any] = {"step": step, "extra": extra or {}}
    for name, tree in (("params", params), ("opt_state", opt_state)):
        if tree is None:
            continue
        flat, _ = _flatten(tree)
        manifest[name + "_keys"] = sorted(flat)
        for k, v in flat.items():
            blobs[f"{name}/{k}"] = v
    np.savez(os.path.join(path, "arrays.npz"), **blobs)
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return path


def _unflatten_like(template: Any, flat: Dict[str, np.ndarray], prefix: str):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for kp, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = flat[f"{prefix}/{key}"]
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_checkpoint(path: str, params_template: Any,
                       opt_template: Optional[Any] = None
                       ) -> Tuple[Any, Optional[Any], int]:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat = {k: npz[k] for k in npz.files}
    params = _unflatten_like(params_template, flat, "params")
    opt = None
    if opt_template is not None:
        opt = _unflatten_like(opt_template, flat, "opt_state")
    return params, opt, int(manifest["step"])
