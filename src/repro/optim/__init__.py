from repro.optim.adamw import adamw_update, init_opt_state  # noqa: F401
from repro.optim.schedule import lr_schedule  # noqa: F401
