"""AdamW with global-norm clipping.  Optimizer states are plain pytrees so
the ZeRO-1 planner (repro.parallel.planner.zero1_spec) can shard them over
the data axis — the survey's DP All-Reduce becomes Reduce-Scatter +
All-Gather, reducing gradient-sync traffic per device by (dp-1)/dp."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import TrainConfig


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 tcfg: TrainConfig, lr: jax.Array
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    b1, b2 = tcfg.beta1, tcfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + tcfg.eps)
        update = update + tcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), \
            m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm}
