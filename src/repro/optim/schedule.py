"""Linear-warmup + cosine-decay learning-rate schedule."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import TrainConfig


def lr_schedule(step, tcfg: TrainConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(tcfg.warmup_steps, 1))
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / max(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)
