"""Network topologies for distributed training (paper Sec. II-D).

Builders for the topology families the survey discusses: fat-tree (+ over-
subscription), 2D/3D torus (TPU pods), ring, full-mesh, and the DGX-style
intra-host NVLink ring+mesh with slower inter-host links — the heterogeneous
"Intra-Inter" setting of Sec. IV-B.  Backed by networkx for path queries.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import networkx as nx


@dataclass
class Topology:
    """Directed multigraph of GPUs/TPUs (+switch nodes) with per-link
    bandwidth (bytes/s) and latency (s).

    ``hosts`` partitions the accelerators into physical hosts (empty = no
    host structure, e.g. a TPU torus where every chip talks ICI directly).
    The codesign layer uses it for placement and for hierarchical
    (intra-host / inter-host) collective decomposition.
    """

    graph: nx.DiGraph
    name: str = "custom"
    accelerators: Tuple[int, ...] = ()
    hosts: Tuple[Tuple[int, ...], ...] = ()

    # ------------------------------------------------------------------
    def link_bw(self, u, v) -> float:
        return self.graph[u][v]["bw"]

    def links(self) -> Iterable[Tuple[int, int, dict]]:
        return self.graph.edges(data=True)

    def path(self, src, dst) -> List:
        """Latency-weighted shortest path (list of nodes)."""
        return nx.shortest_path(self.graph, src, dst, weight="lat")

    def path_links(self, src, dst) -> Tuple[Tuple, ...]:
        """Links of the latency-weighted shortest path, memoized — the flow
        simulator queries the same pairs for every step of a schedule.
        (Assumes the graph is not mutated after the first query.)"""
        cache = self.__dict__.setdefault("_path_cache", {})
        key = (src, dst)
        if key not in cache:
            p = self.path(src, dst)
            cache[key] = tuple(zip(p[:-1], p[1:]))
        return cache[key]

    # ------------------------------------------------------------------
    # Host / switch structure (codesign + ATP consumers)
    # ------------------------------------------------------------------

    def switch_nodes(self) -> Tuple:
        """Non-accelerator nodes (ToR/Agg/Core switches, host NICs, DCN
        routers) — the candidates for in-network aggregation."""
        accel = set(self.accelerators)
        return tuple(n for n in self.graph.nodes if n not in accel)

    def host_of(self, device) -> int:
        """Index into ``hosts`` of the host owning ``device`` (-1 if the
        topology has no host structure or the device is unassigned)."""
        lookup = self.__dict__.get("_host_lookup")
        if lookup is None:
            lookup = {d: h for h, devs in enumerate(self.hosts)
                      for d in devs}
            self.__dict__["_host_lookup"] = lookup
        return lookup.get(device, -1)

    def host_groups(self, group: Iterable[int]
                    ) -> Tuple[Tuple[int, ...], ...]:
        """Partition ``group`` (physical device ids) by host, preserving
        the group's order within each host.  Devices without a host each
        form a singleton."""
        buckets: Dict[int, List[int]] = {}
        order: List[int] = []
        for i, d in enumerate(group):
            h = self.host_of(d)
            key = h if h >= 0 else -(i + 2)  # unassigned: unique bucket
            if key not in buckets:
                buckets[key] = []
                order.append(key)
            buckets[key].append(d)
        return tuple(tuple(buckets[k]) for k in order)

    def bisection_bw(self) -> float:
        """Max-flow bandwidth across a node-count bisection of the
        accelerators (switch nodes route flow, they don't count as
        endpoints)."""
        n = len(self.accelerators)
        left = self.accelerators[: n // 2]
        right = self.accelerators[n // 2:]
        g = nx.DiGraph()
        for u, v, d in self.graph.edges(data=True):
            g.add_edge(u, v, capacity=d["bw"])
        inf = float("inf")
        for u in left:
            g.add_edge("__s", u, capacity=inf)
        for v in right:
            g.add_edge(v, "__t", capacity=inf)
        return nx.maximum_flow_value(g, "__s", "__t")

    @property
    def num_accelerators(self) -> int:
        return len(self.accelerators)

    # ------------------------------------------------------------------
    # Degradation views (codesign.dynamics consumers)
    # ------------------------------------------------------------------
    #
    # Production clusters churn: links fail or degrade, hosts drop out.
    # Each view returns a NEW Topology sharing nothing mutable with this
    # one (fresh graph copy, fresh path/host caches), so the event loop
    # can re-plan on the degraded fabric while the base topology keeps
    # answering queries for the healthy state.

    def without_link(self, u, v, symmetric: bool = True) -> "Topology":
        """View with the ``u<->v`` link removed (``symmetric=False`` drops
        only the ``u->v`` orientation).  Missing edges are ignored, so
        stacking failures is idempotent."""
        g = self.graph.copy()
        for a, b in ((u, v), (v, u)) if symmetric else ((u, v),):
            if g.has_edge(a, b):
                g.remove_edge(a, b)
        return Topology(g, name=f"{self.name}-link({u},{v})",
                        accelerators=self.accelerators, hosts=self.hosts)

    def without_host(self, host: int) -> "Topology":
        """View with one host's accelerators (and their incident links)
        removed.  ``host`` indexes ``hosts``; the surviving hosts keep
        their relative order (indices shift — views are snapshots, not
        stable ids)."""
        if not 0 <= host < len(self.hosts):
            raise ValueError(f"host {host} out of range "
                             f"(topology has {len(self.hosts)} hosts)")
        dead = set(self.hosts[host])
        g = self.graph.copy()
        g.remove_nodes_from(dead)
        return Topology(
            g, name=f"{self.name}-host{host}",
            accelerators=tuple(a for a in self.accelerators
                               if a not in dead),
            hosts=tuple(h for i, h in enumerate(self.hosts) if i != host))

    def scaled_bw(self, factors) -> "Topology":
        """View with link bandwidths scaled: ``factors`` is either one
        float applied to every link, or a ``{(u, v): factor}`` map (each
        entry scales both orientations of its link; factors must be
        > 0 — use :meth:`without_link` for outright failure)."""
        # normalize to one factor per *directed* edge before applying:
        # a dict entry names a physical link (both orientations), but the
        # scalar form enumerates graph.edges(), which already lists each
        # orientation — expanding those to both directions again would
        # scale every link twice
        per_edge = {}
        if not isinstance(factors, dict):
            per_edge = {(u, v): float(factors)
                        for u, v in self.graph.edges()}
        else:
            for (u, v), f in factors.items():
                for a, b in ((u, v), (v, u)):
                    if self.graph.has_edge(a, b):
                        per_edge[(a, b)] = f
        g = self.graph.copy()
        for (u, v), f in per_edge.items():
            if f <= 0:
                raise ValueError(f"bandwidth factor for ({u}, {v}) must "
                                 f"be > 0, got {f} (use without_link)")
            g[u][v]["bw"] = g[u][v]["bw"] * f
        return Topology(g, name=f"{self.name}-degraded",
                        accelerators=self.accelerators, hosts=self.hosts)


def _new_graph():
    return nx.DiGraph()


def _bilink(g, u, v, bw, lat):
    g.add_edge(u, v, bw=bw, lat=lat)
    g.add_edge(v, u, bw=bw, lat=lat)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def ring(n: int, bw: float = 50e9, lat: float = 1e-6) -> Topology:
    g = _new_graph()
    for i in range(n):
        _bilink(g, i, (i + 1) % n, bw, lat)
    return Topology(g, name=f"ring{n}", accelerators=tuple(range(n)))


def full_mesh(n: int, bw: float = 50e9, lat: float = 1e-6) -> Topology:
    g = _new_graph()
    for i, j in itertools.combinations(range(n), 2):
        _bilink(g, i, j, bw, lat)
    return Topology(g, name=f"mesh{n}", accelerators=tuple(range(n)))


def torus2d(nx_: int, ny: int, bw: float = 50e9, lat: float = 1e-6
            ) -> Topology:
    """2D torus with wraparound (TPU v5e pod = 16x16)."""
    g = _new_graph()
    def nid(x, y):
        return x * ny + y
    for x in range(nx_):
        for y in range(ny):
            _bilink(g, nid(x, y), nid((x + 1) % nx_, y), bw, lat)
            _bilink(g, nid(x, y), nid(x, (y + 1) % ny), bw, lat)
    return Topology(g, name=f"torus{nx_}x{ny}",
                    accelerators=tuple(range(nx_ * ny)))


def torus3d(a: int, b: int, c: int, bw: float = 50e9, lat: float = 1e-6
            ) -> Topology:
    """3D torus (TPU v4, [4] in the paper)."""
    g = _new_graph()
    def nid(x, y, z):
        return (x * b + y) * c + z
    for x in range(a):
        for y in range(b):
            for z in range(c):
                _bilink(g, nid(x, y, z), nid((x + 1) % a, y, z), bw, lat)
                _bilink(g, nid(x, y, z), nid(x, (y + 1) % b, z), bw, lat)
                _bilink(g, nid(x, y, z), nid(x, y, (z + 1) % c), bw, lat)
    return Topology(g, name=f"torus{a}x{b}x{c}",
                    accelerators=tuple(range(a * b * c)))


def fat_tree(num_hosts: int, gpus_per_host: int = 8,
             nic_bw: float = 25e9, agg_bw: float = 100e9,
             core_bw: float = 400e9, oversub: float = 1.0,
             pcie_bw: float = 32e9, lat: float = 2e-6,
             hosts_per_rack: int = 4, racks_per_pod: int = 4,
             agg_redundancy: int = 1) -> Topology:
    """Three-tier fat-tree (ToR / Agg / Core) with hosts of ``gpus_per_host``
    GPUs behind a NIC — the Fig. 5(b) setting.  ``oversub`` > 1 thins the
    uplinks.  ``agg_redundancy`` > 1 gives each pod that many parallel agg
    switches (every ToR uplinks to all of them, per-uplink bandwidth split
    so pod capacity is unchanged) — the multi-path tier that lets
    ``Topology.without_link`` failures re-route instead of partitioning
    the tree."""
    if agg_redundancy < 1:
        raise ValueError(f"agg_redundancy must be >= 1, got "
                         f"{agg_redundancy}")
    g = _new_graph()
    accel = []
    num_racks = (num_hosts + hosts_per_rack - 1) // hosts_per_rack
    num_pods = (num_racks + racks_per_pod - 1) // racks_per_pod
    core = "core"

    def agg_name(pod: int, k: int) -> str:
        # keep the legacy single-agg node names so redundancy=1 graphs
        # are byte-identical to what earlier PRs priced
        return f"agg{pod}" if agg_redundancy == 1 else f"agg{pod}.{k}"

    for r in range(num_racks):
        tor = f"tor{r}"
        for k in range(agg_redundancy):
            _bilink(g, tor, agg_name(r // racks_per_pod, k),
                    agg_bw / oversub / agg_redundancy, lat)
    for p in range(num_pods):
        for k in range(agg_redundancy):
            _bilink(g, agg_name(p, k), core,
                    core_bw / oversub / agg_redundancy, lat)
    gid = 0
    hosts = []
    for h in range(num_hosts):
        tor = f"tor{h // hosts_per_rack}"
        nic = f"host{h}"
        _bilink(g, nic, tor, nic_bw, lat)
        members = []
        for _ in range(gpus_per_host):
            _bilink(g, gid, nic, pcie_bw, 5e-7)
            accel.append(gid)
            members.append(gid)
            gid += 1
        hosts.append(tuple(members))
    return Topology(g, name=f"fattree_h{num_hosts}",
                    accelerators=tuple(accel), hosts=tuple(hosts))


def dgx_cluster(num_hosts: int, gpus_per_host: int = 8,
                nvlink_bw: float = 150e9, nic_bw: float = 25e9,
                lat: float = 1e-6) -> Topology:
    """DGX-1-style hosts: intra-host NVLink ring+mesh (fast), inter-host
    NICs into a single switch (slow) — the "Intra-Inter" heterogeneity."""
    g = _new_graph()
    accel = []
    hosts = []
    sw = "switch"
    for h in range(num_hosts):
        base = h * gpus_per_host
        gpus = list(range(base, base + gpus_per_host))
        accel.extend(gpus)
        hosts.append(tuple(gpus))
        # ring
        for i in range(gpus_per_host):
            _bilink(g, gpus[i], gpus[(i + 1) % gpus_per_host], nvlink_bw, lat)
        # partial mesh (skip-2 links, as in DGX-1's hypercube-ish wiring)
        for i in range(gpus_per_host):
            _bilink(g, gpus[i], gpus[(i + 2) % gpus_per_host],
                    nvlink_bw / 2, lat)
        nic = f"host{h}"
        _bilink(g, nic, sw, nic_bw, 2e-6)
        for gpu in gpus:
            _bilink(g, gpu, nic, nic_bw, 1e-6)
    return Topology(g, name=f"dgx_h{num_hosts}", accelerators=tuple(accel),
                    hosts=tuple(hosts))


def tpu_pod(multi_pod: bool = False, ici_bw: float = 50e9,
            dcn_bw: float = 25e9) -> Topology:
    """The production mesh's physical fabric: 16x16 ICI torus per pod;
    two pods joined via DCN through per-pod border hosts."""
    if not multi_pod:
        return torus2d(16, 16, bw=ici_bw)
    g = _new_graph()
    pods = []
    for p in range(2):
        t = torus2d(16, 16, bw=ici_bw)
        off = p * 256
        for u, v, d in t.graph.edges(data=True):
            g.add_edge(u + off, v + off, **d)
        pods.append(off)
    # DCN: one border router per pod, 8 chips per pod homed on it
    _bilink(g, "dcn0", "dcn1", dcn_bw * 8, 5e-6)
    for p, off in enumerate(pods):
        for i in range(0, 256, 32):
            _bilink(g, off + i, f"dcn{p}", dcn_bw, 2e-6)
    return Topology(g, name="tpu_2pods", accelerators=tuple(range(512)))


TOPOLOGY_BUILDERS = {
    "ring": ring,
    "full_mesh": full_mesh,
    "torus2d": torus2d,
    "torus3d": torus3d,
    "fat_tree": fat_tree,
    "dgx": dgx_cluster,
    "tpu_pod": tpu_pod,
}
