"""Network layer (paper Sec. II-D / III-C): topologies + flow simulation."""
from repro.net.topology import Topology  # noqa: F401
from repro.net.simulate import simulate_flowset, simulate_schedule  # noqa: F401
