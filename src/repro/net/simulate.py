"""Flow-level network simulator.

Simulates a FlowSet (the CCL layer's traffic) on a Topology: flows of the
same step run concurrently and share links; a step's duration is the max
over links of (bytes on link / link bw) plus one latency hop (synchronous
bulk model — the same abstraction SCCL/TACCL cost their schedules with).
Supports in-network aggregation (ATP-style): flows of the same task that
meet at a programmable switch are merged (summed payload -> single flow),
and the symmetric multicast case — flows of the same task fanning out from
one source (the aggregated result returning to the workers) carry the
payload once on every shared path prefix.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.demand import Flow, FlowSet
from repro.net.topology import Topology


def _route_bytes(topo: Topology, flows: Iterable[Flow],
                 aggregate_at: Optional[Set] = None
                 ) -> Dict[Tuple, float]:
    """Per-link byte loads for one concurrent step."""
    link_bytes: Dict[Tuple, float] = defaultdict(float)
    if not aggregate_at:
        for f in flows:
            for link in topo.path_links(f.src, f.dst):
                link_bytes[link] += f.size_bytes
        return link_bytes

    # ATP-style: flows with identical (task, dst) merge at the first shared
    # aggregation-capable switch on their paths; downstream of the merge
    # point only one payload continues.  The symmetric case — one source
    # fanning the aggregated result back out (task, src) — is a multicast:
    # every link on the shared path tree carries the payload once.
    by_dst: Dict[Tuple, List[Flow]] = defaultdict(list)
    for f in flows:
        by_dst[(f.task_id, f.dst)].append(f)
    remaining: List[Flow] = []  # not merged; multicast candidates
    for (task, dst), fl in by_dst.items():
        if len(fl) == 1:
            remaining.append(fl[0])
            continue
        seen_downstream: Set[Tuple] = set()
        for f in fl:
            links = topo.path_links(f.src, f.dst)
            merged = False
            for u, v in links:
                if merged:
                    # downstream of merge point: count once per group
                    if (u, v) not in seen_downstream:
                        link_bytes[(u, v)] += f.size_bytes
                        seen_downstream.add((u, v))
                else:
                    link_bytes[(u, v)] += f.size_bytes
                if not merged and (u in aggregate_at or v in aggregate_at):
                    merged = True
        # (approximation: payload sizes equal within a group)
    by_src: Dict[Tuple, List[Flow]] = defaultdict(list)
    for f in remaining:
        by_src[(f.task_id, f.src)].append(f)
    for (task, src), fl in by_src.items():
        if len(fl) == 1:
            f = fl[0]
            for link in topo.path_links(f.src, f.dst):
                link_bytes[link] += f.size_bytes
            continue
        # multicast fan-out: one shared copy travels as far as the LAST
        # aggregation-capable switch on each receiver's path (which
        # replicates it); links beyond that carry per-receiver copies.
        # Shared links are counted once across the group.
        seen_shared: Set[Tuple] = set()
        for f in fl:
            links = topo.path_links(f.src, f.dst)
            last_cap = -1
            for i, (u, v) in enumerate(links):
                if v in aggregate_at:
                    last_cap = i
            for i, link in enumerate(links):
                if i <= last_cap:
                    if link not in seen_shared:
                        link_bytes[link] += f.size_bytes
                        seen_shared.add(link)
                else:
                    link_bytes[link] += f.size_bytes
    return link_bytes


def simulate_step(topo: Topology, flows: Sequence[Flow],
                  aggregate_at: Optional[Set] = None) -> float:
    if not flows:
        return 0.0
    link_bytes = _route_bytes(topo, flows, aggregate_at)
    t = 0.0
    for (u, v), nbytes in link_bytes.items():
        t = max(t, nbytes / topo.graph[u][v]["bw"])
    # one latency charge per step (max path latency)
    lat = max(sum(topo.graph[u][v]["lat"]
                  for u, v in topo.path_links(f.src, f.dst))
              for f in flows)
    return t + lat


def simulate_flowset(topo: Topology, fs: FlowSet,
                     aggregate_at: Optional[Set] = None) -> float:
    """Total completion time of one collective's schedule (steps serialize)."""
    by_step: Dict[int, List[Flow]] = defaultdict(list)
    for f in fs.flows:
        by_step[f.step].append(f)
    return sum(simulate_step(topo, by_step[s], aggregate_at)
               for s in sorted(by_step))


def simulate_schedule(topo: Topology, flowsets: Sequence[FlowSet],
                      concurrent: bool = False,
                      aggregate_at: Optional[Set] = None) -> float:
    """Multiple collectives: serialized, or naively concurrent (all steps of
    all tasks overlap — the resource-competition case of Fig. 5(b))."""
    if not concurrent:
        return sum(simulate_flowset(topo, fs, aggregate_at)
                   for fs in flowsets)
    # concurrent: align step k of every task
    max_steps = max((fs.num_steps for fs in flowsets), default=0)
    total = 0.0
    for s in range(max_steps):
        flows = [f for fs in flowsets for f in fs.flows if f.step == s]
        total += simulate_step(topo, flows, aggregate_at)
    return total


def link_utilization(topo: Topology, fs: FlowSet,
                     aggregate_at: Optional[Set] = None) -> Dict[Tuple, float]:
    """Aggregate bytes per link across the whole schedule (hot-spot map).

    ``aggregate_at``: switches that merge/multicast same-task flows
    (in-network aggregation) — pass for ATP-style schedules so the map
    reflects the reduced on-wire traffic."""
    out: Dict[Tuple, float] = defaultdict(float)
    if aggregate_at:
        by_step: Dict[int, List[Flow]] = defaultdict(list)
        for f in fs.flows:
            by_step[f.step].append(f)
        for step_flows in by_step.values():
            for link, nbytes in _route_bytes(topo, step_flows,
                                             aggregate_at).items():
                out[link] += nbytes
        return dict(out)
    for f in fs.flows:
        for link in topo.path_links(f.src, f.dst):
            out[link] += f.size_bytes
    return dict(out)


def link_rate_series(topo: Topology,
                     placed: Sequence[Tuple[FlowSet, float, float]],
                     aggregate_at: Optional[Set] = None
                     ) -> Dict[Tuple, List[Tuple[float, float]]]:
    """Per-link byte-rate step functions for a scheduled set of collectives.

    ``placed`` pairs each FlowSet with the wall-clock window it occupied
    (``(fs, start_s, end_s)``, e.g. a ``SimResult.timeline`` comm span);
    the schedule's per-link bytes (:func:`link_utilization`, so
    ``aggregate_at`` applies) are spread uniformly over the window.
    Returns ``link -> [(t, bytes_per_s), ...]`` breakpoints — a
    piecewise-constant utilization profile, sorted by time and closed
    with a final zero-rate sample — ready to plot or to emit as trace
    counter tracks (``repro.obs.trace``)."""
    deltas: Dict[Tuple, Dict[float, float]] = defaultdict(
        lambda: defaultdict(float))
    for fs, start, end in placed:
        dur = max(end - start, 1e-12)
        for link, nbytes in link_utilization(topo, fs, aggregate_at).items():
            rate = nbytes / dur
            deltas[link][start] += rate
            deltas[link][start + dur] -= rate
    series: Dict[Tuple, List[Tuple[float, float]]] = {}
    for link, dd in deltas.items():
        rate = 0.0
        points: List[Tuple[float, float]] = []
        for t in sorted(dd):
            rate += dd[t]
            points.append((t, max(rate, 0.0)))
        series[link] = points
    return series


def shared_link_load(per_job: Dict[str, Dict[Tuple, float]],
                     min_jobs: int = 2) -> Dict[Tuple, Dict[str, float]]:
    """Link-share query for the horizontal planner: given per-job link-byte
    maps (e.g. each job's ``CodesignReport`` hot-spot map), return the links
    carrying traffic from at least ``min_jobs`` distinct jobs, as
    link -> {job: bytes}."""
    users: Dict[Tuple, Dict[str, float]] = defaultdict(dict)
    for job, link_bytes in per_job.items():
        for link, nbytes in link_bytes.items():
            if nbytes > 0:
                users[link][job] = nbytes
    return {link: jobs for link, jobs in users.items()
            if len(jobs) >= min_jobs}
