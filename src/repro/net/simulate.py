"""Flow-level network simulator.

Simulates a FlowSet (the CCL layer's traffic) on a Topology: flows of the
same step run concurrently and share links; a step's duration is the max
over links of (bytes on link / link bw) plus one latency hop (synchronous
bulk model — the same abstraction SCCL/TACCL cost their schedules with).
Supports in-network aggregation (ATP-style): flows of the same task that
meet at a programmable switch are merged (summed payload -> single flow).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.demand import Flow, FlowSet
from repro.net.topology import Topology


def _route_bytes(topo: Topology, flows: Iterable[Flow],
                 aggregate_at: Optional[Set] = None
                 ) -> Dict[Tuple, float]:
    """Per-link byte loads for one concurrent step."""
    link_bytes: Dict[Tuple, float] = defaultdict(float)
    if not aggregate_at:
        for f in flows:
            for link in topo.path_links(f.src, f.dst):
                link_bytes[link] += f.size_bytes
        return link_bytes

    # ATP-style: flows with identical (task, dst) merge at the first shared
    # aggregation-capable switch on their paths; downstream of the merge
    # point only one payload continues.
    by_group: Dict[Tuple, List[Flow]] = defaultdict(list)
    for f in flows:
        by_group[(f.task_id, f.dst)].append(f)
    for (task, dst), fl in by_group.items():
        if len(fl) == 1:
            for link in topo.path_links(fl[0].src, fl[0].dst):
                link_bytes[link] += fl[0].size_bytes
            continue
        seen_downstream: Set[Tuple] = set()
        for f in fl:
            links = topo.path_links(f.src, f.dst)
            merged = False
            for u, v in links:
                if merged:
                    # downstream of merge point: count once per group
                    if (u, v) not in seen_downstream:
                        link_bytes[(u, v)] += f.size_bytes
                        seen_downstream.add((u, v))
                else:
                    link_bytes[(u, v)] += f.size_bytes
                if not merged and (u in aggregate_at or v in aggregate_at):
                    merged = True
        # (approximation: payload sizes equal within a group)
    return link_bytes


def simulate_step(topo: Topology, flows: Sequence[Flow],
                  aggregate_at: Optional[Set] = None) -> float:
    if not flows:
        return 0.0
    link_bytes = _route_bytes(topo, flows, aggregate_at)
    t = 0.0
    for (u, v), nbytes in link_bytes.items():
        t = max(t, nbytes / topo.graph[u][v]["bw"])
    # one latency charge per step (max path latency)
    lat = max(sum(topo.graph[u][v]["lat"]
                  for u, v in topo.path_links(f.src, f.dst))
              for f in flows)
    return t + lat


def simulate_flowset(topo: Topology, fs: FlowSet,
                     aggregate_at: Optional[Set] = None) -> float:
    """Total completion time of one collective's schedule (steps serialize)."""
    by_step: Dict[int, List[Flow]] = defaultdict(list)
    for f in fs.flows:
        by_step[f.step].append(f)
    return sum(simulate_step(topo, by_step[s], aggregate_at)
               for s in sorted(by_step))


def simulate_schedule(topo: Topology, flowsets: Sequence[FlowSet],
                      concurrent: bool = False,
                      aggregate_at: Optional[Set] = None) -> float:
    """Multiple collectives: serialized, or naively concurrent (all steps of
    all tasks overlap — the resource-competition case of Fig. 5(b))."""
    if not concurrent:
        return sum(simulate_flowset(topo, fs, aggregate_at)
                   for fs in flowsets)
    # concurrent: align step k of every task
    max_steps = max((fs.num_steps for fs in flowsets), default=0)
    total = 0.0
    for s in range(max_steps):
        flows = [f for fs in flowsets for f in fs.flows if f.step == s]
        total += simulate_step(topo, flows, aggregate_at)
    return total


def link_utilization(topo: Topology, fs: FlowSet) -> Dict[Tuple, float]:
    """Aggregate bytes per link across the whole schedule (hot-spot map)."""
    out: Dict[Tuple, float] = defaultdict(float)
    for f in fs.flows:
        for link in topo.path_links(f.src, f.dst):
            out[link] += f.size_bytes
    return dict(out)
