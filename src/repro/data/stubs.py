"""Modality frontends — STUBS by design (the one allowed carve-out).

[audio]: the mel-spectrogram + conv feature extractor is not implemented;
``audio_frames`` provides precomputed frame embeddings of the right shape.
[vlm]: the ViT/SigLIP vision encoder + projector is not implemented;
``vision_patches`` provides precomputed patch embeddings.

Both are seeded and deterministic so smoke tests / examples are stable.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import ModelConfig


def audio_frames(cfg: ModelConfig, batch: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
    return (rng.standard_normal(
        (batch, cfg.num_audio_frames, cfg.d_model)) * 0.02).astype(np.float32)


def vision_patches(cfg: ModelConfig, batch: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 11]))
    return (rng.standard_normal(
        (batch, cfg.num_vision_tokens, cfg.d_model)) * 0.02).astype(np.float32)
