"""Deterministic synthetic LM data pipeline.

Two generators:
  * ``bigram`` (default): a fixed seed-derived vocabulary permutation P;
    sequences follow t[i+1] = P[t[i]] from a random start.  Any architecture
    learns it quickly (next token is a function of the current token), so
    training examples/tests show loss dropping far below the uniform
    baseline within tens of steps.
  * ``recurrence``: second-order integer recurrence
    t[i+1] = (a*t[i] + b*t[i-1] + c) mod V with per-sequence coefficients —
    a harder probe task.

Generation is host-side numpy, seeded, and shardable: each sequence index
derives its own PRNG stream (seed, epoch, index), so multi-host data
loading produces identical global batches regardless of host count.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Literal, Optional

import numpy as np

from repro.core.types import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    pattern: Literal["bigram", "recurrence"] = "bigram"
    num_patterns: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0xB16]))
        self._perm = rng.permutation(self.vocab_size)

    def _params_for(self, rng: np.random.Generator):
        a = rng.integers(1, self.num_patterns + 1)
        b = rng.integers(0, self.num_patterns)
        c = rng.integers(0, self.vocab_size)
        return int(a), int(b), int(c)

    def sequence(self, epoch: int, index: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch, index]))
        v = self.vocab_size
        seq = np.empty(self.seq_len + 1, np.int64)
        if self.pattern == "bigram":
            seq[0] = rng.integers(0, v)
            for i in range(self.seq_len):
                seq[i + 1] = self._perm[seq[i]]
            return seq
        a, b, c = self._params_for(rng)
        seq[0] = rng.integers(0, v)
        seq[1] = rng.integers(0, v)
        for i in range(1, self.seq_len):
            seq[i + 1] = (a * seq[i] + b * seq[i - 1] + c) % v
        return seq

    def batch(self, epoch: int, start: int, batch_size: int
              ) -> Dict[str, np.ndarray]:
        seqs = np.stack([self.sequence(epoch, start + i)
                         for i in range(batch_size)])
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }


def make_batches(cfg: ModelConfig, batch_size: int, seq_len: int,
                 seed: int = 0, epoch: int = 0
                 ) -> Iterator[Dict[str, np.ndarray]]:
    ds = SyntheticLM(cfg.vocab_size, seq_len, seed=seed)
    start = 0
    while True:
        yield ds.batch(epoch, start, batch_size)
        start += batch_size
