from repro.data.pipeline import (  # noqa: F401
    SyntheticLM,
    make_batches,
)
from repro.data.stubs import audio_frames, vision_patches  # noqa: F401
