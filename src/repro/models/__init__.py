"""Model zoo: pure-JAX modules covering all 10 assigned architectures."""
from repro.models.transformer import (  # noqa: F401
    forward,
    init_cache,
    init_params,
    decode_step,
    encode,
)
