"""Shared building blocks: norms, linear init, embeddings, dense FFN, RoPE."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init, matching common LLM practice."""
    scale = 1.0 / math.sqrt(in_dim)
    flat_out = 1
    for s in out_shape:
        flat_out *= s
    w = jax.random.truncated_normal(
        key, -3.0, 3.0, (in_dim, flat_out), jnp.float32) * scale
    return w.reshape((in_dim, *out_shape)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def init_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU / GeGLU / GeLU)
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.ffn_act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, (d_ff,), dtype),
            "w_up": dense_init(ks[1], d, (d_ff,), dtype),
            "w_down": dense_init(ks[2], d_ff, (d,), dtype),
        }
    return {
        "w_up": dense_init(ks[0], d, (d_ff,), dtype),
        "w_down": dense_init(ks[1], d_ff, (d,), dtype),
    }


def ffn_apply(params: dict, x: jax.Array, act: str) -> jax.Array:
    if act in ("swiglu", "geglu"):
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        return (g * u) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]
