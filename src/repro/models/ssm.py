"""Mamba2 (state-space duality) block — chunked SSD form, TPU-adapted.

The GPU reference implementation relies on a fused selective-scan CUDA kernel
(warp shuffles, shared-memory staging).  That mechanism has no TPU analogue;
the TPU-idiomatic equivalent is the *chunked dual form* of SSD
[arXiv:2405.21060, Sec. 6]: intra-chunk work becomes dense (Q x Q) and
(Q x N) matmuls that map onto the MXU, and only the O(L/Q) inter-chunk state
recurrence is sequential (``lax.scan``).  ``repro.kernels.ssd_scan`` provides
the Pallas kernel for the intra-chunk part; this module is the pure-jnp
model-level implementation (also the kernel's oracle).

Projections are kept as separate tensors (z / x / B / C / dt and per-stream
convs) instead of one fused ``in_proj`` so the tensor-parallel planner can
shard the head-structured ones (z, x, dt, out) over the model axis while the
state projections (B, C — shared across heads, GQA-like) stay replicated.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.models.modules import dense_init, init_norm, rms_norm

DEFAULT_CHUNK = 256


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_num_heads
    k = cfg.ssm_conv_kernel
    ks = jax.random.split(key, 10)

    def conv_init(kk, ch):
        return (jax.random.normal(kk, (k, ch), jnp.float32) * 0.1).astype(dtype)

    return {
        "z_proj": dense_init(ks[0], d, (din,), dtype),
        "x_proj": dense_init(ks[1], d, (din,), dtype),
        "b_proj": dense_init(ks[2], d, (n,), dtype),
        "c_proj": dense_init(ks[3], d, (n,), dtype),
        "dt_proj": dense_init(ks[4], d, (h,), dtype),
        "conv_x": conv_init(ks[5], din),
        "conv_x_bias": jnp.zeros((din,), dtype),
        "conv_b": conv_init(ks[6], n),
        "conv_b_bias": jnp.zeros((n,), dtype),
        "conv_c": conv_init(ks[7], n),
        "conv_c_bias": jnp.zeros((n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_norm(din, dtype),
        "out_proj": dense_init(ks[8], din, (d,), dtype),
    }


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _segsum(dac: jax.Array) -> jax.Array:
    """dac: (..., Q) log-decay per step. Returns (..., Q, Q) with
    out[i, j] = sum_{j < m <= i} dac[m]  (-inf above the diagonal)."""
    q = dac.shape[-1]
    cs = jnp.cumsum(dac, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [i,j] = cs_i - cs_j
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, *, chunk: int = DEFAULT_CHUNK, h0=None):
    """Chunked SSD scan.

    x: (B, L, H, P) f32; dt: (B, L, H) f32 (post-softplus);
    a: (H,) negative decay rates; b, c: (B, L, N) (single group, broadcast
    over heads).  Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    xs = x.reshape(bsz, nc, q, h, p)
    dts = dt.reshape(bsz, nc, q, h)
    bs = b.reshape(bsz, nc, q, n)
    cs_ = c.reshape(bsz, nc, q, n)

    da = dts * a  # (B,nc,Q,H) log-decay contributions
    da_cum = jnp.cumsum(da, axis=2)  # inclusive within chunk
    da_total = da_cum[:, :, -1]  # (B,nc,H)

    # --- intra-chunk (dual / attention-like) term ---
    lmat = jnp.exp(_segsum(jnp.moveaxis(da, 2, 3)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cs_, bs)  # (B,nc,Q,Q)
    w = scores[:, :, None] * lmat  # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", w, dts, xs)

    # --- chunk -> state contributions ---
    decay_out = jnp.exp(da_total[:, :, None, :] - da_cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjh,bcjhp->bchpn",
                        bs, decay_out, dts, xs)  # (B,nc,H,P,N)

    # --- inter-chunk recurrence ---
    def step(hprev, inputs):
        st, dtot = inputs  # (B,H,P,N), (B,H)
        hnew = hprev * jnp.exp(dtot)[:, :, None, None] + st
        return hnew, hprev

    init = (jnp.zeros((bsz, h, p, n), jnp.float32)
            if h0 is None else h0.astype(jnp.float32))
    h_final, h_before = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(da_total, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)  # (B,nc,H,P,N) state at chunk start

    # --- inter-chunk output term ---
    decay_in = jnp.exp(da_cum)  # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", cs_, decay_in, h_before)

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, h_final


# ---------------------------------------------------------------------------
# Block-level forward / decode
# ---------------------------------------------------------------------------


def mamba_forward(p: dict, cfg: ModelConfig, xin: jax.Array, *,
                  chunk: int = DEFAULT_CHUNK) -> jax.Array:
    """xin: (B, L, d) -> (B, L, d)."""
    din, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    hd = cfg.ssm_head_dim
    z = xin @ p["z_proj"]
    x = _causal_conv(xin @ p["x_proj"], p["conv_x"], p["conv_x_bias"])
    b = _causal_conv(xin @ p["b_proj"], p["conv_b"], p["conv_b_bias"])
    c = _causal_conv(xin @ p["c_proj"], p["conv_c"], p["conv_c_bias"])
    dt = jax.nn.softplus(
        (xin @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = x.astype(jnp.float32).reshape(*x.shape[:2], h, hd)
    y, _ = ssd_chunked(xh, dt, a, b.astype(jnp.float32),
                       c.astype(jnp.float32), chunk=chunk)
    y = y + xh * p["D"][:, None]
    y = y.reshape(*xin.shape[:2], din).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["scale"], cfg.norm_eps)
    return y @ p["out_proj"]


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    din, n = cfg.ssm_d_inner, cfg.ssm_state
    km1 = cfg.ssm_conv_kernel - 1
    return {
        "conv_x": jnp.zeros((batch, km1, din), dtype),
        "conv_b": jnp.zeros((batch, km1, n), dtype),
        "conv_c": jnp.zeros((batch, km1, n), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_num_heads, cfg.ssm_head_dim, n),
                         jnp.float32),
    }


def _conv_step(hist, new, w, b):
    """hist: (B, K-1, C) past inputs; new: (B, C). Returns (out, new_hist)."""
    full = jnp.concatenate([hist, new[:, None, :].astype(hist.dtype)], axis=1)
    out = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                     w.astype(jnp.float32)) + b
    return jax.nn.silu(out), full[:, 1:]


def mamba_decode(p: dict, cfg: ModelConfig, xin: jax.Array, cache: dict
                 ) -> Tuple[jax.Array, dict]:
    """Single-token recurrent step. xin: (B, 1, d)."""
    din, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    hd = cfg.ssm_head_dim
    x0 = xin[:, 0]
    z = x0 @ p["z_proj"]
    x, conv_x = _conv_step(cache["conv_x"], x0 @ p["x_proj"], p["conv_x"],
                           p["conv_x_bias"])
    b, conv_b = _conv_step(cache["conv_b"], x0 @ p["b_proj"], p["conv_b"],
                           p["conv_b_bias"])
    c, conv_c = _conv_step(cache["conv_c"], x0 @ p["c_proj"], p["conv_c"],
                           p["conv_c_bias"])
    dt1 = jax.nn.softplus(
        (x0 @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])

    xh = x.astype(jnp.float32).reshape(-1, h, hd)
    decay = jnp.exp(dt1 * a)  # (B,H)
    hnew = (cache["ssm"] * decay[..., None, None]
            + jnp.einsum("bh,bhp,bn->bhpn", dt1, xh,
                         b.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", hnew, c.astype(jnp.float32)) \
        + xh * p["D"][:, None]
    y = y.reshape(-1, din).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["scale"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c,
                 "ssm": hnew}
    return out, new_cache
