"""Full-model assembly: embeddings -> scanned layer groups -> LM head.

Layer stacks are built as ``lax.scan`` over parameter-stacked *layer groups*
(``ModelConfig.layer_groups``): HLO size stays O(period), so 100-layer
configs lower and compile quickly in the multi-pod dry-run.  Heterogeneous
patterns (Jamba's 1 attn : 7 mamba, Llama-Vision's 4 self : 1 cross) become
a short unrolled period inside the scan body.

Every function takes an optional ``ctx`` (repro.parallel.planner.ParallelCtx)
that carries the mesh + axis names for the expert-parallel shard_map path and
activation sharding constraints; with ``ctx=None`` everything runs on a
single device (smoke tests, examples).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.modules import (dense_init, embed_init, ffn_apply,
                                  init_ffn, init_norm, rms_norm)


def _constrain(x, ctx, spec_name: str):
    if ctx is not None and getattr(ctx, spec_name, None) is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(ctx.mesh, getattr(ctx, spec_name)))
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            p["mixer"] = attn.init_mla(ks[0], cfg, dtype)
        else:
            p["mixer"] = attn.init_gqa(ks[0], cfg, dtype)
    elif spec.mixer == "cross_attn":
        p["mixer"] = attn.init_gqa(ks[0], cfg, dtype, cross=True)
    else:
        p["mixer"] = ssm.init_mamba(ks[0], cfg, dtype)
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg.d_model, dtype)
        if spec.ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = init_ffn(ks[1], cfg, cfg.d_ff, dtype)
    return p


def _init_group(key, cfg: ModelConfig, period, repeats: int, dtype) -> dict:
    """Params for one layer group: each leaf stacked over ``repeats``."""
    def init_one(k):
        ks = jax.random.split(k, len(period))
        return {f"pos{i}": _init_layer(ks[i], cfg, spec, dtype)
                for i, spec in enumerate(period)}
    return jax.vmap(init_one)(jax.random.split(key, repeats))


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    params = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model,
                                       (cfg.padded_vocab,), dtype)
    for gi, (period, repeats) in enumerate(cfg.layer_groups()):
        params[f"group{gi}"] = _init_group(ks[2 + gi], cfg, period, repeats,
                                           dtype)
    if cfg.is_encoder_decoder:
        enc_spec = LayerSpec(mixer="attn", ffn="dense")
        params["encoder"] = {
            "group0": _init_group(ks[6], cfg, (enc_spec,), cfg.encoder_layers,
                                  dtype),
            "final_norm": init_norm(cfg.d_model, dtype),
        }
        # decoder cross-attention: one per decoder layer (stacked)
        params["cross"] = jax.vmap(
            lambda k: attn.init_gqa(k, cfg, dtype, cross=True))(
            jax.random.split(ks[7], cfg.num_layers))
    return params


# ---------------------------------------------------------------------------
# Layer application (train / prefill)
# ---------------------------------------------------------------------------


def _apply_layer(lp: dict, spec: LayerSpec, cfg: ModelConfig, x, positions,
                 context, ctx, window, cross_lp=None):
    h = rms_norm(x, lp["norm1"]["scale"], cfg.norm_eps)
    unroll = _flag(ctx, "unroll_layers")  # dry-run cost mode: see attention
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            h = attn.mla_forward(lp["mixer"], cfg, h, positions,
                                 window=window, unroll=unroll,
                                 causal_skip=_flag(ctx, "causal_skip"))
        else:
            h = attn.gqa_forward(lp["mixer"], cfg, h, positions,
                                 window=window, unroll=unroll,
                                 causal_skip=_flag(ctx, "causal_skip"),
                                 use_pallas=_flag(ctx, "use_pallas"))
    elif spec.mixer == "cross_attn":
        h = attn.cross_attention_forward(lp["mixer"], cfg, h, context,
                                         unroll=unroll)
    else:
        h = ssm.mamba_forward(lp["mixer"], cfg, h)
    x = x + h
    x = _constrain(x, ctx, "act_spec")
    aux = jnp.zeros((), jnp.float32)

    # encoder-decoder: interleave a cross-attention block after self-attn
    if cross_lp is not None:
        h = rms_norm(x, lp["norm1"]["scale"], cfg.norm_eps)
        x = x + attn.cross_attention_forward(cross_lp, cfg, h, context)
        x = _constrain(x, ctx, "act_spec")

    if spec.ffn != "none":
        h2 = rms_norm(x, lp["norm2"]["scale"], cfg.norm_eps)
        if spec.ffn == "moe":
            y, aux = moe_mod.moe_apply(lp["ffn"], cfg, h2, ctx=ctx)
        else:
            y = ffn_apply(lp["ffn"], h2, cfg.ffn_act)
        x = x + y
        x = _constrain(x, ctx, "act_spec")
    return x, aux


def _flag(ctx, name: str) -> bool:
    return bool(getattr(ctx, name, False)) if ctx is not None else False


def _run_groups(params, cfg: ModelConfig, x, positions, context, ctx,
                window, cross_stack=None):
    """Apply all layer groups via scan; returns (x, aux_total)."""
    aux_total = jnp.zeros((), jnp.float32)
    layer_offset = 0
    for gi, (period, repeats) in enumerate(cfg.layer_groups()):
        gp = params[f"group{gi}"]

        def body(carry, xs, _period=period, _off=layer_offset):
            h, aux = carry
            lp_stack = xs["lp"]
            for i, spec in enumerate(_period):
                cross_lp = None
                if xs.get("cross") is not None and spec.mixer == "attn" \
                        and cfg.is_encoder_decoder:
                    cross_lp = jax.tree.map(lambda a, _i=i: a[_i],
                                            xs["cross"])
                h, a = _apply_layer(lp_stack[f"pos{i}"], spec, cfg, h,
                                    positions, context, ctx, window,
                                    cross_lp=cross_lp)
                aux = aux + a
            return (h, aux), None

        if _flag(ctx, "remat"):
            body = jax.checkpoint(body)

        xs = {"lp": gp, "cross": None}
        if cross_stack is not None:
            per = len(period)
            sl = jax.tree.map(
                lambda a: a[layer_offset:layer_offset + repeats * per]
                .reshape(repeats, per, *a.shape[1:]), cross_stack)
            xs["cross"] = sl
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), xs,
            unroll=repeats if _flag(ctx, "unroll_layers") else 1)
        layer_offset += repeats * len(period)
    return x, aux_total


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def _vocab_bias(cfg: ModelConfig, dtype):
    v = jnp.arange(cfg.padded_vocab)
    return jnp.where(v < cfg.vocab_size, 0.0, attn.NEG_INF).astype(dtype)


def encode(cfg: ModelConfig, params: dict, frames: jax.Array, ctx=None
           ) -> jax.Array:
    """Encoder stack over stub frame embeddings (B, T, d) -> context."""
    enc = params["encoder"]
    positions = jnp.arange(frames.shape[1])
    gp = enc["group0"]
    spec = LayerSpec(mixer="attn", ffn="dense")

    def body(carry, lp):
        h = carry
        hh = rms_norm(h, lp["pos0"]["norm1"]["scale"], cfg.norm_eps)
        hh = attn.gqa_forward(lp["pos0"]["mixer"], cfg, hh, positions)
        h = h + hh
        h2 = rms_norm(h, lp["pos0"]["norm2"]["scale"], cfg.norm_eps)
        h = h + ffn_apply(lp["pos0"]["ffn"], h2, cfg.ffn_act)
        h = _constrain(h, ctx, "act_spec")
        return h, None

    if _flag(ctx, "remat"):
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(
        body, frames, gp,
        unroll=cfg.encoder_layers if _flag(ctx, "unroll_layers") else 1)
    return rms_norm(x, enc["final_norm"]["scale"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            context: Optional[jax.Array] = None, ctx=None,
            window: Optional[int] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) int32. Returns (logits (B,S,V_pad), aux_loss).

    ``context``: encoder output (audio), vision patch embeddings (vlm), or
    None.  ``window``: overrides cfg.sliding_window (long-context variant).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _constrain(x, ctx, "act_spec")
    positions = jnp.arange(tokens.shape[1])
    if cfg.is_encoder_decoder and context is None:
        raise ValueError("encoder-decoder model requires context")
    win = window if window is not None else cfg.sliding_window
    cross_stack = params.get("cross")
    x, aux = _run_groups(params, cfg, x, positions, context, ctx, win,
                         cross_stack=cross_stack)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    logits = logits + _vocab_bias(cfg, logits.dtype)
    logits = _constrain(logits, ctx, "logit_spec")
    return logits, aux


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def _init_layer_cache(cfg: ModelConfig, spec: LayerSpec, lp: dict,
                      batch: int, max_len: int, dtype, context, window):
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            return attn.init_mla_cache(cfg, batch, max_len, dtype)
        return attn.init_kv_cache(cfg, batch, max_len, dtype, window=window)
    if spec.mixer == "cross_attn":
        return attn.init_cross_cache(lp["mixer"], cfg, context, dtype)
    return ssm.init_mamba_cache(cfg, batch, dtype)


def init_cache(cfg: ModelConfig, params: dict, batch: int, max_len: int,
               dtype=jnp.float32, *, context=None,
               window: Optional[int] = None) -> dict:
    """Build the decode cache pytree (stacked per layer group)."""
    win = window if window is not None else cfg.sliding_window
    cache = {}
    for gi, (period, repeats) in enumerate(cfg.layer_groups()):
        gp = params[f"group{gi}"]

        def one(lp_r):
            return {f"pos{i}": _init_layer_cache(
                cfg, spec, lp_r[f"pos{i}"], batch, max_len, dtype, context,
                win) for i, spec in enumerate(period)}

        cache[f"group{gi}"] = jax.vmap(one)(gp)
    if cfg.is_encoder_decoder:
        cache["cross"] = jax.vmap(
            lambda lp: attn.init_cross_cache(lp, cfg, context, dtype))(
            params["cross"])
    return cache


def _decode_layer(lp: dict, spec: LayerSpec, cfg: ModelConfig, x, lcache,
                  pos, ctx, window, cross_lp=None, cross_cache=None):
    h = rms_norm(x, lp["norm1"]["scale"], cfg.norm_eps)
    new_cache = lcache
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            h, new_cache = attn.mla_decode(lp["mixer"], cfg, h, lcache, pos)
        else:
            h, new_cache = attn.gqa_decode(lp["mixer"], cfg, h, lcache, pos,
                                           window=window)
    elif spec.mixer == "cross_attn":
        h = attn.cross_attention_decode(lp["mixer"], cfg, h, lcache)
    else:
        h, new_cache = ssm.mamba_decode(lp["mixer"], cfg, h, lcache)
    x = x + h
    if cross_lp is not None:
        h = rms_norm(x, lp["norm1"]["scale"], cfg.norm_eps)
        x = x + attn.cross_attention_decode(cross_lp, cfg, h, cross_cache)
    if spec.ffn != "none":
        h2 = rms_norm(x, lp["norm2"]["scale"], cfg.norm_eps)
        if spec.ffn == "moe":
            y, _ = moe_mod.moe_apply(lp["ffn"], cfg, h2, ctx=ctx, decode=True)
        else:
            y = ffn_apply(lp["ffn"], h2, cfg.ffn_act)
        x = x + y
    x = _constrain(x, ctx, "act_spec")
    return x, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos, *, ctx=None,
                window: Optional[int] = None) -> Tuple[jax.Array, dict]:
    """tokens: (B, 1) int32; pos: scalar int32 (position of the new token).
    Returns (logits (B,1,V_pad), new_cache)."""
    win = window if window is not None else cfg.sliding_window
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _constrain(x, ctx, "act_spec")
    new_cache = {}
    layer_offset = 0
    for gi, (period, repeats) in enumerate(cfg.layer_groups()):
        gp = params[f"group{gi}"]
        gc = cache[f"group{gi}"]
        cross_all = cache.get("cross")

        def body(carry, xs, _period=period, _off=layer_offset):
            h = carry
            lp_stack, c_stack, cross_lp_s, cross_c_s = xs
            new_c = {}
            for i, spec in enumerate(_period):
                clp = cc = None
                if cross_lp_s is not None and spec.mixer == "attn" \
                        and cfg.is_encoder_decoder:
                    clp = jax.tree.map(lambda a, _i=i: a[_i], cross_lp_s)
                    cc = jax.tree.map(lambda a, _i=i: a[_i], cross_c_s)
                h, nc = _decode_layer(lp_stack[f"pos{i}"], spec, cfg, h,
                                      c_stack[f"pos{i}"], pos, ctx, win,
                                      cross_lp=clp, cross_cache=cc)
                new_c[f"pos{i}"] = nc
            return h, new_c

        cross_lp_stack = cross_c_stack = None
        if cfg.is_encoder_decoder:
            per = len(period)
            cross_lp_stack = jax.tree.map(
                lambda a: a[layer_offset:layer_offset + repeats * per]
                .reshape(repeats, per, *a.shape[1:]), params["cross"])
            cross_c_stack = jax.tree.map(
                lambda a: a[layer_offset:layer_offset + repeats * per]
                .reshape(repeats, per, *a.shape[1:]), cross_all)
        x, nc = jax.lax.scan(
            body, x, (gp, gc, cross_lp_stack, cross_c_stack),
            unroll=repeats if _flag(ctx, "unroll_layers") else 1)
        new_cache[f"group{gi}"] = nc
        layer_offset += repeats * len(period)
    if cfg.is_encoder_decoder:
        new_cache["cross"] = cache["cross"]
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    logits = logits + _vocab_bias(cfg, logits.dtype)
    return logits, new_cache
