"""Mixture-of-Experts FFN with three execution paths.

The survey (Sec. II-B, III-A) singles out MoE parallelism as the emerging
strategy whose All-to-All dispatch traffic dominates: Lina prioritizes
All-to-All over All-Reduce, Janus flips it into a data-centric "move the
experts" scheme.  This module implements the token-centric (expert-parallel)
scheme as a first-class ``shard_map`` program whose collectives are visible
to the CCL/scheduler layers:

  * ``moe_dense``     — O(T*E) loop oracle, used by smoke tests + kernels' ref
  * ``moe_ep_train``  — sequence-sharded capacity dispatch, All-to-All over
                        the expert-parallel axis, batched expert matmul,
                        All-to-All back, weighted combine (train / prefill)
  * ``moe_ep_decode`` — token-replicated local-expert compute with an
                        All-Reduce combine (tiny T; avoids the A2A latency)

Routing (softmax -> top-k -> renormalize) and the Switch-style load-balance
auxiliary loss are computed in the surrounding pjit region so XLA shards
them; the shard_map bodies receive ids/weights as data.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.models.modules import dense_init, ffn_apply, init_ffn


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (e,), jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, (ff,), dtype))(
            jax.random.split(ks[1], e)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, (ff,), dtype))(
            jax.random.split(ks[2], e)),
        "w_down": jax.vmap(lambda k: dense_init(k, ff, (d,), dtype))(
            jax.random.split(ks[3], e)),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_ffn(ks[4], cfg, ff * cfg.num_shared_experts, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing (runs in pjit)
# ---------------------------------------------------------------------------


def route(p: dict, cfg: ModelConfig, x: jax.Array):
    """x: (..., d). Returns (ids (...,k), weights (...,k), aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)
    # Switch-transformer load-balance loss: E * sum_e f_e * P_e
    e = cfg.num_experts
    f = jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=tuple(
        range(ids.ndim - 1)))  # (k, E) fraction per rank — sum over k below
    f = f.sum(axis=0) if f.ndim == 2 else f
    pbar = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = e * jnp.sum(f * pbar) / cfg.top_k
    return ids, weights.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Dense oracle path
# ---------------------------------------------------------------------------


def _expert_ffn(p: dict, cfg: ModelConfig, x_e: jax.Array) -> jax.Array:
    """Batched-over-experts FFN. x_e: (E, T, d) -> (E, T, d)."""
    g = jnp.einsum("etd,edf->etf", x_e, p["w_gate"])
    u = jnp.einsum("etd,edf->etf", x_e, p["w_up"])
    act = jax.nn.silu if cfg.ffn_act == "swiglu" else jax.nn.gelu
    return jnp.einsum("etf,efd->etd", act(g) * u, p["w_down"])


def moe_dense(p: dict, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Computes every expert for every token, masks by routing weight.
    Exact (no capacity drops); used as the correctness oracle."""
    ids, weights, aux = route(p, cfg, x)
    shp = x.shape
    xt = x.reshape(-1, shp[-1])
    e = cfg.num_experts
    y_all = _expert_ffn(p, cfg, jnp.broadcast_to(xt, (e, *xt.shape)))
    w_full = jnp.zeros((xt.shape[0], e), x.dtype)
    w_full = w_full.at[jnp.arange(xt.shape[0])[:, None],
                       ids.reshape(-1, cfg.top_k)].set(
        weights.reshape(-1, cfg.top_k))
    y = jnp.einsum("te,etd->td", w_full, y_all)
    y = y + _shared(p, cfg, xt)
    return y.reshape(shp), aux


def _shared(p: dict, cfg: ModelConfig, xt: jax.Array) -> jax.Array:
    if "shared" in p:
        return ffn_apply(p["shared"], xt, cfg.ffn_act)
    return jnp.zeros_like(xt)


# ---------------------------------------------------------------------------
# Capacity-based dispatch helpers
# ---------------------------------------------------------------------------


def _slots(ids_flat: jax.Array, num_experts: int) -> jax.Array:
    """Position of each (token, choice) within its expert's capacity queue.
    ids_flat: (M,) expert ids. Returns (M,) slot indices (0-based)."""
    one_hot = jax.nn.one_hot(ids_flat, num_experts, dtype=jnp.int32)
    # exclusive cumsum: how many earlier dispatches target the same expert
    cum = jnp.cumsum(one_hot, axis=0) - one_hot
    return jnp.take_along_axis(cum, ids_flat[:, None], axis=1)[:, 0]


def capacity_for(tokens: int, top_k: int, num_experts: int,
                 factor: float) -> int:
    c = math.ceil(tokens * top_k / num_experts * factor)
    return max(4, ((c + 3) // 4) * 4)


# ---------------------------------------------------------------------------
# Expert-parallel train/prefill path (shard_map body)
# ---------------------------------------------------------------------------


def _ep_train_body(xt, ids, weights, w_gate, w_up, w_down, *,
                   cfg: ModelConfig, axis: str, capacity: int):
    """Per-shard body. xt: (T_local, d); ids/weights: (T_local, k);
    w_*: local expert slices (E_local, ...)."""
    tp = jax.lax.psum(1, axis)
    e_local = w_gate.shape[0]
    t, d = xt.shape
    k = cfg.top_k
    m = t * k

    ids_f = ids.reshape(m)
    w_f = weights.reshape(m)
    dest = ids_f // e_local          # destination shard on the EP axis
    le = ids_f % e_local             # local expert id on that shard
    # slot within (dest, le) capacity queue; same expert id => same queue
    slot = _slots(ids_f, cfg.num_experts)
    ok = slot < capacity
    slot_c = jnp.where(ok, slot, capacity)  # OOB rows dropped by scatter

    x_rep = jnp.repeat(xt, k, axis=0)  # (M, d) token per dispatch
    buf = jnp.zeros((tp, e_local, capacity + 1, d), xt.dtype)
    buf = buf.at[dest, le, slot_c].set(x_rep, mode="drop")
    buf = buf[:, :, :capacity]

    # ---- All-to-All #1: tokens -> expert shards ----
    recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv: (tp, E_local, C, d), dim0 = source shard
    h = jnp.swapaxes(recv, 0, 1).reshape(e_local, tp * capacity, d)
    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    act = jax.nn.silu if cfg.ffn_act == "swiglu" else jax.nn.gelu
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, w_down)
    y = jnp.swapaxes(y.reshape(e_local, tp, capacity, d), 0, 1)

    # ---- All-to-All #2: results -> source shards ----
    back = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # back: (tp, E_local, C, d), dim0 = dest shard again (round trip)
    pad = jnp.zeros((tp, e_local, 1, d), back.dtype)
    back = jnp.concatenate([back, pad], axis=2)
    y_tok = back[dest, le, slot_c] * (w_f * ok)[:, None]
    return y_tok.reshape(t, k, d).sum(axis=1)


def moe_ep_train(p: dict, cfg: ModelConfig, x: jax.Array, mesh,
                 ep_axis: str, data_axes, capacity_factor: float = 1.25
                 ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) global. Sequence-sharded over ``ep_axis``; experts live
    on ``ep_axis`` shards; two All-to-Alls per MoE layer (dispatch+combine)."""
    ids, weights, aux = route(p, cfg, x)
    b, s, d = x.shape
    tp = 1
    for a in (ep_axis,):
        tp *= mesh.shape[a]
    t_local = (b // _axis_prod(mesh, data_axes)) * (s // tp)
    capacity = capacity_for(t_local, cfg.top_k, cfg.num_experts,
                            capacity_factor)

    body = partial(_ep_train_body, cfg=cfg, axis=ep_axis, capacity=capacity)

    def shard_body(x_l, ids_l, w_l, wg, wu, wd):
        t = x_l.shape[0] * x_l.shape[1]
        y = body(x_l.reshape(t, d), ids_l.reshape(t, cfg.top_k),
                 w_l.reshape(t, cfg.top_k), wg, wu, wd)
        return y.reshape(x_l.shape)

    bspec = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    xs = P(bspec, ep_axis, None)
    y = jax.shard_map(
        shard_body, mesh=mesh,
        in_specs=(xs, xs, xs,
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=xs,
    )(x, ids, weights, p["w_gate"], p["w_up"], p["w_down"])
    y = y + _shared(p, cfg, x.reshape(-1, d)).reshape(x.shape)
    return y, aux


def _axis_prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# Expert-parallel decode path (shard_map body)
# ---------------------------------------------------------------------------


def _ep_decode_body(xt, ids, weights, w_gate, w_up, w_down, *,
                    cfg: ModelConfig, axis: str, capacity: int):
    """Tokens replicated over the EP axis; each shard computes only its local
    experts for the tokens routed to them, then All-Reduce combines."""
    e_local = w_gate.shape[0]
    rank = jax.lax.axis_index(axis)
    t, d = xt.shape
    k = cfg.top_k
    m = t * k
    ids_f = ids.reshape(m)
    w_f = weights.reshape(m)
    le = ids_f - rank * e_local
    mine = (le >= 0) & (le < e_local)
    slot = _slots(ids_f, cfg.num_experts)
    ok = mine & (slot < capacity)
    le_c = jnp.where(ok, le, 0)
    slot_c = jnp.where(ok, slot, capacity)

    x_rep = jnp.repeat(xt, k, axis=0)
    buf = jnp.zeros((e_local, capacity + 1, d), xt.dtype)
    buf = buf.at[le_c, slot_c].set(x_rep, mode="drop")
    h = buf[:, :capacity]
    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    act = jax.nn.silu if cfg.ffn_act == "swiglu" else jax.nn.gelu
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, w_down)
    y = jnp.concatenate([y, jnp.zeros((e_local, 1, d), y.dtype)], axis=1)
    y_tok = y[le_c, slot_c] * (w_f * ok)[:, None]
    out = y_tok.reshape(t, k, d).sum(axis=1)
    return jax.lax.psum(out, axis)


def moe_ep_decode(p: dict, cfg: ModelConfig, x: jax.Array, mesh,
                  ep_axis: str, data_axes, capacity_factor: float = 4.0
                  ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, 1, d). Combine is an All-Reduce over the EP axis — the decode
    MoE traffic pattern differs from train (A2A), which the CommDemand layer
    reports per shape."""
    ids, weights, aux = route(p, cfg, x)
    b, s, d = x.shape
    dp = _axis_prod(mesh, data_axes)
    batch_sharded = b % dp == 0
    t_local = (b // dp if batch_sharded else b) * s
    capacity = capacity_for(t_local, cfg.top_k, cfg.num_experts,
                            capacity_factor)
    body = partial(_ep_decode_body, cfg=cfg, axis=ep_axis, capacity=capacity)

    def shard_body(x_l, ids_l, w_l, wg, wu, wd):
        t = x_l.shape[0] * x_l.shape[1]
        y = body(x_l.reshape(t, d), ids_l.reshape(t, cfg.top_k),
                 w_l.reshape(t, cfg.top_k), wg, wu, wd)
        return y.reshape(x_l.shape)

    dspec = data_axes if len(data_axes) > 1 else data_axes[0]
    # long-context decode has global_batch=1: replicate tokens over data
    xs = P(dspec, None, None) if batch_sharded else P(None, None, None)
    y = jax.shard_map(
        shard_body, mesh=mesh,
        in_specs=(xs, xs, xs,
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=xs,
    )(x, ids, weights, p["w_gate"], p["w_up"], p["w_down"])
    y = y + _shared(p, cfg, x.reshape(-1, d)).reshape(x.shape)
    return y, aux


# ---------------------------------------------------------------------------
# Weight-stationary decode path (beyond-paper §Perf optimization)
# ---------------------------------------------------------------------------
#
# With FSDP'd experts, the standard decode path all-gathers every expert's
# weights over the data axes each step — gigabytes moved to compute a
# one-token output.  Weight-stationary EP inverts it: weights stay sharded
# over BOTH axes (experts over model, ffn dim over data); the tiny token
# activations are replicated, each shard computes an ffn-slice partial for
# its local experts, and two cheap activation psums (data: ffn partials,
# model: expert combine) replace the weight gathers.


def _ep_decode_ws_body(xt, ids, weights, w_gate, w_up, w_down, *,
                       cfg: ModelConfig, model_axis: str, data_axes,
                       capacity: int):
    e_local = w_gate.shape[0]
    rank = jax.lax.axis_index(model_axis)
    t, d = xt.shape
    k = cfg.top_k
    m = t * k
    ids_f = ids.reshape(m)
    w_f = weights.reshape(m)
    le = ids_f - rank * e_local
    mine = (le >= 0) & (le < e_local)
    slot = _slots(ids_f, cfg.num_experts)
    ok = mine & (slot < capacity)
    le_c = jnp.where(ok, le, 0)
    slot_c = jnp.where(ok, slot, capacity)

    x_rep = jnp.repeat(xt, k, axis=0)
    buf = jnp.zeros((e_local, capacity + 1, d), xt.dtype)
    buf = buf.at[le_c, slot_c].set(x_rep, mode="drop")
    h = buf[:, :capacity]
    # ffn-dim-sharded expert compute: partial over the data axes
    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    act = jax.nn.silu if cfg.ffn_act == "swiglu" else jax.nn.gelu
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, w_down)
    y = jnp.concatenate([y, jnp.zeros((e_local, 1, d), y.dtype)], axis=1)
    y_tok = y[le_c, slot_c] * (w_f * ok)[:, None]
    out = y_tok.reshape(t, k, d).sum(axis=1)
    out = jax.lax.psum(out, model_axis)      # combine experts
    for a in data_axes:
        out = jax.lax.psum(out, a)           # combine ffn partials
    return out


def moe_ep_decode_ws(p: dict, cfg: ModelConfig, x: jax.Array, mesh,
                     ep_axis: str, data_axes,
                     capacity_factor: float = 4.0
                     ) -> Tuple[jax.Array, jax.Array]:
    ids, weights, aux = route(p, cfg, x)
    b, s, d = x.shape
    t_local = b * s  # tokens replicated over every axis in the body
    capacity = capacity_for(t_local, cfg.top_k, cfg.num_experts,
                            capacity_factor)
    body = partial(_ep_decode_ws_body, cfg=cfg, model_axis=ep_axis,
                   data_axes=tuple(data_axes), capacity=capacity)

    def shard_body(x_l, ids_l, w_l, wg, wu, wd):
        t = x_l.shape[0] * x_l.shape[1]
        y = body(x_l.reshape(t, d), ids_l.reshape(t, cfg.top_k),
                 w_l.reshape(t, cfg.top_k), wg, wu, wd)
        return y.reshape(x_l.shape)

    bspec = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    rep = P(None, None, None)
    y = jax.shard_map(
        shard_body, mesh=mesh,
        in_specs=(rep, rep, rep,
                  P(ep_axis, None, bspec), P(ep_axis, None, bspec),
                  P(ep_axis, bspec, None)),
        out_specs=rep,
    )(x, ids, weights, p["w_gate"], p["w_up"], p["w_down"])
    y = y + _shared(p, cfg, x.reshape(-1, d)).reshape(x.shape)
    return y, aux


# ---------------------------------------------------------------------------
# Unified entry point
# ---------------------------------------------------------------------------


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array, *, ctx=None,
              decode: bool = False) -> Tuple[jax.Array, jax.Array]:
    """ctx: ParallelCtx (repro.parallel.planner) or None for single-device."""
    if ctx is None or ctx.mesh is None or not ctx.use_ep:
        return moe_dense(p, cfg, x)
    if decode:
        if getattr(ctx, "ep_weight_stationary", False):
            return moe_ep_decode_ws(
                p, cfg, x, ctx.mesh, ctx.ep_axis, ctx.data_axes,
                capacity_factor=ctx.decode_capacity_factor)
        return moe_ep_decode(p, cfg, x, ctx.mesh, ctx.ep_axis, ctx.data_axes,
                             capacity_factor=ctx.decode_capacity_factor)
    return moe_ep_train(p, cfg, x, ctx.mesh, ctx.ep_axis, ctx.data_axes,
                        capacity_factor=ctx.capacity_factor)
