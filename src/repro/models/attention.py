"""Attention flavours: GQA (+RoPE, QKV-bias, sliding-window), MLA, cross-attn.

Two compute paths:
  * plain einsum attention for short sequences (smoke tests, examples);
  * flash-style chunked attention in pure jnp (two nested ``lax.scan``) for
    long sequences — O(S * chunk) live memory, small HLO, used by the dry-run.
    The Pallas kernel in ``repro.kernels.flash_attention`` implements the same
    contract for the TPU production path.

Decode attends one new token against a KV cache; sliding-window caches are
ring buffers of ``window`` slots.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.models.modules import apply_rope, dense_init, init_norm, rms_norm

_PLAIN_ATTN_MAX_SEQ = 2048
_Q_CHUNK = 1024
_KV_CHUNK = 1024

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (cfg.num_heads, hd), dtype),
        "wk": dense_init(ks[1], d, (cfg.num_kv_heads, hd), dtype),
        "wv": dense_init(ks[2], d, (cfg.num_kv_heads, hd), dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, (d,), dtype).reshape(
            cfg.num_heads, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
    if cross:
        # query-norm on the hidden stream, gating as in Llama-3.2-Vision
        p["gate_attn"] = jnp.zeros((), dtype)
    return p


def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim          # qk nope dim
    vhd = cfg.resolved_v_head_dim
    rhd = cfg.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    p = {}
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], d, (cfg.q_lora_rank,), dtype)
        p["norm_q"] = init_norm(cfg.q_lora_rank, dtype)
        q_in = cfg.q_lora_rank
    else:
        q_in = d
    p["w_uq"] = dense_init(ks[1], q_in, (cfg.num_heads, hd + rhd), dtype)
    p["w_dkv"] = dense_init(ks[2], d, (cfg.kv_lora_rank + rhd,), dtype)
    p["norm_kv"] = init_norm(cfg.kv_lora_rank, dtype)
    p["w_uk"] = dense_init(ks[3], cfg.kv_lora_rank, (cfg.num_heads, hd), dtype)
    p["w_uv"] = dense_init(ks[4], cfg.kv_lora_rank, (cfg.num_heads, vhd), dtype)
    p["wo"] = dense_init(ks[5], cfg.num_heads * vhd, (d,), dtype).reshape(
        cfg.num_heads, vhd, d)
    return p


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _group_q(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def _plain_attention(q, k, v, *, q_pos, k_pos, causal, window, logit_dtype):
    """q: (B,Sq,KV,G,hd); k,v: (B,Sk,KV,hd). Materializes (Sq,Sk) scores."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    scores = jnp.einsum("bqkgh,bskh->bqkgs", q.astype(logit_dtype),
                        k.astype(logit_dtype)) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def _flash_attention_jnp(q, k, v, *, q_pos, k_pos, causal, window,
                         q_chunk=_Q_CHUNK, kv_chunk=_KV_CHUNK,
                         causal_skip: bool = False, unroll: bool = False):
    """Flash-style online-softmax attention, pure jnp.

    q: (B,Sq,KV,G,hd); k,v: (B,Sk,KV,hd); q_pos: (Sq,), k_pos: (Sk,).
    ``causal_skip``: unroll the q-chunk loop in python and slice the KV range
    each q chunk can actually see (exact-causal FLOPs; bigger HLO).  Default
    is a uniform double-scan (2x the causal FLOPs, tiny HLO) — this is the
    baseline/optimized pair used in EXPERIMENTS.md §Perf.

    ``unroll``: python loops for BOTH chunk levels (dry-run cost mode only —
    XLA cost analysis visits scan bodies once, so the scanned form
    undercounts attention FLOPs/bytes by ~nq*nk).
    """
    if unroll:
        q_chunk = kv_chunk = 2048  # fewer, MXU-aligned bodies for compile
    b, sq, nkv, g, hd = q.shape
    sk = k.shape[1]
    vd = v.shape[-1]  # may differ from hd (MLA: qk 192, v 128)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # pad ragged tails (e.g. 1601 vision tokens) and mask them out
    sq_pad = (-sq) % q_chunk
    sk_pad = (-sk) % kv_chunk
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, sq_pad))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        # padded keys get position +inf-ish so the causal mask kills them;
        # the explicit validity mask below handles the non-causal case
        q_pos_max = jnp.iinfo(jnp.int32).max
        k_pos = jnp.pad(k_pos, (0, sk_pad), constant_values=q_pos_max)
    k_valid = jnp.arange(sk + sk_pad) < sk
    sq_full, sk_full = sq + sq_pad, sk + sk_pad
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))

    def one_q_chunk(q_blk, qpos_blk, k_all, v_all, kpos_all, kvalid_all):
        nkc = k_all.shape[1] // kv_chunk
        k_c = k_all.reshape(b, nkc, kv_chunk, nkv, hd)
        v_c = v_all.reshape(b, nkc, kv_chunk, nkv, vd)
        kp_c = kpos_all.reshape(nkc, kv_chunk)
        kv_c = kvalid_all.reshape(nkc, kv_chunk)

        def body(carry, xs):
            m, l, acc = carry
            k_blk, v_blk, kp_blk, kval_blk = xs
            s = jnp.einsum("bqkgh,bskh->bqkgs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(kval_blk[None, :],
                                    (q_blk.shape[1], kv_chunk))
            if causal:
                mask &= qpos_blk[:, None] >= kp_blk[None, :]
            if window is not None:
                mask &= qpos_blk[:, None] - kp_blk[None, :] < window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskh->bqkgh", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        qc = q_blk.shape[1]
        init = (jnp.full((b, qc, nkv, g), NEG_INF, jnp.float32),
                jnp.zeros((b, qc, nkv, g), jnp.float32),
                jnp.zeros((b, qc, nkv, g, vd), jnp.float32))
        if unroll:
            carry = init
            for j in range(nkc):
                carry, _ = body(carry, (k_c[:, j], v_c[:, j], kp_c[j],
                                        kv_c[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                body, init,
                (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0), kp_c,
                 kv_c))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    nqc = sq_full // q_chunk
    q_c = q.reshape(b, nqc, q_chunk, nkv, g, hd)
    qp_c = q_pos.reshape(nqc, q_chunk)

    if unroll and not (causal_skip and causal):
        outs = [one_q_chunk(q_c[:, i], qp_c[i], k, v, k_pos, k_valid)
                for i in range(nqc)]
        out = jnp.stack(outs, axis=1).reshape(b, sq_full, nkv, g, vd)
        return out[:, :sq]

    if causal_skip and causal:
        # python loop over q chunks with exact KV extent per chunk
        outs = []
        for i in range(nqc):
            hi = (i + 1) * q_chunk
            lo = 0
            if window is not None:
                lo = max(0, (i * q_chunk - int(window)) // kv_chunk * kv_chunk)
            hi = min(((hi + kv_chunk - 1) // kv_chunk) * kv_chunk, sk_full)
            outs.append(one_q_chunk(q_c[:, i], qp_c[i], k[:, lo:hi],
                                    v[:, lo:hi], k_pos[lo:hi],
                                    k_valid[lo:hi]))
        out = jnp.stack(outs, axis=1).reshape(b, sq_full, nkv, g, vd)
        return out[:, :sq]

    out = jax.lax.map(
        lambda xs: one_q_chunk(xs[0], xs[1], k, v, k_pos, k_valid),
        (jnp.moveaxis(q_c, 1, 0), qp_c))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_full, nkv, g, vd)
    return out[:, :sq]


def multihead_attention(q, k, v, *, q_pos, k_pos, causal, window=None,
                        causal_skip=False, unroll=False,
                        use_pallas=False):
    """Dispatch between plain / flash-jnp / Pallas paths.
    q: (B,Sq,H,hd) ungrouped."""
    if use_pallas and q.shape[1] == k.shape[1] and \
            q.shape[-1] == v.shape[-1] and q.shape[1] % 128 == 0:
        # Pallas kernel path (TPU production; interpret=True on CPU).
        # Layout: (B,S,H,D) -> (B,H,S,D); contiguous positions assumed.
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=causal, window=window)
        return jnp.swapaxes(out, 1, 2)
    nkv = k.shape[2]
    qg = _group_q(q, nkv)
    if q.shape[1] * k.shape[1] <= _PLAIN_ATTN_MAX_SEQ ** 2:
        out = _plain_attention(qg, k, v, q_pos=q_pos, k_pos=k_pos,
                               causal=causal, window=window,
                               logit_dtype=jnp.float32)
    else:
        out = _flash_attention_jnp(qg, k, v, q_pos=q_pos, k_pos=k_pos,
                                   causal=causal, window=window,
                                   causal_skip=causal_skip, unroll=unroll)
    b, s = q.shape[:2]
    return out.reshape(b, s, q.shape[2], v.shape[-1])  # out head dim = v's


# ---------------------------------------------------------------------------
# GQA self-attention (train / prefill)
# ---------------------------------------------------------------------------


def _project_qkv(p: dict, cfg: ModelConfig, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def gqa_forward(p: dict, cfg: ModelConfig, x, positions, *,
                window=None, causal_skip=False, unroll=False,
                use_pallas=False):
    """x: (B,S,d); positions: (S,) absolute positions."""
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    win = window if window is not None else cfg.sliding_window
    out = multihead_attention(q, k, v, q_pos=positions, k_pos=positions,
                              causal=True, window=win,
                              causal_skip=causal_skip, unroll=unroll,
                              use_pallas=use_pallas)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention_forward(p: dict, cfg: ModelConfig, x, context,
                            unroll=False):
    """Cross-attention: queries from x (B,S,d), keys/values from context
    (B,T,d).  No RoPE, no causal mask (Llama-3.2-Vision / enc-dec style)."""
    q, k, v = _project_qkv(p, cfg, x, kv_x=context)
    s_pos = jnp.arange(x.shape[1])
    t_pos = jnp.arange(context.shape[1])
    out = multihead_attention(q, k, v, q_pos=s_pos, k_pos=t_pos, causal=False,
                              unroll=unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "gate_attn" in p:
        out = out * jnp.tanh(p["gate_attn"])
    return out


# ---------------------------------------------------------------------------
# GQA decode with KV cache (ring buffer for sliding-window)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  window=None) -> dict:
    win = window if window is not None else cfg.sliding_window
    slots = min(max_len, win) if win else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, slots, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, slots, cfg.num_kv_heads, hd), dtype),
    }


def _pos_vec(pos, batch: int):
    """Normalize decode positions to a (B,) vector (per-sequence positions
    enable continuous batching: each slot decodes at its own offset)."""
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos, (batch,)) if pos.ndim == 0 else pos


def _ring_slot_positions(pos, slots: int):
    """Positions stored in each ring slot after the token at ``pos`` was
    inserted; -1 where the slot has never been written. pos: (B,)."""
    s = jnp.arange(slots)
    p = pos[:, None] - ((pos[:, None] - s[None, :]) % slots)
    return jnp.where(p >= 0, p, -1)  # (B, slots)


def gqa_decode(p: dict, cfg: ModelConfig, x, cache: dict, pos, *,
               window=None):
    """x: (B,1,d); pos: scalar or (B,) int32 position(s) of the new token.
    Returns (out (B,1,d), new_cache)."""
    b = x.shape[0]
    pos = _pos_vec(pos, b)
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slots = cache["k"].shape[1]
    slot = jnp.mod(pos, slots)  # (B,)
    bi = jnp.arange(b)
    new_k = cache["k"].at[bi, slot].set(k[:, 0].astype(cache["k"].dtype))
    new_v = cache["v"].at[bi, slot].set(v[:, 0].astype(cache["v"].dtype))

    slot_pos = _ring_slot_positions(pos, slots)  # (B, slots)
    win = window if window is not None else cfg.sliding_window
    valid = slot_pos >= 0
    valid &= slot_pos <= pos[:, None]
    if win:
        valid &= pos[:, None] - slot_pos < win

    nkv = new_k.shape[2]
    qg = _group_q(q, nkv)  # (B,1,KV,G,hd)
    scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bqkgh,bskh->bqkgs", qg, new_k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", probs.astype(new_v.dtype), new_v)
    out = out.reshape(x.shape[0], 1, cfg.num_heads, -1)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": new_k, "v": new_v}


def init_cross_cache(p: dict, cfg: ModelConfig, context, dtype) -> dict:
    """Precompute cross-attention K/V once from the (encoder/vision) context."""
    k = jnp.einsum("btd,dhk->bthk", context, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", context, p["wv"])
    if cfg.qkv_bias and "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return {"k": k.astype(dtype), "v": v.astype(dtype)}


def cross_attention_decode(p: dict, cfg: ModelConfig, x, cross_cache: dict):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"]
    k, v = cross_cache["k"], cross_cache["v"]
    qg = _group_q(q, k.shape[2])
    scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bqkgh,bskh->bqkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", probs.astype(v.dtype), v)
    out = out.reshape(x.shape[0], x.shape[1], cfg.num_heads, -1)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "gate_attn" in p:
        out = out * jnp.tanh(p["gate_attn"])
    return out


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def _mla_q(p: dict, cfg: ModelConfig, x, positions):
    hd = cfg.resolved_head_dim
    if cfg.q_lora_rank:
        cq = x @ p["w_dq"]
        cq = rms_norm(cq, p["norm_q"]["scale"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: dict, cfg: ModelConfig, x, positions):
    ckv = x @ p["w_dkv"]  # (B,S,lora+rope)
    c, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c = rms_norm(c, p["norm_kv"]["scale"], cfg.norm_eps)
    # k_rope is shared across heads: treat as a single head for RoPE
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c, k_rope


def mla_forward(p: dict, cfg: ModelConfig, x, positions, *,
                window=None, causal_skip=False, unroll=False):
    """Naive (decompressed) MLA for train/prefill: materialize per-head K/V."""
    hd = cfg.resolved_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], cfg.qk_rope_head_dim))],
        axis=-1)
    out = multihead_attention(q, k, v, q_pos=positions, k_pos=positions,
                              causal=True, window=window,
                              causal_skip=causal_skip, unroll=unroll)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p: dict, cfg: ModelConfig, x, cache: dict, pos):
    """Absorbed MLA decode: attend directly in the latent space.

    Cache holds the 512-dim latent + 64-dim shared rope key per token —
    DeepSeek-V2's actual deployment trick (93% KV-cache reduction).
    pos: scalar or (B,) per-sequence positions."""
    hd = cfg.resolved_head_dim
    b = x.shape[0]
    pos = _pos_vec(pos, b)
    q_nope, q_rope = _mla_q(p, cfg, x, pos[:, None])  # (B,1,H,*)
    c_new, k_rope_new = _mla_latent(p, cfg, x, pos[:, None])

    bi = jnp.arange(b)
    cache_c = cache["c"].at[bi, pos].set(
        c_new[:, 0].astype(cache["c"].dtype))
    cache_r = cache["k_rope"].at[bi, pos].set(
        k_rope_new[:, 0].astype(cache["k_rope"].dtype))

    # absorb W_uk into the query: q_lat (B,1,H,lora)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = 1.0 / jnp.sqrt(jnp.array(hd + cfg.qk_rope_head_dim, jnp.float32))
    scores = (jnp.einsum("bshr,blr->bshl", q_lat, cache_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,blk->bshl", q_rope, cache_r,
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(cache_c.shape[1])[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bshl,blr->bshr", probs.astype(cache_c.dtype),
                         cache_c)
    v = jnp.einsum("bshr,rhk->bshk", ctx_lat, p["w_uv"])
    out = jnp.einsum("bshk,hkd->bsd", v, p["wo"])
    return out, {"c": cache_c, "k_rope": cache_r}
