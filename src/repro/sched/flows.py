"""Flow scheduler — "Horizontal" co-design across jobs (paper Sec. IV-A).

Multiple training jobs' iterations are periodic bandwidth pulses (compute
phase, then a communication burst).  When bursts from different jobs hit a
shared link simultaneously, both stretch (the Fig. 5(b) case at (2)).
CASSINI's observation: shifting jobs' iteration *phases* interleaves the
bursts ("staggering peak") and recovers most of the loss.

We model each job as a rectangular bandwidth-demand pulse train and compute
the stretch factor of the communication phase under proportional max-min
sharing, then search over phase shifts to minimize the worst JCT.

Two granularities:

  * single link — every job presses ``JobProfile.demand_frac`` onto one
    shared link (the original CASSINI toy model);
  * a **set of contended links** — each job carries a per-link demand map
    (``link_demands``) derived from its ``CodesignReport`` hot-spot map by
    ``codesign.cluster.plan_cluster``; a job's burst progresses at the rate
    of its most-contended link (the network-layer bottleneck rule).

The simulator steps from phase transition to phase transition (rates are
piecewise constant in between), so results are exact and independent of
the ``dt`` knob, which survives in signatures as a floating-point fallback
step — see ``tests/test_sched.py``'s convergence check.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

LinkDemands = Sequence[Dict[Hashable, float]]  # per-job {link: demand frac}


@dataclass(frozen=True)
class JobProfile:
    """One training job as seen by the shared network."""

    name: str
    compute_s: float        # compute phase duration per iteration
    comm_s: float           # communication burst duration (alone on link)
    demand_frac: float = 1.0  # fraction of the link the burst wants

    @property
    def period(self) -> float:
        return self.compute_s + self.comm_s


def _simulate_links(jobs: Sequence[JobProfile], phases: Sequence[float],
                    link_demands: Optional[LinkDemands] = None,
                    horizon_iters: int = 20, dt: float = 1e-4
                    ) -> Dict[str, float]:
    """Time-stepped sharing of a set of contended links.

    Each job alternates compute (no demand) and comm phases; during comm it
    presses its per-link demand fractions onto every link in its map, and
    its burst progresses at the rate of its most oversubscribed link
    (proportional sharing: rate = min over links of 1/total_demand, capped
    at 1).  Returns average iteration time ('JCT') per job."""
    if len(phases) != len(jobs):
        raise ValueError(f"{len(phases)} phases for {len(jobs)} jobs")
    if link_demands is None:
        link_demands = [{"shared": j.demand_frac} for j in jobs]
    elif len(link_demands) != len(jobs):
        raise ValueError(f"{len(link_demands)} link-demand maps for "
                         f"{len(jobs)} jobs")
    t = 0.0
    state = []
    for j, ph in zip(jobs, phases):
        state.append({
            "job": j, "phase": "compute",
            "remaining": j.compute_s + (ph % j.period),
            "iters": 0, "t_done": [],
        })
    # run until EVERY job finishes its horizon (a global iteration budget
    # would starve a slow tenant sharing with a much faster one and report
    # inf); the wall-clock cap guards pathological stretch
    max_t = horizon_iters * max(j.period for j in jobs) * (len(jobs) + 3)
    # Event-driven stepping: link demand (and so every job's rate) is
    # piecewise constant between phase transitions, so advancing exactly
    # onto the next transition integrates the sharing model *exactly*.
    # The old fixed-dt loop discarded each transition's overshoot and
    # held other jobs' rates stale across the transition step, an O(dt)
    # bias per phase per job that made dt-halving converge only first
    # order.  ``dt`` is kept as a public knob / fp fallback: steps never
    # need to be smaller than the next event, so results are now
    # dt-independent (dt-halving changes nothing but runtime).
    while any(s["iters"] < horizon_iters for s in state) and t < max_t:
        total_d: Dict[Hashable, float] = {}
        for s, dem in zip(state, link_demands):
            if s["phase"] == "comm":
                for link, d in dem.items():
                    total_d[link] = total_d.get(link, 0.0) + d
        rates = []
        for s, dem in zip(state, link_demands):
            if s["phase"] == "compute":
                rates.append(1.0)
            else:
                rate = 1.0
                for link in dem:
                    td = total_d.get(link, 0.0)
                    if td > 1.0:
                        rate = min(rate, 1.0 / td)
                rates.append(rate)
        step = min((s["remaining"] / r for s, r in zip(state, rates)
                    if r > 0), default=dt)
        step = max(step, 1e-12)  # fp guard: always make progress
        for s, rate in zip(state, rates):
            s["remaining"] -= step * rate
            if s["remaining"] <= 1e-12:
                if s["phase"] == "compute":
                    s["phase"] = "comm"
                    s["remaining"] = s["job"].comm_s
                else:
                    s["phase"] = "compute"
                    s["remaining"] = s["job"].compute_s
                    s["iters"] += 1
                    s["t_done"].append(t + step)
        t += step
    out = {}
    for s in state:
        if s["iters"] >= 2:
            d = s["t_done"]
            out[s["job"].name] = (d[-1] - d[0]) / (len(d) - 1)
        else:
            out[s["job"].name] = float("inf")
    return out


def _simulate_link(jobs: Sequence[JobProfile], phases: Sequence[float],
                   horizon_iters: int = 20, dt: float = 1e-4
                   ) -> Dict[str, float]:
    """Single shared link (every job demands ``demand_frac`` of it)."""
    return _simulate_links(jobs, phases, None, horizon_iters, dt)


# ---------------------------------------------------------------------------
# Non-periodic (arrival-driven) profiles: the serving path
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BurstProfile:
    """A non-periodic tenant as seen by the shared network: an explicit
    list of communication bursts at absolute ``(scheduled_start_s,
    comm_s)`` — e.g. a serving tenant's per-batch transfer windows under
    an open-loop arrival process.  Bursts are FIFO-chained: a burst
    starts at ``max(scheduled_start, previous burst's finish)`` (one
    transfer engine per tenant), so queueing delay propagates."""

    name: str
    bursts: Tuple[Tuple[float, float], ...] = ()
    demand_frac: float = 1.0

    @property
    def total_comm_s(self) -> float:
        return sum(c for _, c in self.bursts)


def _simulate_mixed(jobs: Sequence[JobProfile], phases: Sequence[float],
                    bursts: Sequence[BurstProfile],
                    link_demands: Optional[LinkDemands] = None,
                    burst_demands: Optional[LinkDemands] = None,
                    horizon_iters: int = 20, dt: float = 1e-4
                    ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Periodic pulse trains and non-periodic burst tenants sharing one
    set of links.  Same exact event-driven engine as
    :func:`_simulate_links` (rates are piecewise constant between phase
    transitions / burst starts), with burst tenants idle between their
    scheduled windows.  Returns ``(avg iteration time per periodic job,
    comm stretch per burst tenant)`` — stretch = total contended burst
    time / total solo burst time (1.0 = unaffected)."""
    if len(phases) != len(jobs):
        raise ValueError(f"{len(phases)} phases for {len(jobs)} jobs")
    if link_demands is None:
        link_demands = [{"shared": j.demand_frac} for j in jobs]
    if burst_demands is None:
        burst_demands = [{"shared": b.demand_frac} for b in bursts]
    if len(link_demands) != len(jobs) or len(burst_demands) != len(bursts):
        raise ValueError("demand maps must match jobs/bursts 1:1")
    t = 0.0
    state = []
    for j, ph in zip(jobs, phases):
        state.append({"job": j, "phase": "compute",
                      "remaining": j.compute_s + (ph % j.period),
                      "iters": 0, "t_done": []})
    bstate = []
    for b in bursts:
        bstate.append({"prof": b, "i": 0, "active": False,
                       "remaining": 0.0, "busy": 0.0})
    horizon_t = max((b.bursts[-1][0] for b in bursts if b.bursts),
                    default=0.0)
    periods = [j.period for j in jobs]
    max_t = (horizon_iters * max(periods, default=1.0)
             * (len(jobs) + len(bursts) + 3)) + 2 * horizon_t + 1.0

    def unfinished() -> bool:
        if any(s["iters"] < horizon_iters for s in state):
            return True
        return any(bs["active"] or bs["i"] < len(bs["prof"].bursts)
                   for bs in bstate)

    while unfinished() and t < max_t:
        # start any burst whose scheduled time has come (FIFO per tenant)
        for bs in bstate:
            if not bs["active"] and bs["i"] < len(bs["prof"].bursts):
                sched, comm = bs["prof"].bursts[bs["i"]]
                if t >= sched - 1e-12:
                    bs["active"] = True
                    bs["remaining"] = comm
        total_d: Dict[Hashable, float] = {}
        for s, dem in zip(state, link_demands):
            if s["phase"] == "comm":
                for link, d in dem.items():
                    total_d[link] = total_d.get(link, 0.0) + d
        for bs, dem in zip(bstate, burst_demands):
            if bs["active"]:
                for link, d in dem.items():
                    total_d[link] = total_d.get(link, 0.0) + d

        def rate_of(dem) -> float:
            rate = 1.0
            for link in dem:
                td = total_d.get(link, 0.0)
                if td > 1.0:
                    rate = min(rate, 1.0 / td)
            return rate

        rates = [1.0 if s["phase"] == "compute" else rate_of(dem)
                 for s, dem in zip(state, link_demands)]
        brates = [rate_of(dem) if bs["active"] else 0.0
                  for bs, dem in zip(bstate, burst_demands)]
        events = [s["remaining"] / r for s, r in zip(state, rates) if r > 0]
        events += [bs["remaining"] / r for bs, r in zip(bstate, brates)
                   if bs["active"] and r > 0]
        # idle bursts wake at their scheduled start — that's an event too
        for bs in bstate:
            if not bs["active"] and bs["i"] < len(bs["prof"].bursts):
                events.append(max(bs["prof"].bursts[bs["i"]][0] - t, 0.0))
        step = max(min(events, default=dt), 1e-12)
        for s, rate in zip(state, rates):
            s["remaining"] -= step * rate
            if s["remaining"] <= 1e-12:
                if s["phase"] == "compute":
                    s["phase"] = "comm"
                    s["remaining"] = s["job"].comm_s
                else:
                    s["phase"] = "compute"
                    s["remaining"] = s["job"].compute_s
                    s["iters"] += 1
                    s["t_done"].append(t + step)
        for bs, rate in zip(bstate, brates):
            if bs["active"]:
                bs["remaining"] -= step * rate
                bs["busy"] += step
                if bs["remaining"] <= 1e-12:
                    bs["active"] = False
                    bs["i"] += 1
        t += step
    jct: Dict[str, float] = {}
    for s in state:
        if s["iters"] >= 2:
            d = s["t_done"]
            jct[s["job"].name] = (d[-1] - d[0]) / (len(d) - 1)
        else:
            jct[s["job"].name] = float("inf")
    stretch: Dict[str, float] = {}
    for bs in bstate:
        solo = bs["prof"].total_comm_s
        stretch[bs["prof"].name] = bs["busy"] / solo if solo > 0 else 1.0
    return jct, stretch


def multi_job_jct(jobs: Sequence[JobProfile], phases: Sequence[float],
                  link_demands: Optional[LinkDemands] = None,
                  horizon_iters: int = 20, dt: float = 1e-4
                  ) -> Dict[str, float]:
    """Average iteration time per job at the given phase offsets."""
    return _simulate_links(jobs, phases, link_demands, horizon_iters, dt)


def worst_stretch(jct: Dict[str, float],
                  jobs: Sequence[JobProfile]) -> float:
    """Worst relative slowdown vs. running alone (>= 1 up to dt noise)."""
    return max(jct[j.name] / j.period for j in jobs)


def stagger_jobs(jobs: Sequence[JobProfile], grid: int = 8,
                 link_demands: Optional[LinkDemands] = None,
                 horizon_iters: int = 20, dt: float = 1e-4, meters=None
                 ) -> Tuple[Tuple[float, ...], Dict[str, float],
                            Dict[str, float]]:
    """CASSINI-style phase search: grid over phase offsets of jobs[1:]
    (job 0 pinned at 0), minimizing the worst relative slowdown.
    Returns (best_phases, jct_unstaggered, jct_staggered).  The zero-phase
    schedule is always in the search set, so the staggered worst case is
    never worse than the naive one.  ``meters`` (``repro.obs.meters``)
    counts the grid points simulated."""

    base_phases = tuple(0.0 for _ in jobs)

    def sim(phases):
        if meters is not None:
            meters.incr("flows.stagger.evals")
        return _simulate_links(jobs, phases, link_demands, horizon_iters, dt)

    base = sim(base_phases)
    best = base_phases
    best_jct = base
    best_val = worst_stretch(base, jobs)
    grids = [[i / grid * j.period for i in range(grid)] for j in jobs[1:]]
    for combo in itertools.product(*grids):
        phases = (0.0, *combo)
        jct = sim(phases)
        val = worst_stretch(jct, jobs)
        if val < best_val - 1e-9:
            best_val = val
            best = phases
            best_jct = jct
    return best, base, best_jct


def stagger_mixed(jobs: Sequence[JobProfile],
                  bursts: Sequence[BurstProfile], grid: int = 8,
                  link_demands: Optional[LinkDemands] = None,
                  burst_demands: Optional[LinkDemands] = None,
                  horizon_iters: int = 20, dt: float = 1e-4, meters=None
                  ) -> Tuple[Tuple[float, ...],
                             Tuple[Dict[str, float], Dict[str, float]],
                             Tuple[Dict[str, float], Dict[str, float]]]:
    """CASSINI for training/serving co-tenancy: grid over the periodic
    jobs' phase offsets with the serving bursts pinned at their
    arrival-driven absolute times (you cannot stagger a user's request),
    minimizing the worst of (training stretch, serving burst stretch).

    Returns ``(best_phases, (jct, burst_stretch) naive,
    (jct, burst_stretch) staggered)``.  The zero-phase schedule is in the
    search set, so the staggered worst case is never worse."""

    def sim(phases):
        if meters is not None:
            meters.incr("flows.stagger_mixed.evals")
        return _simulate_mixed(jobs, phases, bursts, link_demands,
                               burst_demands, horizon_iters, dt)

    def val(jct, stretch):
        worst = max(stretch.values(), default=1.0)
        if jobs:
            worst = max(worst, worst_stretch(jct, jobs))
        return worst

    base_phases = tuple(0.0 for _ in jobs)
    base = sim(base_phases)
    best, best_res, best_val = base_phases, base, val(*base)
    # every periodic job is free: the bursts are the pinned reference
    grids = [[i / grid * j.period for i in range(grid)] for j in jobs]
    for combo in itertools.product(*grids):
        phases = tuple(combo)
        if phases == base_phases:
            continue
        res = sim(phases)
        v = val(*res)
        if v < best_val - 1e-9:
            best_val, best, best_res = v, phases, res
    return best, base, best_res


def restagger_jobs(jobs: Sequence[JobProfile], phases: Sequence[float],
                   free: Sequence[int], grid: int = 8,
                   link_demands: Optional[LinkDemands] = None,
                   horizon_iters: int = 20, dt: float = 1e-4, meters=None
                   ) -> Tuple[Tuple[float, ...], Dict[str, float],
                              Dict[str, float]]:
    """Incremental CASSINI: search phase offsets only for the jobs at the
    ``free`` indices, holding every other job at its current phase — the
    horizontal half of event-driven re-planning (``codesign.dynamics``),
    where only the jobs touching changed links are dirty and the full
    ``grid**(n-1)`` sweep of :func:`stagger_jobs` is wasted work.

    Returns ``(best_phases, jct_at_current_phases, jct_staggered)``.  The
    current phase vector is in the search set, so the re-staggered worst
    case is never worse than leaving the phases untouched."""
    if len(phases) != len(jobs):
        raise ValueError(f"{len(phases)} phases for {len(jobs)} jobs")
    bad = [i for i in free if not 0 <= i < len(jobs)]
    if bad:
        raise ValueError(f"free indices {bad} out of range for "
                         f"{len(jobs)} jobs")
    base_phases = tuple(phases)

    def sim(ph):
        if meters is not None:
            meters.incr("flows.restagger.evals")
        return _simulate_links(jobs, ph, link_demands, horizon_iters, dt)

    base = sim(base_phases)
    best = base_phases
    best_jct = base
    best_val = worst_stretch(base, jobs)
    free = sorted(set(free))
    grids = [[i / grid * jobs[f].period for i in range(grid)]
             for f in free]
    for combo in itertools.product(*grids):
        ph = list(base_phases)
        for f, v in zip(free, combo):
            ph[f] = v
        jct = sim(tuple(ph))
        val = worst_stretch(jct, jobs)
        if val < best_val - 1e-9:
            best_val = val
            best = tuple(ph)
            best_jct = jct
    return best, base, best_jct
