"""Flow scheduler — "Horizontal" co-design across jobs (paper Sec. IV-A).

Multiple training jobs' iterations are periodic bandwidth pulses (compute
phase, then a communication burst).  When bursts from different jobs hit a
shared link simultaneously, both stretch (the Fig. 5(b) case at (2)).
CASSINI's observation: shifting jobs' iteration *phases* interleaves the
bursts ("staggering peak") and recovers most of the loss.

We model each job as a rectangular bandwidth-demand pulse train on a shared
link and compute the stretch factor of the communication phase under
max-min sharing, then search over phase shifts to minimize the worst JCT.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple


@dataclass(frozen=True)
class JobProfile:
    """One training job as seen by a shared link."""

    name: str
    compute_s: float        # compute phase duration per iteration
    comm_s: float           # communication burst duration (alone on link)
    demand_frac: float = 1.0  # fraction of the link the burst wants

    @property
    def period(self) -> float:
        return self.compute_s + self.comm_s


def _simulate_link(jobs: Sequence[JobProfile], phases: Sequence[float],
                   horizon_iters: int = 20, dt: float = 1e-4
                   ) -> Dict[str, float]:
    """Time-stepped max-min sharing of one link.  Each job alternates
    compute (no demand) and comm (demand_frac) phases; a job's comm phase
    extends while it hasn't transmitted comm_s * demand_frac worth of
    link-seconds.  Returns average iteration time ('JCT') per job."""
    t = 0.0
    state = []
    for j, ph in zip(jobs, phases):
        state.append({
            "job": j, "phase": "compute",
            "remaining": j.compute_s + (ph % j.period),
            "iters": 0, "t_done": [],
            "start": t,
        })
    total_iters = horizon_iters * len(jobs)
    done_iters = 0
    max_t = horizon_iters * max(j.period for j in jobs) * 4
    while done_iters < total_iters and t < max_t:
        demands = [s["job"].demand_frac if s["phase"] == "comm" else 0.0
                   for s in state]
        total_d = sum(demands)
        share = [0.0] * len(state)
        if total_d > 0:
            scale = min(1.0, 1.0 / total_d)
            share = [d * scale for d in demands]
        for s, sh in zip(state, share):
            if s["phase"] == "compute":
                s["remaining"] -= dt
                if s["remaining"] <= 0:
                    s["phase"] = "comm"
                    s["remaining"] = s["job"].comm_s * s["job"].demand_frac
            else:
                s["remaining"] -= dt * (sh / s["job"].demand_frac
                                        if s["job"].demand_frac else 1.0)
                if s["remaining"] <= 0:
                    s["phase"] = "compute"
                    s["remaining"] = s["job"].compute_s
                    s["iters"] += 1
                    s["t_done"].append(t)
                    done_iters += 1
        t += dt
    out = {}
    for s in state:
        if s["iters"] >= 2:
            d = s["t_done"]
            out[s["job"].name] = (d[-1] - d[0]) / (len(d) - 1)
        else:
            out[s["job"].name] = float("inf")
    return out


def multi_job_jct(jobs: Sequence[JobProfile],
                  phases: Sequence[float]) -> Dict[str, float]:
    return _simulate_link(jobs, phases)


def stagger_jobs(jobs: Sequence[JobProfile], grid: int = 8
                 ) -> Tuple[Tuple[float, ...], Dict[str, float], Dict[str, float]]:
    """CASSINI-style phase search: grid over phase offsets of jobs[1:]
    (job 0 pinned at 0), minimizing the worst relative slowdown.
    Returns (best_phases, jct_unstaggered, jct_staggered)."""
    base_phases = tuple(0.0 for _ in jobs)
    base = _simulate_link(jobs, base_phases)

    def badness(jct: Dict[str, float]) -> float:
        return max(jct[j.name] / j.period for j in jobs)

    best = base_phases
    best_val = badness(base)
    grids = [[i / grid * j.period for i in range(grid)] for j in jobs[1:]]
    for combo in itertools.product(*grids):
        phases = (0.0, *combo)
        val = badness(_simulate_link(jobs, phases))
        if val < best_val - 1e-9:
            best_val = val
            best = phases
    return best, base, _simulate_link(jobs, best)
