"""Flow scheduler — "Horizontal" co-design across jobs (paper Sec. IV-A).

Multiple training jobs' iterations are periodic bandwidth pulses (compute
phase, then a communication burst).  When bursts from different jobs hit a
shared link simultaneously, both stretch (the Fig. 5(b) case at (2)).
CASSINI's observation: shifting jobs' iteration *phases* interleaves the
bursts ("staggering peak") and recovers most of the loss.

We model each job as a rectangular bandwidth-demand pulse train and compute
the stretch factor of the communication phase under proportional max-min
sharing, then search over phase shifts to minimize the worst JCT.

Two granularities:

  * single link — every job presses ``JobProfile.demand_frac`` onto one
    shared link (the original CASSINI toy model);
  * a **set of contended links** — each job carries a per-link demand map
    (``link_demands``) derived from its ``CodesignReport`` hot-spot map by
    ``codesign.cluster.plan_cluster``; a job's burst progresses at the rate
    of its most-contended link (the network-layer bottleneck rule).

The simulator steps from phase transition to phase transition (rates are
piecewise constant in between), so results are exact and independent of
the ``dt`` knob, which survives in signatures as a floating-point fallback
step — see ``tests/test_sched.py``'s convergence check.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

LinkDemands = Sequence[Dict[Hashable, float]]  # per-job {link: demand frac}


@dataclass(frozen=True)
class JobProfile:
    """One training job as seen by the shared network."""

    name: str
    compute_s: float        # compute phase duration per iteration
    comm_s: float           # communication burst duration (alone on link)
    demand_frac: float = 1.0  # fraction of the link the burst wants

    @property
    def period(self) -> float:
        return self.compute_s + self.comm_s


def _simulate_links(jobs: Sequence[JobProfile], phases: Sequence[float],
                    link_demands: Optional[LinkDemands] = None,
                    horizon_iters: int = 20, dt: float = 1e-4
                    ) -> Dict[str, float]:
    """Time-stepped sharing of a set of contended links.

    Each job alternates compute (no demand) and comm phases; during comm it
    presses its per-link demand fractions onto every link in its map, and
    its burst progresses at the rate of its most oversubscribed link
    (proportional sharing: rate = min over links of 1/total_demand, capped
    at 1).  Returns average iteration time ('JCT') per job."""
    if len(phases) != len(jobs):
        raise ValueError(f"{len(phases)} phases for {len(jobs)} jobs")
    if link_demands is None:
        link_demands = [{"shared": j.demand_frac} for j in jobs]
    elif len(link_demands) != len(jobs):
        raise ValueError(f"{len(link_demands)} link-demand maps for "
                         f"{len(jobs)} jobs")
    t = 0.0
    state = []
    for j, ph in zip(jobs, phases):
        state.append({
            "job": j, "phase": "compute",
            "remaining": j.compute_s + (ph % j.period),
            "iters": 0, "t_done": [],
        })
    # run until EVERY job finishes its horizon (a global iteration budget
    # would starve a slow tenant sharing with a much faster one and report
    # inf); the wall-clock cap guards pathological stretch
    max_t = horizon_iters * max(j.period for j in jobs) * (len(jobs) + 3)
    # Event-driven stepping: link demand (and so every job's rate) is
    # piecewise constant between phase transitions, so advancing exactly
    # onto the next transition integrates the sharing model *exactly*.
    # The old fixed-dt loop discarded each transition's overshoot and
    # held other jobs' rates stale across the transition step, an O(dt)
    # bias per phase per job that made dt-halving converge only first
    # order.  ``dt`` is kept as a public knob / fp fallback: steps never
    # need to be smaller than the next event, so results are now
    # dt-independent (dt-halving changes nothing but runtime).
    while any(s["iters"] < horizon_iters for s in state) and t < max_t:
        total_d: Dict[Hashable, float] = {}
        for s, dem in zip(state, link_demands):
            if s["phase"] == "comm":
                for link, d in dem.items():
                    total_d[link] = total_d.get(link, 0.0) + d
        rates = []
        for s, dem in zip(state, link_demands):
            if s["phase"] == "compute":
                rates.append(1.0)
            else:
                rate = 1.0
                for link in dem:
                    td = total_d.get(link, 0.0)
                    if td > 1.0:
                        rate = min(rate, 1.0 / td)
                rates.append(rate)
        step = min((s["remaining"] / r for s, r in zip(state, rates)
                    if r > 0), default=dt)
        step = max(step, 1e-12)  # fp guard: always make progress
        for s, rate in zip(state, rates):
            s["remaining"] -= step * rate
            if s["remaining"] <= 1e-12:
                if s["phase"] == "compute":
                    s["phase"] = "comm"
                    s["remaining"] = s["job"].comm_s
                else:
                    s["phase"] = "compute"
                    s["remaining"] = s["job"].compute_s
                    s["iters"] += 1
                    s["t_done"].append(t + step)
        t += step
    out = {}
    for s in state:
        if s["iters"] >= 2:
            d = s["t_done"]
            out[s["job"].name] = (d[-1] - d[0]) / (len(d) - 1)
        else:
            out[s["job"].name] = float("inf")
    return out


def _simulate_link(jobs: Sequence[JobProfile], phases: Sequence[float],
                   horizon_iters: int = 20, dt: float = 1e-4
                   ) -> Dict[str, float]:
    """Single shared link (every job demands ``demand_frac`` of it)."""
    return _simulate_links(jobs, phases, None, horizon_iters, dt)


def multi_job_jct(jobs: Sequence[JobProfile], phases: Sequence[float],
                  link_demands: Optional[LinkDemands] = None,
                  horizon_iters: int = 20, dt: float = 1e-4
                  ) -> Dict[str, float]:
    """Average iteration time per job at the given phase offsets."""
    return _simulate_links(jobs, phases, link_demands, horizon_iters, dt)


def worst_stretch(jct: Dict[str, float],
                  jobs: Sequence[JobProfile]) -> float:
    """Worst relative slowdown vs. running alone (>= 1 up to dt noise)."""
    return max(jct[j.name] / j.period for j in jobs)


def stagger_jobs(jobs: Sequence[JobProfile], grid: int = 8,
                 link_demands: Optional[LinkDemands] = None,
                 horizon_iters: int = 20, dt: float = 1e-4, meters=None
                 ) -> Tuple[Tuple[float, ...], Dict[str, float],
                            Dict[str, float]]:
    """CASSINI-style phase search: grid over phase offsets of jobs[1:]
    (job 0 pinned at 0), minimizing the worst relative slowdown.
    Returns (best_phases, jct_unstaggered, jct_staggered).  The zero-phase
    schedule is always in the search set, so the staggered worst case is
    never worse than the naive one.  ``meters`` (``repro.obs.meters``)
    counts the grid points simulated."""

    base_phases = tuple(0.0 for _ in jobs)

    def sim(phases):
        if meters is not None:
            meters.incr("flows.stagger.evals")
        return _simulate_links(jobs, phases, link_demands, horizon_iters, dt)

    base = sim(base_phases)
    best = base_phases
    best_jct = base
    best_val = worst_stretch(base, jobs)
    grids = [[i / grid * j.period for i in range(grid)] for j in jobs[1:]]
    for combo in itertools.product(*grids):
        phases = (0.0, *combo)
        jct = sim(phases)
        val = worst_stretch(jct, jobs)
        if val < best_val - 1e-9:
            best_val = val
            best = phases
            best_jct = jct
    return best, base, best_jct


def restagger_jobs(jobs: Sequence[JobProfile], phases: Sequence[float],
                   free: Sequence[int], grid: int = 8,
                   link_demands: Optional[LinkDemands] = None,
                   horizon_iters: int = 20, dt: float = 1e-4, meters=None
                   ) -> Tuple[Tuple[float, ...], Dict[str, float],
                              Dict[str, float]]:
    """Incremental CASSINI: search phase offsets only for the jobs at the
    ``free`` indices, holding every other job at its current phase — the
    horizontal half of event-driven re-planning (``codesign.dynamics``),
    where only the jobs touching changed links are dirty and the full
    ``grid**(n-1)`` sweep of :func:`stagger_jobs` is wasted work.

    Returns ``(best_phases, jct_at_current_phases, jct_staggered)``.  The
    current phase vector is in the search set, so the re-staggered worst
    case is never worse than leaving the phases untouched."""
    if len(phases) != len(jobs):
        raise ValueError(f"{len(phases)} phases for {len(jobs)} jobs")
    bad = [i for i in free if not 0 <= i < len(jobs)]
    if bad:
        raise ValueError(f"free indices {bad} out of range for "
                         f"{len(jobs)} jobs")
    base_phases = tuple(phases)

    def sim(ph):
        if meters is not None:
            meters.incr("flows.restagger.evals")
        return _simulate_links(jobs, ph, link_demands, horizon_iters, dt)

    base = sim(base_phases)
    best = base_phases
    best_jct = base
    best_val = worst_stretch(base, jobs)
    free = sorted(set(free))
    grids = [[i / grid * jobs[f].period for i in range(grid)]
             for f in free]
    for combo in itertools.product(*grids):
        ph = list(base_phases)
        for f, v in zip(free, combo):
            ph[f] = v
        jct = sim(tuple(ph))
        val = worst_stretch(jct, jobs)
        if val < best_val - 1e-9:
            best_val = val
            best = tuple(ph)
            best_jct = jct
    return best, base, best_jct
