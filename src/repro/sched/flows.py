"""Flow scheduler — "Horizontal" co-design across jobs (paper Sec. IV-A).

Multiple training jobs' iterations are periodic bandwidth pulses (compute
phase, then a communication burst).  When bursts from different jobs hit a
shared link simultaneously, both stretch (the Fig. 5(b) case at (2)).
CASSINI's observation: shifting jobs' iteration *phases* interleaves the
bursts ("staggering peak") and recovers most of the loss.

We model each job as a rectangular bandwidth-demand pulse train and compute
the stretch factor of the communication phase under proportional max-min
sharing, then search over phase shifts to minimize the worst JCT.

Two granularities:

  * single link — every job presses ``JobProfile.demand_frac`` onto one
    shared link (the original CASSINI toy model);
  * a **set of contended links** — each job carries a per-link demand map
    (``link_demands``) derived from its ``CodesignReport`` hot-spot map by
    ``codesign.cluster.plan_cluster``; a job's burst progresses at the rate
    of its most-contended link (the network-layer bottleneck rule).

The time-step ``dt`` and simulation ``horizon_iters`` are part of the
public API (they default to values for ~10ms-scale iterations; callers with
much shorter periods should shrink ``dt`` — see ``tests/test_sched.py``'s
convergence check).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

LinkDemands = Sequence[Dict[Hashable, float]]  # per-job {link: demand frac}


@dataclass(frozen=True)
class JobProfile:
    """One training job as seen by the shared network."""

    name: str
    compute_s: float        # compute phase duration per iteration
    comm_s: float           # communication burst duration (alone on link)
    demand_frac: float = 1.0  # fraction of the link the burst wants

    @property
    def period(self) -> float:
        return self.compute_s + self.comm_s


def _simulate_links(jobs: Sequence[JobProfile], phases: Sequence[float],
                    link_demands: Optional[LinkDemands] = None,
                    horizon_iters: int = 20, dt: float = 1e-4
                    ) -> Dict[str, float]:
    """Time-stepped sharing of a set of contended links.

    Each job alternates compute (no demand) and comm phases; during comm it
    presses its per-link demand fractions onto every link in its map, and
    its burst progresses at the rate of its most oversubscribed link
    (proportional sharing: rate = min over links of 1/total_demand, capped
    at 1).  Returns average iteration time ('JCT') per job."""
    if len(phases) != len(jobs):
        raise ValueError(f"{len(phases)} phases for {len(jobs)} jobs")
    if link_demands is None:
        link_demands = [{"shared": j.demand_frac} for j in jobs]
    elif len(link_demands) != len(jobs):
        raise ValueError(f"{len(link_demands)} link-demand maps for "
                         f"{len(jobs)} jobs")
    t = 0.0
    state = []
    for j, ph in zip(jobs, phases):
        state.append({
            "job": j, "phase": "compute",
            "remaining": j.compute_s + (ph % j.period),
            "iters": 0, "t_done": [],
        })
    # run until EVERY job finishes its horizon (a global iteration budget
    # would starve a slow tenant sharing with a much faster one and report
    # inf); the wall-clock cap guards pathological stretch
    max_t = horizon_iters * max(j.period for j in jobs) * (len(jobs) + 3)
    while any(s["iters"] < horizon_iters for s in state) and t < max_t:
        total_d: Dict[Hashable, float] = {}
        for s, dem in zip(state, link_demands):
            if s["phase"] == "comm":
                for link, d in dem.items():
                    total_d[link] = total_d.get(link, 0.0) + d
        for s, dem in zip(state, link_demands):
            if s["phase"] == "compute":
                s["remaining"] -= dt
                if s["remaining"] <= 0:
                    s["phase"] = "comm"
                    s["remaining"] = s["job"].comm_s
            else:
                rate = 1.0
                for link in dem:
                    td = total_d.get(link, 0.0)
                    if td > 1.0:
                        rate = min(rate, 1.0 / td)
                s["remaining"] -= dt * rate
                if s["remaining"] <= 0:
                    s["phase"] = "compute"
                    s["remaining"] = s["job"].compute_s
                    s["iters"] += 1
                    s["t_done"].append(t)
        t += dt
    out = {}
    for s in state:
        if s["iters"] >= 2:
            d = s["t_done"]
            out[s["job"].name] = (d[-1] - d[0]) / (len(d) - 1)
        else:
            out[s["job"].name] = float("inf")
    return out


def _simulate_link(jobs: Sequence[JobProfile], phases: Sequence[float],
                   horizon_iters: int = 20, dt: float = 1e-4
                   ) -> Dict[str, float]:
    """Single shared link (every job demands ``demand_frac`` of it)."""
    return _simulate_links(jobs, phases, None, horizon_iters, dt)


def multi_job_jct(jobs: Sequence[JobProfile], phases: Sequence[float],
                  link_demands: Optional[LinkDemands] = None,
                  horizon_iters: int = 20, dt: float = 1e-4
                  ) -> Dict[str, float]:
    """Average iteration time per job at the given phase offsets."""
    return _simulate_links(jobs, phases, link_demands, horizon_iters, dt)


def worst_stretch(jct: Dict[str, float],
                  jobs: Sequence[JobProfile]) -> float:
    """Worst relative slowdown vs. running alone (>= 1 up to dt noise)."""
    return max(jct[j.name] / j.period for j in jobs)


def stagger_jobs(jobs: Sequence[JobProfile], grid: int = 8,
                 link_demands: Optional[LinkDemands] = None,
                 horizon_iters: int = 20, dt: float = 1e-4
                 ) -> Tuple[Tuple[float, ...], Dict[str, float],
                            Dict[str, float]]:
    """CASSINI-style phase search: grid over phase offsets of jobs[1:]
    (job 0 pinned at 0), minimizing the worst relative slowdown.
    Returns (best_phases, jct_unstaggered, jct_staggered).  The zero-phase
    schedule is always in the search set, so the staggered worst case is
    never worse than the naive one."""
    base_phases = tuple(0.0 for _ in jobs)

    def sim(phases):
        return _simulate_links(jobs, phases, link_demands, horizon_iters, dt)

    base = sim(base_phases)
    best = base_phases
    best_jct = base
    best_val = worst_stretch(base, jobs)
    grids = [[i / grid * j.period for i in range(grid)] for j in jobs[1:]]
    for combo in itertools.product(*grids):
        phases = (0.0, *combo)
        jct = sim(phases)
        val = worst_stretch(jct, jobs)
        if val < best_val - 1e-9:
            best_val = val
            best = phases
            best_jct = jct
    return best, base, best_jct
