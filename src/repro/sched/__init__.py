"""Scheduler middleware — the two layers the paper ADDS to get from the
three-layer to the five-layer paradigm (Sec. IV-A).

``tasks``  — task scheduler ("Vertical" co-design): orders the comm tasks a
             parallelization strategy emits, overlapping them with compute
             to minimize JCT (Lina-style priority, Echelon-style slack).
``flows``  — flow scheduler ("Horizontal" co-design): places multiple jobs'
             flows onto shared links (CASSINI-style staggering), periodic
             training profiles and non-periodic serving bursts alike.
``arrivals`` — open-loop request processes (seeded Poisson /
             trace-driven) feeding the serving co-design layer.
``atp``    — "Host-Net" co-design: in-network aggregation modeling (ATP).
"""
from repro.sched.tasks import SimResult, simulate_iteration  # noqa: F401
from repro.sched.flows import (BurstProfile, JobProfile,  # noqa: F401
                               multi_job_jct, stagger_jobs, stagger_mixed,
                               worst_stretch)
from repro.sched.arrivals import (Arrival, PoissonArrivals,  # noqa: F401
                                  TraceArrivals, demand_series,
                                  offered_load)
