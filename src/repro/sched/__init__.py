"""Scheduler middleware — the two layers the paper ADDS to get from the
three-layer to the five-layer paradigm (Sec. IV-A).

``tasks``  — task scheduler ("Vertical" co-design): orders the comm tasks a
             parallelization strategy emits, overlapping them with compute
             to minimize JCT (Lina-style priority, Echelon-style slack).
``flows``  — flow scheduler ("Horizontal" co-design): places multiple jobs'
             flows onto shared links (CASSINI-style staggering).
``atp``    — "Host-Net" co-design: in-network aggregation modeling (ATP).
"""
from repro.sched.tasks import SimResult, simulate_iteration  # noqa: F401
from repro.sched.flows import (JobProfile, multi_job_jct,  # noqa: F401
                               stagger_jobs, worst_stretch)
