"""Open-loop request arrival processes for serving co-design.

Training demand is iteration-periodic; serving demand is *arrival-driven*:
an open-loop process emits requests at times the system does not control,
and the scheduler's job is to keep latency SLOs under that offered load
(the workload-dependence the survey's Sec. V frames as the reason one
communication schedule cannot fit all tenants).

Everything here is deterministic by construction — the Poisson process
runs on a hand-rolled splitmix64 counter PRNG keyed by ``seed``, never
the stdlib's global ``random`` — so `plan_serving` reports, benchmark
rows, and hypothesis properties replay bit-identically.

Two processes:

  * :class:`PoissonArrivals` — exponential inter-arrival times at
    ``rate_rps``, fixed (prompt, decode) token budget per request.
  * :class:`TraceArrivals`  — an explicit tuple of :class:`Arrival`s
    (production trace replay); round-trips through
    :func:`arrivals_to_dict` / :func:`arrivals_from_dict`.

Both expose ``sample(horizon_s)``; :func:`demand_series` folds a sample
into per-phase (prefill / decode) token demand over time windows, the
open-loop analogue of the periodic per-link demand maps in
``sched.flows``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Sequence, Tuple

_MASK = (1 << 64) - 1


def _splitmix64(state: int) -> Tuple[int, int]:
    """One splitmix64 step: returns (new_state, 64-bit output)."""
    state = (state + 0x9E3779B97F4A7C15) & _MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return state, z ^ (z >> 31)


def _uniform(z: int) -> float:
    """A 64-bit word as a uniform in [0, 1) with 53-bit mantissa."""
    return (z >> 11) * (1.0 / (1 << 53))


@dataclass(frozen=True)
class Arrival:
    """One request entering the system at absolute time ``t`` (seconds),
    carrying a prefill budget of ``prompt_tokens`` and a decode budget of
    ``decode_tokens`` new tokens."""

    rid: str
    t: float
    prompt_tokens: int
    decode_tokens: int

    def to_dict(self) -> Dict[str, object]:
        return {"rid": self.rid, "t": self.t,
                "prompt_tokens": self.prompt_tokens,
                "decode_tokens": self.decode_tokens}

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "Arrival":
        return cls(rid=str(d["rid"]), t=float(d["t"]),
                   prompt_tokens=int(d["prompt_tokens"]),
                   decode_tokens=int(d["decode_tokens"]))


@dataclass(frozen=True)
class PoissonArrivals:
    """Seeded open-loop Poisson process: inter-arrival gaps are
    ``Exp(rate_rps)`` drawn from a splitmix64 stream, every request has
    the same (prompt, decode) token mix.  ``sample`` is a pure function
    of ``(seed, rate_rps, horizon_s)``."""

    rate_rps: float
    prompt_tokens: int = 512
    decode_tokens: int = 128
    seed: int = 0

    def __post_init__(self) -> None:
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.prompt_tokens <= 0 or self.decode_tokens <= 0:
            raise ValueError("prompt_tokens and decode_tokens must be > 0")

    def sample(self, horizon_s: float) -> Tuple[Arrival, ...]:
        state = (self.seed * 0x9E3779B97F4A7C15 + 1) & _MASK
        out: List[Arrival] = []
        t = 0.0
        i = 0
        while True:
            state, z = _splitmix64(state)
            u = _uniform(z)
            t += -math.log(1.0 - u) / self.rate_rps
            if t >= horizon_s:
                break
            out.append(Arrival(rid=f"r{i}", t=t,
                               prompt_tokens=self.prompt_tokens,
                               decode_tokens=self.decode_tokens))
            i += 1
        return tuple(out)

    def to_dict(self) -> Dict[str, object]:
        return {"process": "poisson", "rate_rps": self.rate_rps,
                "prompt_tokens": self.prompt_tokens,
                "decode_tokens": self.decode_tokens, "seed": self.seed}


@dataclass(frozen=True)
class TraceArrivals:
    """Trace-driven replay: an explicit, time-sorted tuple of arrivals
    (e.g. a production request log).  ``sample`` clips to the horizon."""

    arrivals: Tuple[Arrival, ...] = field(default_factory=tuple)

    def __post_init__(self) -> None:
        ts = [a.t for a in self.arrivals]
        if ts != sorted(ts):
            object.__setattr__(
                self, "arrivals",
                tuple(sorted(self.arrivals, key=lambda a: (a.t, a.rid))))

    def sample(self, horizon_s: float) -> Tuple[Arrival, ...]:
        return tuple(a for a in self.arrivals if a.t < horizon_s)

    def to_dict(self) -> Dict[str, object]:
        return {"process": "trace",
                "arrivals": [a.to_dict() for a in self.arrivals]}


def arrivals_to_dict(process) -> Dict[str, object]:
    """JSON-serializable form of either arrival process."""
    return process.to_dict()


def arrivals_from_dict(d: Mapping[str, object]):
    """Inverse of :func:`arrivals_to_dict`."""
    kind = d.get("process")
    if kind == "poisson":
        return PoissonArrivals(rate_rps=float(d["rate_rps"]),
                               prompt_tokens=int(d["prompt_tokens"]),
                               decode_tokens=int(d["decode_tokens"]),
                               seed=int(d["seed"]))
    if kind == "trace":
        return TraceArrivals(tuple(Arrival.from_dict(a)
                                   for a in d["arrivals"]))
    raise ValueError(f"unknown arrival process {kind!r}; "
                     f"expected 'poisson' or 'trace'")


def offered_load(arrivals: Sequence[Arrival], horizon_s: float) -> float:
    """Offered load in requests/second over the horizon — the ceiling no
    goodput number can exceed."""
    if horizon_s <= 0:
        return 0.0
    return len(arrivals) / horizon_s


def demand_series(arrivals: Sequence[Arrival], horizon_s: float,
                  window_s: float) -> Dict[str, Tuple[float, ...]]:
    """Per-phase token demand over time: windowed sums of prefill tokens
    and decode tokens.  Returns ``{"t": window starts, "prefill": ...,
    "decode": ...}`` — the open-loop demand profile a co-tenant planner
    lays against a training job's periodic comm phases."""
    if window_s <= 0:
        raise ValueError(f"window_s must be > 0, got {window_s}")
    n = max(1, int(math.ceil(horizon_s / window_s)))
    prefill = [0.0] * n
    decode = [0.0] * n
    for a in arrivals:
        if not (0.0 <= a.t < horizon_s):
            continue
        i = min(int(a.t / window_s), n - 1)
        prefill[i] += a.prompt_tokens
        decode[i] += a.decode_tokens
    return {"t": tuple(i * window_s for i in range(n)),
            "prefill": tuple(prefill), "decode": tuple(decode)}
