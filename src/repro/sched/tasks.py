"""Task scheduler — "Vertical" co-design (paper Sec. IV-A, Fig. 5a).

Discrete-event simulation of one training iteration: a compute resource
(the accelerator) and a communication resource (the network) execute a
dependency DAG of ComputeTask/CommTask.  The scheduler policy decides which
ready comm task transmits next; the objective is JCT, not per-flow FCT.

Policies:
  * serial    — no overlap: every comm task runs with compute idle (the
                no-overlap strawman; exposes ALL communication)
  * fifo      — comm overlaps compute, network served in arrival order
  * priority  — Lina-style: blocking collectives (e.g. MoE All-to-All on
                the critical path) preempt gradient All-Reduce
  * slack     — Echelon-style: least-slack-first (slack = how long until
                the dependent compute stalls)

Reports JCT and *exposed communication* (comm time the compute resource
spends stalled) — the survey's central metric.  Exposure is accounted
per dependency edge: every stall is attributed to the comm task the
compute resource actually waited on (``SimResult.task_exposed_s``), so
hot-task attribution no longer has to be inferred from the timeline.

The demand side can hand this scheduler a *pipelined bucket DAG*
(``build_demand(bucket_bytes=...)``): gradient buckets chain off the
backward layer that filled them, so bucket i's sync starts when layer
i's backward retires rather than when the whole backward ends.  That
makes the classic bucket-size tradeoff (MG-WFBP / ByteScheduler; Shi et
al., arXiv 2005.13247) visible to the simulator — larger buckets
amortize the per-step alpha, smaller buckets become ready earlier and
hide deeper under the remaining backward compute.  Decomposed TP
collectives (``decompose_demand``) show up here as chains of "permute"
tasks riding under split partial matmuls, the collective-matmul
overlap pattern.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Literal, Optional, Tuple

from repro.core.demand import CommDemand, CommTask, ComputeTask

Policy = Literal["serial", "fifo", "priority", "slack", "preempt"]

# Lina-style: blocking collectives (MoE All-to-All, pipeline p2p, TP
# All-Reduce, decomposed-collective permute steps) before the hideable
# gradient Reduce-Scatter/All-Gather.
_PRIORITY = {"all_to_all": 0, "p2p": 1, "permute": 1, "all_reduce": 2,
             "broadcast": 2, "all_gather": 3, "reduce_scatter": 3}


@dataclass
class SimResult:
    jct: float
    compute_time: float
    comm_time: float
    exposed_comm: float
    # the iteration's executed schedule: ``("comp:<id>" | "comm:<id>",
    # start_s, end_s)`` per run segment.  Compute entries tile the
    # accelerator resource, comm entries the (single) network resource;
    # within each resource the spans never overlap (preempted transfers
    # are split into one span per segment).
    timeline: List[Tuple[str, float, float]] = field(default_factory=list)
    # per-task answers from the CCL layer, recorded when ``comm_cost``
    # returns (seconds, algorithm) pairs (the codesign driver does)
    algo_choices: Dict[str, str] = field(default_factory=dict)
    task_comm_s: Dict[str, float] = field(default_factory=dict)
    # per-task exposure attribution: seconds the compute resource spent
    # stalled waiting on each comm task (sums to ``exposed_comm``)
    task_exposed_s: Dict[str, float] = field(default_factory=dict)

    @property
    def comm_fraction(self) -> float:
        return self.exposed_comm / self.jct if self.jct else 0.0

    def to_trace(self, label: str = "iteration"):
        """This schedule as a Perfetto-loadable ``repro.obs.trace.Trace``
        (compute / comm / exposed-comm tracks)."""
        from repro.obs.trace import Trace, timeline_tracks
        tr = Trace()
        timeline_tracks(tr, pid=1, label=label, timeline=self.timeline,
                        task_exposed_s=self.task_exposed_s)
        return tr


def _pick(policy: Policy, ready: List[CommTask], arrival: Dict[str, int]
          ) -> CommTask:
    if policy in ("serial", "fifo"):
        return min(ready, key=lambda t: arrival[t.task_id])
    if policy in ("priority", "preempt"):
        return min(ready, key=lambda t: (_PRIORITY.get(t.primitive, 9),
                                         arrival[t.task_id]))
    return min(ready, key=lambda t: (t.slack, arrival[t.task_id]))  # slack


def simulate_iteration(demand: CommDemand,
                       comm_cost: Callable[[CommTask], object],
                       policy: Policy = "priority") -> SimResult:
    """Simulate one iteration.  ``comm_cost`` maps a CommTask to seconds —
    the CCL+network layers' answer, i.e. the cross-layer information
    exchange arrow of the five-layer paradigm.  It may instead return a
    ``(seconds, algorithm_name)`` pair; the chosen algorithm is then
    recorded in ``SimResult.algo_choices`` for the codesign report."""
    comm_tasks = list(demand.comm_tasks)
    arrival = {t.task_id: i for i, t in enumerate(comm_tasks)}
    blockers: Dict[str, List[str]] = {}
    for t in comm_tasks:
        if t.before_compute:
            blockers.setdefault(t.before_compute, []).append(t.task_id)

    done_compute: Dict[str, float] = {}  # task_id -> finish time
    done_comm: set = set()
    running: Optional[Tuple[float, CommTask]] = None  # (finish, task)
    run_start = 0.0
    dur_left: Dict[str, float] = {}  # remaining seconds (preemption)
    t_compute = 0.0  # compute resource frontier
    t_net = 0.0      # network resource frontier
    exposed = 0.0
    comm_total = 0.0
    timeline: List[Tuple[str, float, float]] = []
    algo_choices: Dict[str, str] = {}
    task_comm_s: Dict[str, float] = {}
    task_exposed_s: Dict[str, float] = {t.task_id: 0.0 for t in comm_tasks}

    def ready_comms() -> List[CommTask]:
        return [t for t in comm_tasks
                if t.task_id not in done_comm
                and (running is None or running[1].task_id != t.task_id)
                and all(c in done_compute for c in t.after_compute)]

    def start_next_comm():
        nonlocal running, run_start, t_net, comm_total
        if running is not None:
            return
        ready = ready_comms()
        if not ready:
            return
        task = _pick(policy, ready, arrival)
        if task.task_id not in dur_left:
            priced = comm_cost(task)
            if isinstance(priced, tuple):
                dur, algo = priced
                algo_choices[task.task_id] = algo
            else:
                dur = priced
            dur_left[task.task_id] = dur
            task_comm_s[task.task_id] = dur
            comm_total += dur
        dur = dur_left[task.task_id]
        ready_at = max((done_compute[c] for c in task.after_compute),
                       default=0.0)
        start = max(t_net, ready_at)
        running = (start + dur, task)
        run_start = start
        t_net = start + dur
        timeline.append((f"comm:{task.task_id}", start, start + dur))

    def preempt_running(at: float):
        """Pause the running comm at time ``at`` (Lina-style preemption);
        its remainder is requeued."""
        nonlocal running, t_net
        fin, task = running
        elapsed = max(0.0, at - run_start)
        dur_left[task.task_id] = max(0.0, (fin - run_start) - elapsed)
        # the span appended at start covered the full duration; cut it to
        # what actually ran (the remainder gets its own span on resume) so
        # the timeline never holds two concurrent spans on the one network
        # resource
        name = f"comm:{task.task_id}"
        for j in range(len(timeline) - 1, -1, -1):
            if timeline[j][0] == name:
                if elapsed > 0.0:
                    timeline[j] = (name, run_start, run_start + elapsed)
                else:
                    del timeline[j]
                break
        t_net = at
        running = None

    def finish_running():
        nonlocal running
        if running is not None:
            done_comm.add(running[1].task_id)
            running = None

    def wait_for_running():
        """Stall compute until the in-flight comm finishes; the stall is
        exposure, attributed to the task that was on the wire."""
        nonlocal t_compute, exposed
        fin, task = running
        if fin > t_compute:
            exposed += fin - t_compute
            task_exposed_s[task.task_id] += fin - t_compute
            t_compute = fin
        finish_running()

    i = 0
    compute_list = list(demand.compute_tasks)
    guard = 0
    while i < len(compute_list) or len(done_comm) < len(comm_tasks):
        guard += 1
        if guard > 100 * (len(compute_list) + len(comm_tasks) + 1):
            raise RuntimeError("scheduler livelock")
        start_next_comm()
        if i < len(compute_list):
            ct = compute_list[i]
            waiting = [b for b in blockers.get(ct.task_id, [])
                       if b not in done_comm]
            if waiting:
                # must wait for comm -> advance time to the running finish
                if running is not None and running[1].task_id in waiting:
                    wait_for_running()
                elif running is not None:
                    if policy == "preempt" and t_compute < running[0]:
                        # pause the non-blocking transfer, let the blocker in
                        preempt_running(max(t_compute, run_start))
                        continue
                    # some other comm on the wire; let it finish first
                    wait_for_running()
                else:
                    continue  # blocker will be started next loop
                continue
            if policy == "serial" and running is not None:
                wait_for_running()
                continue
            # run compute
            timeline.append((f"comp:{ct.task_id}", t_compute,
                             t_compute + ct.duration))
            t_compute += ct.duration
            done_compute[ct.task_id] = t_compute
            i += 1
            # retire comm finished in the background
            if running is not None and running[0] <= t_compute:
                finish_running()
            continue
        # only comm left
        if running is not None:
            wait_for_running()
        elif not ready_comms():
            break

    jct = max(t_compute, t_net)
    compute_time = sum(c.duration for c in demand.compute_tasks)
    return SimResult(jct=jct, compute_time=compute_time,
                     comm_time=comm_total, exposed_comm=exposed,
                     timeline=timeline, algo_choices=algo_choices,
                     task_comm_s=task_comm_s,
                     task_exposed_s=task_exposed_s)
