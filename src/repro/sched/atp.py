"""In-network aggregation — "Host-Net" co-design (paper Sec. IV-B, ATP [15]).

On a fat-tree with programmable ToR/Agg switches, gradient flows from
workers under the same switch can be summed in-network: upstream of the
switch only one aggregated flow continues, reducing core-layer traffic.
No TPU/ICI analogue exists (DESIGN.md hardware-adaptation note) — this is
a network-layer model used by the benchmark reproducing ATP's traffic
reduction, including the multi-tenant fallback (switch memory exhausted ->
degrade to host aggregation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Set, Tuple

from repro.core.demand import CommTask, Flow, FlowSet
from repro.net.topology import Topology
from repro.net.simulate import link_utilization, simulate_flowset


def aggregation_switches(topo: Topology, group: Sequence[int],
                         capacity: Optional[int] = None) -> Set:
    """The switches able to aggregate a group's gradient flows in-network.

    ``capacity``: max concurrent aggregations a switch supports (None =
    unlimited).  A group larger than the capacity exhausts switch memory
    and gets the empty set — the multi-tenant degradation to host
    aggregation that ATP prices in.  This is the "Host-Net" hook the CCL
    selection layer (``ccl.select.FlowSim``) consults when pricing the
    ``atp`` all-reduce candidate."""
    if capacity is not None and len(group) > capacity:
        return set()
    return set(topo.switch_nodes())


def host_aggregation_flows(task: CommTask, ps_node) -> FlowSet:
    """Baseline: every worker sends its gradient to a parameter-server node
    (host aggregation), PS broadcasts back."""
    fs = FlowSet(task_id=task.task_id, algorithm="ps_host")
    for w in task.group:
        fs.flows.append(Flow(w, ps_node, task.size_bytes, task.task_id, 0,
                             task.job_id))
    for w in task.group:
        fs.flows.append(Flow(ps_node, w, task.size_bytes, task.task_id, 1,
                             task.job_id))
    fs.num_steps = 2
    return fs


def atp_traffic(topo: Topology, task: CommTask, ps_node,
                switch_capacity: Optional[int] = None
                ) -> Dict[str, float]:
    """Compare PS traffic with vs. without in-network aggregation.

    ``switch_capacity``: max concurrent aggregations a switch supports
    (None = unlimited); beyond it, flows fall back to host aggregation —
    ATP's multi-tenant degradation."""
    fs = host_aggregation_flows(task, ps_node)
    base_bytes = sum(link_utilization(topo, fs).values())
    base_time = simulate_flowset(topo, fs)

    agg_at = aggregation_switches(topo, task.group, switch_capacity)
    agg_time = simulate_flowset(topo, fs, aggregate_at=agg_at)

    # aggregated byte count: recount with merge semantics
    from repro.net.simulate import _route_bytes  # noqa: PLC0415
    agg_bytes = sum(_route_bytes(topo, fs.flows, agg_at).values())
    return {
        "base_bytes": base_bytes, "agg_bytes": agg_bytes,
        "base_time": base_time, "agg_time": agg_time,
        "traffic_reduction": base_bytes / max(agg_bytes, 1.0),
        "speedup": base_time / max(agg_time, 1e-12),
    }
