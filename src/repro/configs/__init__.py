"""Assigned architecture configs (10) + input shapes + registry."""
from repro.configs.registry import ARCHS, get_config, list_archs, smoke_config  # noqa: F401
