"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attention="gqa",
    attn_period=8,        # 1 attn : 7 mamba -> 9 attn layers out of 72
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=128,     # d_inner=16384 -> 128 SSD heads
    ssm_conv_kernel=4,
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_layer_period=2,   # MoE every other layer, as in Jamba
    ffn_act="swiglu",
)
