"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA [arXiv:2401.16818]."""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    attention="gqa",
    sliding_window=4096,  # mistral-style SWA -> long_500k is native
    ffn_act="swiglu",
)
