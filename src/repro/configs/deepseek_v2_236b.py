"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]."""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: per-head KV decompressed from the latent
    head_dim=128,       # qk_nope head dim
    d_ff=12288,         # dense FFN (first layer only, as in the paper)
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    moe_first_dense=1,
    ffn_act="swiglu",
)
