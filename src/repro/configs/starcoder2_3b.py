"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173]."""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    attention="gqa",
    qkv_bias=True,
    ffn_act="gelu",
    rope_theta=100_000.0,
)
