"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,            # attention-free, no separate FFN (Mamba2 block only)
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,   # d_inner=1536 -> 24 SSD heads
    ssm_conv_kernel=4,
    tie_embeddings=True,
)
