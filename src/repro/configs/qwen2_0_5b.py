"""qwen2-0.5b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    attention="gqa",
    qkv_bias=True,
    ffn_act="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
