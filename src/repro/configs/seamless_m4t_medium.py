"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

Backbone only: the mel-spectrogram + conv feature extractor frontend is a
stub; ``input_specs`` provides precomputed frame embeddings (batch, frames,
d_model) for the encoder, and the decoder consumes them via cross-attention.
"""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=12,          # decoder layers
    encoder_layers=12,      # encoder layers (self-attn + dense FFN)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    attention="gqa",
    ffn_act="gelu",
    num_audio_frames=1024,  # stub frontend output length per utterance
)
