"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.core.types import ModelConfig

# arch id -> module name
_MODULES: Dict[str, str] = {
    "granite-3-8b": "granite_3_8b",
    "mamba2-130m": "mamba2_130m",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "dbrx-132b": "dbrx_132b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-0.5b": "qwen2_0_5b",
    "starcoder2-3b": "starcoder2_3b",
}

ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCHS)


def smoke_config(arch: str) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts.

    Used by per-arch CPU smoke tests; the full config is exercised only via
    the dry-run (ShapeDtypeStruct, no allocation).
    """
    cfg = get_config(arch)
    d_model = min(cfg.d_model, 256)
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=1024,
    )
    if cfg.attention != "none":
        heads = min(cfg.num_heads, 4)
        kv = max(1, min(cfg.num_kv_heads, 2))
        updates.update(
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        )
    else:
        updates.update(d_ff=0)
    if cfg.attention == "mla":
        updates.update(kv_lora_rank=64, q_lora_rank=96,
                       qk_rope_head_dim=16, v_head_dim=32)
    if cfg.is_moe:
        updates.update(
            num_experts=4,
            top_k=min(cfg.top_k, 2),
            moe_d_ff=128,
            num_shared_experts=min(cfg.num_shared_experts, 1),
            moe_first_dense=min(cfg.moe_first_dense, 1),
            moe_layer_period=min(cfg.moe_layer_period, 2),
        )
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_period:
        # keep the hybrid character with 2 layers: attn at layer 0, mamba at 1
        updates.update(attn_period=2)
    if cfg.encoder_layers:
        updates.update(encoder_layers=2, num_audio_frames=64)
    if cfg.cross_attn_period:
        updates.update(cross_attn_period=2, num_vision_tokens=16)
    if cfg.sliding_window:
        updates.update(sliding_window=128)
    return dataclasses.replace(cfg, **updates)
