"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

Backbone only: the ViT vision encoder + projector is a stub; ``input_specs``
provides precomputed patch embeddings (batch, patches, d_model).  100 layers
with one cross-attention layer every 5th layer (20 cross-attn + 80 self-attn),
matching the Llama-3.2-Vision interleave ratio.
"""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    attention="gqa",
    cross_attn_period=5,     # layers 4, 9, ... are cross-attention
    num_vision_tokens=1601,  # (448/14)^2 + cls, Llama-3.2 vision tile
    ffn_act="swiglu",
    rope_theta=500_000.0,
)
