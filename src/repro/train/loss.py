"""Cross-entropy over (possibly vocab-sharded) logits.

Uses logsumexp directly on the padded-vocab logits — with the LM head
sharded over the model axis the reduction stays sharded and XLA emits a
small All-Reduce over per-shard partial sums instead of gathering the full
(B, S, V) logits (the "vocab-sharded loss" optimization in §Perf)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -1) -> jax.Array:
    """logits: (B, S, V) (padded vocab already masked with a -inf bias);
    labels: (B, S) int32.  Returns mean NLL over non-ignored tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - true_logit
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
