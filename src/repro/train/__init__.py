from repro.train.loss import cross_entropy  # noqa: F401
from repro.train.step import make_train_step  # noqa: F401
