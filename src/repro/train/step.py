"""Jitted training step: fwd -> loss -> bwd -> clip -> AdamW.

The returned function is pure and pjit-able; the launcher supplies
in/out shardings from the planner.  Gradient synchronization across the
data axes falls out of the sharding propagation: with plain DP specs XLA
emits All-Reduce, with ZeRO-1 specs Reduce-Scatter + All-Gather — the
Para.-layer knob (TrainConfig.grad_sync) the survey describes."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig, TrainConfig
from repro.models.transformer import encode, forward
from repro.optim.adamw import adamw_update
from repro.optim.schedule import lr_schedule
from repro.train.loss import cross_entropy


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    ctx=None) -> Callable:
    nmb = max(1, tcfg.microbatches)

    def loss_fn(p, batch):
        context = batch.get("context")
        if cfg.is_encoder_decoder:
            context = encode(cfg, p, context, ctx=ctx)
        logits, aux = forward(cfg, p, batch["tokens"], context=context,
                              ctx=ctx)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_loss * aux
        return loss, {"ce": ce, "aux": aux}

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params: Any, opt_state: Dict[str, Any],
                   batch: Dict[str, jax.Array]):
        if nmb == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            # gradient accumulation: scan over microbatch slices of the
            # batch dim — live activation memory shrinks by ~nmb (§Perf)
            def split(x):
                b = x.shape[0]
                return x.reshape(nmb, b // nmb, *x.shape[1:])

            mbs = {k: split(v) for k, v in batch.items()}

            def body(acc, mb):
                (loss, metrics), g = grads_of(params, mb)
                g_acc, l_acc, m_acc = acc
                g_acc = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype),
                                     g_acc, g)
                return (g_acc, l_acc + loss,
                        jax.tree.map(lambda a, b_: a + b_, m_acc, metrics)), \
                    None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            init = (g0, jnp.zeros((), jnp.float32),
                    {"ce": jnp.zeros(()), "aux": jnp.zeros(())})
            # dry-run cost mode unrolls so XLA cost analysis counts every
            # microbatch (it visits while bodies once)
            mb_unroll = nmb if (ctx is not None and
                                getattr(ctx, "unroll_layers", False)) else 1
            (grads, loss, metrics), _ = jax.lax.scan(body, init, mbs,
                                                     unroll=mb_unroll)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = loss / nmb
            metrics = jax.tree.map(lambda m: m / nmb, metrics)

        if tcfg.grad_dtype == "bf16":
            # sync-precision cast: halves the DP gradient collective bytes;
            # AdamW re-accumulates in f32
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        lr = lr_schedule(opt_state["step"], tcfg)
        new_params, new_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg, lr)
        metrics = dict(metrics, loss=loss, lr=lr, **opt_metrics)
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, ctx=None) -> Callable:
    def eval_step(params, batch):
        context = batch.get("context")
        if cfg.is_encoder_decoder:
            context = encode(cfg, params, context, ctx=ctx)
        logits, _ = forward(cfg, params, batch["tokens"], context=context,
                            ctx=ctx)
        return cross_entropy(logits, batch["labels"])
    return eval_step
