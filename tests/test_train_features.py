"""Training-step features: microbatch gradient accumulation equivalence,
bf16 gradient sync, LR schedule shape, optimizer invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.core.types import TrainConfig
from repro.models import init_params
from repro.optim.adamw import adamw_update, global_norm, init_opt_state
from repro.optim.schedule import lr_schedule
from repro.train.loss import cross_entropy
from repro.train.step import make_train_step


def _setup(arch="qwen2-0.5b"):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    return cfg, params, batch


def test_microbatch_equivalence():
    """K-way gradient accumulation must produce the same update as the
    monolithic batch (loss is a per-token mean; equal microbatch sizes)."""
    cfg, params, batch = _setup()
    opt = init_opt_state(params)
    outs = {}
    for mb in (1, 2, 4):
        tcfg = TrainConfig(microbatches=mb, remat=False)
        p, o, m = jax.jit(make_train_step(cfg, tcfg))(params, opt, batch)
        outs[mb] = (float(m["loss"]), p)
    assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][1]),
                    jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_bf16_grad_sync_close_to_f32():
    cfg, params, batch = _setup()
    opt = init_opt_state(params)
    p32, _, m32 = jax.jit(make_train_step(
        cfg, TrainConfig(remat=False, grad_dtype="f32")))(params, opt, batch)
    p16, _, m16 = jax.jit(make_train_step(
        cfg, TrainConfig(remat=False, grad_dtype="bf16")))(params, opt, batch)
    assert float(m32["loss"]) == pytest.approx(float(m16["loss"]), rel=1e-5)
    # updates agree to bf16 precision
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p16)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_remat_matches_no_remat():
    cfg, params, batch = _setup("granite-3-8b")
    opt = init_opt_state(params)
    from repro.parallel.planner import ParallelCtx
    p_a, _, m_a = jax.jit(make_train_step(
        cfg, TrainConfig()))(params, opt, batch)
    ctx = ParallelCtx(remat=True)
    p_b, _, m_b = jax.jit(make_train_step(
        cfg, TrainConfig(), ctx))(params, opt, batch)
    assert float(m_a["loss"]) == pytest.approx(float(m_b["loss"]), rel=1e-6)
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), tcfg)) for s in range(100)]
    assert lrs[0] < lrs[9] <= tcfg.learning_rate * 1.001  # warmup rises
    assert max(lrs) <= tcfg.learning_rate * 1.001  # (f32 rounding slack)
    assert lrs[99] < lrs[20]                      # cosine decays
    assert lrs[99] >= 0.09 * tcfg.learning_rate   # floor at 10%


@given(scale=st.floats(0.1, 100.0))
@settings(max_examples=10, deadline=None)
def test_grad_clip_bounds_update(scale):
    """Post-clip effective gradient norm never exceeds grad_clip."""
    cfg, params, batch = _setup()
    tcfg = TrainConfig(grad_clip=1.0, weight_decay=0.0, remat=False)
    grads = jax.tree.map(lambda p: jnp.full(p.shape, scale, jnp.float32),
                         params)
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(params, grads, opt, tcfg,
                                 jnp.asarray(1e-3))
    gnorm = float(metrics["grad_norm"])
    clip_scale = min(1.0, tcfg.grad_clip / gnorm)
    assert gnorm * clip_scale <= tcfg.grad_clip * 1.001


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]]])
    labels = jnp.asarray([[0, 1]])
    got = float(cross_entropy(logits, labels))
    import math
    want = -(math.log(math.exp(2) / (math.exp(2) + 1 + math.exp(-1)))
             + math.log(math.exp(3) / (2 + math.exp(3)))) / 2
    assert got == pytest.approx(want, rel=1e-6)


def test_cross_entropy_ignore_index():
    logits = jnp.zeros((1, 3, 4))
    labels = jnp.asarray([[1, -1, -1]])
    got = float(cross_entropy(logits, labels))
    import math
    assert got == pytest.approx(math.log(4), rel=1e-6)
