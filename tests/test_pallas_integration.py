"""Full-model integration of the Pallas flash-attention kernel: a GQA
model's forward with ``use_pallas=True`` (interpret mode on CPU) must match
the jnp attention path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import forward, init_params
from repro.parallel.planner import ParallelCtx


def test_forward_with_pallas_attention_matches_jnp():
    cfg = dataclasses.replace(smoke_config("granite-3-8b"),
                              sliding_window=None, max_seq_len=256)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 256), 0, cfg.vocab_size)
    ref_logits, _ = forward(cfg, params, tokens)
    ctx = ParallelCtx(use_pallas=True)
    pal_logits, _ = forward(cfg, params, tokens, ctx=ctx)
    np.testing.assert_allclose(np.asarray(pal_logits),
                               np.asarray(ref_logits), atol=5e-4, rtol=1e-3)


def test_pallas_sliding_window_model():
    cfg = dataclasses.replace(smoke_config("h2o-danube-1.8b"),
                              sliding_window=128)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (1, 256), 0, cfg.vocab_size)
    ref_logits, _ = forward(cfg, params, tokens)
    pal_logits, _ = forward(cfg, params, tokens,
                            ctx=ParallelCtx(use_pallas=True))
    np.testing.assert_allclose(np.asarray(pal_logits),
                               np.asarray(ref_logits), atol=5e-4, rtol=1e-3)
