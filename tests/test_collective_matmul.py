"""Collective (decomposed) matmul + executable 2D-torus AR: equivalence
with the bulk-collective forms on a multi-device host platform."""
from helpers import run_multidevice

SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collective_matmul import ag_matmul, matmul_rs
from repro.ccl.primitives import torus2d_all_reduce

P_ = 4
mesh = jax.make_mesh((P_,), ("x",))
key = jax.random.PRNGKey(0)
M, K, N = 8 * P_, 16, 12 * P_
x = jax.random.normal(key, (M, K))
w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.3

# --- ag_matmul: x row-sharded, w col-sharded -> y col-sharded ---
def body_ag(xl, wl):
    return ag_matmul(xl, wl, "x", P_)
y = jax.jit(jax.shard_map(body_ag, mesh=mesh,
                          in_specs=(P("x", None), P(None, "x")),
                          out_specs=P(None, "x")))(x, w)
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4)
print("ag_matmul ok")

# --- matmul_rs: x contraction-sharded, w row-sharded -> y row-sharded ---
K2 = 16 * P_
x2 = jax.random.normal(jax.random.fold_in(key, 2), (M, K2))
w2 = jax.random.normal(jax.random.fold_in(key, 3), (K2, N)) * 0.3
def body_rs(xl, wl):
    return matmul_rs(xl, wl, "x", P_)
y2 = jax.jit(jax.shard_map(body_rs, mesh=mesh,
                           in_specs=(P(None, "x"), P("x", None)),
                           out_specs=P("x", None)))(x2, w2)
np.testing.assert_allclose(np.asarray(y2), np.asarray(x2 @ w2), atol=1e-4)
print("matmul_rs ok")

# --- 2D-torus dimension-ordered all-reduce on a (2,2) mesh ---
mesh2 = jax.make_mesh((2, 2), ("r", "c"))
z = jnp.arange(4 * 10, dtype=jnp.float32).reshape(4, 10)
def body_t(zl):
    return torus2d_all_reduce(zl[0], "r", "c", 2, 2)[None]
got = jax.jit(jax.shard_map(
    body_t, mesh=mesh2, in_specs=P(("r", "c"), None),
    out_specs=P(("r", "c"), None)))(z)
want = jnp.broadcast_to(z.sum(0), (4, 10))
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
print("torus2d ok")
print("OK")
"""


def test_collective_matmul_and_torus_ar():
    run_multidevice(SCRIPT, num_devices=4)
