"""Shared test utilities, incl. running multi-device checks in a
subprocess (the only place the fake-device XLA flag is allowed)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidevice(script: str, num_devices: int = 8,
                    timeout: int = 420) -> str:
    """Run ``script`` in a subprocess with N fake host devices.  The script
    should print 'OK' on success; raises on failure."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{num_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice script failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-3000:]}")
    assert "OK" in proc.stdout, proc.stdout
    return proc.stdout
