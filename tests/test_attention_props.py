"""Attention-path properties: flash==plain, causal-skip==uniform scan,
RoPE norm preservation & relative-position property, MLA absorption."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.models.attention import (_flash_attention_jnp, _group_q,
                                    _plain_attention, mla_forward,
                                    multihead_attention)
from repro.models.modules import apply_rope


def _qkv(key, b, sq, sk, h, kv, d, vd=None):
    vd = vd or d
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kv, vd))
    return q, k, v


@pytest.mark.parametrize("sq,sk,window", [
    (256, 256, None), (128, 384, None), (256, 256, 100), (100, 300, 77),
])
def test_flash_equals_plain(sq, sk, window):
    key = jax.random.PRNGKey(0)
    q, k, v = _qkv(key, 2, sq, sk, 4, 2, 32)
    qg = _group_q(q, 2)
    qp = jnp.arange(sk - sq, sk)  # q positions aligned to the kv suffix
    kp = jnp.arange(sk)
    plain = _plain_attention(qg, k, v, q_pos=qp, k_pos=kp, causal=True,
                             window=window, logit_dtype=jnp.float32)
    flash = _flash_attention_jnp(qg, k, v, q_pos=qp, k_pos=kp, causal=True,
                                 window=window, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(plain),
                               atol=2e-5, rtol=2e-5)


def test_unrolled_flash_equals_scanned():
    """The dry-run cost-mode unrolled flash (python chunk loops) must match
    the scanned production form bit-for-bit-ish."""
    key = jax.random.PRNGKey(7)
    q, k, v = _qkv(key, 1, 4096, 4096, 2, 2, 32)
    qg = _group_q(q, 2)
    pos = jnp.arange(4096)
    a = _flash_attention_jnp(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                             window=None)
    b = _flash_attention_jnp(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                             window=None, unroll=True)
    c = _flash_attention_jnp(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                             window=None, unroll=True, causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_causal_skip_equals_uniform():
    key = jax.random.PRNGKey(1)
    q, k, v = _qkv(key, 1, 512, 512, 2, 2, 32)
    qg = _group_q(q, 2)
    pos = jnp.arange(512)
    a = _flash_attention_jnp(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                             window=None, q_chunk=128, kv_chunk=128,
                             causal_skip=False)
    b = _flash_attention_jnp(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                             window=None, q_chunk=128, kv_chunk=128,
                             causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_mla_head_dims():
    """MLA with distinct qk (192) and v (128) head dims runs through both
    the plain and flash paths."""
    cfg = smoke_config("deepseek-v2-236b")
    from repro.models.attention import init_mla
    key = jax.random.PRNGKey(0)
    p = init_mla(key, cfg, jnp.float32)
    for s in (16, 4096):  # plain path, then flash path
        x = jax.random.normal(key, (1, s, cfg.d_model)) * 0.02
        out = mla_forward(p, cfg, x, jnp.arange(s))
        assert out.shape == (1, s, cfg.d_model)
        assert bool(jnp.isfinite(out).all())
        if s == 4096:
            break  # one flash-path pass is enough (CPU time)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm(pos):
    key = jax.random.PRNGKey(pos)
    x = jax.random.normal(key, (1, 1, 2, 64))
    y = apply_rope(x, jnp.asarray([pos]), 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x)),
                               np.linalg.norm(np.asarray(y)), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))

    def dot_at(m, n):
        qm = apply_rope(q, jnp.asarray([m]), 10_000.0)
        kn = apply_rope(k, jnp.asarray([n]), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
    # f32 cos/sin at position ~1000 carries ~2e-4 relative rounding error
    assert dot_at(17, 0) == pytest.approx(dot_at(1017, 1000), rel=1e-3)


def test_softmax_rows_sum_to_one_under_padding():
    """Ragged KV (vision tokens) padding must not leak probability mass:
    attention output for valid tokens is unchanged by padding amount."""
    key = jax.random.PRNGKey(4)
    q, k, v = _qkv(key, 1, 128, 1601, 4, 4, 32)
    pos_q = jnp.arange(128)
    pos_k = jnp.arange(1601)
    out = multihead_attention(q, k, v, q_pos=pos_q, k_pos=pos_k,
                              causal=False)
    # same computation with KV padded manually to 2048 + masked
    k2 = jnp.pad(k, ((0, 0), (0, 447), (0, 0), (0, 0)))
    v2 = jnp.pad(v, ((0, 0), (0, 447), (0, 0), (0, 0)))
    out2 = multihead_attention(q, k2[:, :1601], v2[:, :1601], q_pos=pos_q,
                               k_pos=pos_k, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)
