"""Executable CCL primitives (shard_map + ppermute) vs jax.lax references,
on 8 fake host devices in a subprocess."""
import pytest

from helpers import run_multidevice

SCRIPT = """
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.ccl.primitives import (ring_all_reduce, bidir_ring_all_reduce,
                                  latency_bound_all_reduce, ring_all_gather,
                                  ring_reduce_scatter)

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 48, dtype=jnp.float32).reshape(8, 48) / 7.0

def check(impl, name):
    def body(xl):
        return impl(xl[0], "x", 8)[None]
    got = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("x", None),
                                out_specs=P("x", None)))(x)
    def ref_body(xl):
        return jax.lax.psum(xl, "x")
    want = jax.jit(jax.shard_map(ref_body, mesh=mesh, in_specs=P("x", None),
                                 out_specs=P("x", None)))(x)
    # psum with in/out specs sharded returns the sum replicated per shard
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    print(name, "ok")

check(ring_all_reduce, "ring")
check(bidir_ring_all_reduce, "bidir_ring")
check(latency_bound_all_reduce, "recursive_doubling")

# all-gather
def ag_body(xl):
    return ring_all_gather(xl[0], "x", 8).reshape(1, -1)
got = jax.jit(jax.shard_map(ag_body, mesh=mesh, in_specs=P("x", None),
                            out_specs=P("x", None)))(x)
want = jnp.broadcast_to(x.reshape(-1), (8, 8 * 48))
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
print("all_gather ok")

# reduce-scatter: rank r gets sum over peers of their r-th chunk
def rs_body(xl):
    return ring_reduce_scatter(xl[0], "x", 8)[None]
y = jnp.arange(8 * 8 * 6, dtype=jnp.float32).reshape(8, 8, 6)
got = jax.jit(jax.shard_map(rs_body, mesh=mesh, in_specs=P("x", None, None),
                            out_specs=P("x", None)))(y)
want = y.sum(axis=0)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
print("reduce_scatter ok")
print("OK")
"""


def test_ccl_primitives_multidevice():
    run_multidevice(SCRIPT, num_devices=8)
