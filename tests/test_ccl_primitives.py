"""Executable CCL primitives (shard_map + ppermute) vs jax.lax references,
on 8 fake host devices in a subprocess (plus inline when the interpreter
itself sees >= 8 devices — the CI multi-device matrix entry)."""
import jax
import numpy as np
import pytest

from helpers import run_multidevice

SCRIPT = """
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.ccl.primitives import (ring_all_reduce, bidir_ring_all_reduce,
                                  compressed_ring_all_reduce,
                                  latency_bound_all_reduce, ring_all_gather,
                                  ring_reduce_scatter)

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 48, dtype=jnp.float32).reshape(8, 48) / 7.0

def psum_ref(x, spec):
    return jax.jit(jax.shard_map(lambda xl: jax.lax.psum(xl, "x"),
                                 mesh=mesh, in_specs=spec,
                                 out_specs=spec))(x)

def check(impl, name):
    def body(xl):
        return impl(xl[0], "x", 8)[None]
    got = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("x", None),
                                out_specs=P("x", None)))(x)
    # psum with in/out specs sharded returns the sum replicated per shard
    want = psum_ref(x, P("x", None))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    print(name, "ok")

check(ring_all_reduce, "ring")
check(bidir_ring_all_reduce, "bidir_ring")
check(latency_bound_all_reduce, "recursive_doubling")

# ---- satellite: bidir ring on odd-length / non-p-divisible payloads ----
# (covers the flat.size // 2 split and the _pad_to trailing-pad path)
for shape in ((1,), (7,), (33,), (50,), (5, 7), (2, 3, 5)):
    for dt, tol in ((jnp.float32, 2e-6), (jnp.bfloat16, 0.06)):
        y = jax.random.normal(jax.random.PRNGKey(sum(shape)),
                              (8, *shape)).astype(dt)
        spec = P("x", *([None] * len(shape)))
        got = jax.jit(jax.shard_map(
            lambda yl: bidir_ring_all_reduce(yl[0], "x", 8)[None],
            mesh=mesh, in_specs=spec, out_specs=spec))(y)
        want = psum_ref(y, spec)
        assert got.dtype == y.dtype, (shape, dt)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=tol)
print("bidir_ring ragged/bf16 ok")

# ---- satellite: all-gather parity on bf16 + ragged sizes vs lax ----
for n in (3, 17, 48):
    for dt in (jnp.float32, jnp.bfloat16):
        y = jax.random.normal(jax.random.PRNGKey(n), (8, n)).astype(dt)
        got = jax.jit(jax.shard_map(
            lambda yl: ring_all_gather(yl[0], "x", 8).reshape(1, -1),
            mesh=mesh, in_specs=P("x", None), out_specs=P("x", None)))(y)
        want = jax.jit(jax.shard_map(
            lambda yl: jax.lax.all_gather(yl[0], "x").reshape(1, -1),
            mesh=mesh, in_specs=P("x", None), out_specs=P("x", None)))(y)
        assert got.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
print("all_gather bf16/ragged ok")

# ---- satellite: reduce-scatter parity on bf16 + ragged sizes ----
# rank r gets sum over peers of their r-th chunk
for n in (6, 5):
    for dt, tol in ((jnp.float32, 2e-6), (jnp.bfloat16, 0.06)):
        y = jax.random.normal(jax.random.PRNGKey(n), (8, 8, n)).astype(dt)
        got = jax.jit(jax.shard_map(
            lambda yl: ring_reduce_scatter(yl[0], "x", 8)[None],
            mesh=mesh, in_specs=P("x", None, None),
            out_specs=P("x", None)))(y)
        want = jax.jit(jax.shard_map(
            lambda yl: jax.lax.psum_scatter(
                yl[0], "x", scatter_dimension=0, tiled=False)[None],
            mesh=mesh, in_specs=P("x", None, None),
            out_specs=P("x", None)))(y)
        assert got.dtype == y.dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=tol)
print("reduce_scatter bf16/ragged ok")

# ---- compressed ring all-reduce matches psum within codec tolerance ----
for bits, steps_factor in ((8, 127.0), (4, 7.0)):
    for shape in ((48,), (37,)):
        y = jax.random.normal(jax.random.PRNGKey(bits), (8, *shape))
        got = jax.jit(jax.shard_map(
            lambda yl: compressed_ring_all_reduce(yl[0], "x", 8,
                                                  bits=bits)[None],
            mesh=mesh, in_specs=P("x", None), out_specs=P("x", None)))(y)
        want = psum_ref(y, P("x", None))
        # each of the p-1 accumulate hops re-quantizes: p * absmax / qmax
        bound = 8 * float(jnp.abs(y).max()) / steps_factor
        err = np.abs(np.asarray(got) - np.asarray(want)).max()
        assert err <= bound, (bits, shape, err, bound)
        # all ranks must hold the identical dequantized result
        np.testing.assert_array_equal(np.asarray(got)[0],
                                      np.asarray(got)[5])
print("compressed_ring ok")
print("OK")
"""


def test_ccl_primitives_multidevice():
    run_multidevice(SCRIPT, num_devices=8)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs >= 8 devices in-process (the CI "
                           "multi-device matrix entry provides them)")
def test_compressed_ring_inline_multidevice():
    """The compressed ring as it would run in production: no subprocess,
    the interpreter's own devices (CI runs the suite once with
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.ccl.primitives import compressed_ring_all_reduce

    mesh = jax.make_mesh((8,), ("x",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    got = jax.jit(jax.shard_map(
        lambda xl: compressed_ring_all_reduce(xl[0], "x", 8)[None],
        mesh=mesh, in_specs=P("x", None), out_specs=P("x", None)))(x)
    want = x.sum(axis=0)
    bound = 8 * float(jnp.abs(x).max()) / 127.0
    assert np.abs(np.asarray(got) - np.asarray(want)).max() <= bound
