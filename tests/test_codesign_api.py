"""Declarative CodesignProblem API: typed knobs, plan()/search(), the
plan_iteration/plan_cluster adapters, placement search, and JSON
round-trips (ISSUE 4)."""
import dataclasses
import inspect
import json
import os
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# the canonical placement-search scenario lives next to the benchmark
# harness so CI smoke assertions, recorded numbers and this suite agree
from benchmarks.paper_claims import _placement_search_problem

from repro.ccl.select import FlowSim, select_for_task
from repro.codesign import (Candidate, Choice, CodesignProblem, CodesignReport,
                            Fixed, JobSpec, Objective, Placement, PlanSpace,
                            Search, SearchResult, balanced_placement,
                            heuristic_placements, plan, plan_cluster,
                            plan_iteration, search, swap_neighbors)
from repro.codesign.placement_search import axis_permuted_placement
from repro.configs import get_config
from repro.core.demand import CommTask
from repro.core.demand_builder import DemandParams
from repro.core.types import MeshConfig, SHAPES_BY_NAME
from repro.net.topology import dgx_cluster, fat_tree

SHAPE = SHAPES_BY_NAME["train_4k"]
DP2_TP8 = MeshConfig(shape=(2, 8), axis_names=("data", "model"))
DP16 = MeshConfig(shape=(16,), axis_names=("data",), data_axes=("data",),
                  model_axes=())
CFG = get_config("qwen2-0.5b")


# ---------------------------------------------------------------------------
# knob types
# ---------------------------------------------------------------------------


def test_knob_types_basics():
    assert Fixed(3) == Fixed(3) and Fixed(3) != Fixed(4)
    # equal knobs must hash equal even for dict values (insertion order)
    a = Fixed({"all_reduce": 0.01, "all_gather": 0.02})
    b = Fixed({"all_gather": 0.02, "all_reduce": 0.01})
    assert a == b and hash(a) == hash(b)
    assert Choice("a", "b").options == ("a", "b")
    assert Choice("a", "b") == Choice("a", "b") != Choice("b", "a")
    assert Search() == Search() and Search(seeds=("x",)) != Search()
    with pytest.raises(ValueError):
        Choice()
    with pytest.raises(AttributeError):
        Fixed(1).value = 2
    space = PlanSpace(placement=Choice("packed", "strided"))
    assert list(space.free_knobs()) == ["placement"]
    assert PlanSpace().free_knobs() == {}
    with pytest.raises(ValueError):
        PlanSpace().pinned(nonsense=1)
    # pinned() takes Knob instances as-is (re-opening a knob), so a free
    # knob fails fast in plan() with the use-search() message instead of
    # surfacing as Fixed(Search()) deep inside placement resolution
    reopened = PlanSpace().pinned(placement=Search())
    assert list(reopened.free_knobs()) == ["placement"]
    assert PlanSpace().pinned(placement="strided").placement == \
        Fixed("strided")


def test_plan_requires_every_scalar_knob_fixed():
    topo = dgx_cluster(2)
    problem = CodesignProblem(CFG, SHAPE, DP2_TP8, topo,
                              space=PlanSpace(policy=Choice("serial",
                                                            "priority")))
    assert not problem.is_fully_specified()
    with pytest.raises(ValueError, match="search"):
        plan(problem)
    assert problem.pinned(policy="serial").is_fully_specified()


def test_objective_validation_and_key():
    with pytest.raises(ValueError):
        Objective(minimize="latency")
    topo = dgx_cluster(2)
    rep = plan(CodesignProblem(CFG, SHAPE, DP2_TP8, topo))
    obj = Objective()
    assert obj.key(rep) == (rep.jct, rep.exposed_comm, rep.worst_link_bytes)
    assert obj.feasible(rep)
    tight = Objective(max_worst_link_bytes=1.0)
    assert not tight.feasible(rep)
    # wire_bytes_saved is bigger-is-better: the minimization key negates
    # it so naming it always rewards saving more bytes
    saver = Objective(minimize="jct", tie_break=("wire_bytes_saved",))
    assert saver.key(rep) == (rep.jct, -rep.wire_bytes_saved)


# ---------------------------------------------------------------------------
# adapter equivalence: plan_iteration(**kw) == plan(from_kwargs(**kw))
# ---------------------------------------------------------------------------


def _reports_equal(a, b):
    assert a.jct == b.jct and a.comm_time == b.comm_time
    assert a.exposed_comm == b.exposed_comm
    assert a.policy == b.policy and a.cost_model == b.cost_model
    assert a.placement.devices == b.placement.devices
    assert [(c.task_id, c.algorithm, c.cost_s, c.codec) for c in a.choices] \
        == [(c.task_id, c.algorithm, c.cost_s, c.codec) for c in b.choices]
    assert a.link_hotspots == b.link_hotspots
    assert a.error_budget == b.error_budget
    assert a.wire_bytes_saved == b.wire_bytes_saved


@settings(max_examples=10)
@given(policy=st.sampled_from(["serial", "priority"]),
       placement=st.sampled_from(["packed", "strided"]),
       cost_model=st.sampled_from(["flowsim", "alphabeta"]),
       error_budget=st.sampled_from([0.0, 0.01]),
       force_ring=st.booleans(),
       zero1=st.booleans())
def test_plan_iteration_is_an_exact_adapter(policy, placement, cost_model,
                                            error_budget, force_ring, zero1):
    """Property: for sampled kwarg combinations the legacy entry point and
    the declarative problem produce identical reports."""
    topo = dgx_cluster(2)
    kw = dict(policy=policy, placement=placement, cost_model=cost_model,
              dp_params=DemandParams(zero1=zero1),
              force={"all_reduce": "ring"} if force_ring else None,
              error_budget=error_budget)
    legacy = plan_iteration(CFG, SHAPE, DP2_TP8, topo, **kw)
    declarative = plan(CodesignProblem.from_kwargs(CFG, SHAPE, DP2_TP8,
                                                   topo, **kw))
    _reports_equal(legacy, declarative)


def test_from_kwargs_allow_maps_to_wildcard_knob():
    """A multi-name allow is a Choice whitelist; a single name is a Fixed
    force — both must reproduce the legacy selection results."""
    topo = dgx_cluster(2)
    for allow in (("ring", "tree"), ("ring",)):
        legacy = plan_iteration(CFG, SHAPE, DP16, topo, allow=allow,
                                dp_params=DemandParams(zero1=False))
        prob = CodesignProblem.from_kwargs(
            CFG, SHAPE, DP16, topo, allow=allow,
            dp_params=DemandParams(zero1=False))
        knob = prob.space.algorithm["*"]
        assert isinstance(knob, Fixed if len(allow) == 1 else Choice)
        _reports_equal(legacy, plan(prob))


def test_empty_allow_still_means_full_registry():
    """Legacy edge: allow=() always behaved like allow=None — the adapter
    must not turn it into an empty (invalid) whitelist."""
    topo = dgx_cluster(2)
    _reports_equal(plan_iteration(CFG, SHAPE, DP2_TP8, topo, allow=()),
                   plan_iteration(CFG, SHAPE, DP2_TP8, topo))
    prob = CodesignProblem.from_kwargs(CFG, SHAPE, DP2_TP8, topo, allow=())
    assert "*" not in prob.space.algorithm


def test_plan_iteration_mutable_default_fixed():
    """The shared-instance hazard: dp_params must default to None (fresh
    DemandParams constructed inside), not a module-level instance."""
    for fn, param in ((plan_iteration, "dp_params"),
                      (CodesignProblem.from_kwargs, "dp_params")):
        assert inspect.signature(fn).parameters[param].default is None
    assert JobSpec.__dataclass_fields__["dp_params"].default is None
    # None behaves exactly like an explicit default DemandParams()
    topo = dgx_cluster(2)
    _reports_equal(plan_iteration(CFG, SHAPE, DP2_TP8, topo),
                   plan_iteration(CFG, SHAPE, DP2_TP8, topo,
                                  dp_params=DemandParams()))


# ---------------------------------------------------------------------------
# selection reads knob constraints
# ---------------------------------------------------------------------------


def test_select_for_task_constraint_knobs():
    topo = dgx_cluster(2)
    model = FlowSim(topo)
    task = CommTask("t", "all_reduce", 2 ** 24, tuple(topo.accelerators))
    open_sel = select_for_task(task, model, constraint=Search())
    assert open_sel.algorithm == select_for_task(task, model).algorithm
    forced = select_for_task(task, model, constraint=Fixed("ring"))
    assert forced.algorithm == "ring" and list(forced.costs) == ["ring"]
    assert forced.algorithm == \
        select_for_task(task, model, allow=("ring",)).algorithm
    pair = select_for_task(task, model, constraint=Choice("ring", "tree"))
    assert set(pair.costs) == {"ring", "tree"}
    # a Fixed compressed name bypasses the error budget (a force is an
    # explicit accuracy decision); a Choice whitelist must not
    q8 = select_for_task(task, model, constraint=Fixed("ring+q8"))
    assert q8.algorithm == "ring+q8"
    gated = select_for_task(task, model, constraint=Choice("ring",
                                                           "ring+q8"))
    assert gated.algorithm == "ring" and "ring+q8" in gated.excluded
    with pytest.raises(ValueError):
        select_for_task(task, model, allow=("ring",),
                        constraint=Fixed("ring"))


# ---------------------------------------------------------------------------
# placement search: generators + the acceptance-criterion win
# ---------------------------------------------------------------------------


def test_balanced_placement_splits_blocks_evenly():
    problem = _placement_search_problem()
    pl = balanced_placement(problem.mesh, problem.topo)
    # every TP-12 block lands 6+6 on two hosts — the equal partition the
    # hierarchical decomposition needs (packed lands 8+4)
    for g in pl.model_groups():
        sizes = [len(h) for h in problem.topo.host_groups(g)]
        assert sizes == [6, 6]
    packed = problem.topo.host_groups(
        tuple(problem.topo.accelerators[:12]))
    assert [len(h) for h in packed] == [8, 4]
    # pure-DP meshes and hostless fabrics yield no balanced candidate
    assert balanced_placement(DP16, dgx_cluster(2)) is None


def test_balanced_placement_handles_model_outer_meshes():
    """The balanced split targets the mesh's actual model-axis
    communicators, not consecutive rank blocks — a model-outermost mesh
    must still land every TP-12 group 6+6 on two hosts."""
    problem = _placement_search_problem()
    outer = MeshConfig(shape=(12, 2), axis_names=("model", "data"))
    pl = balanced_placement(outer, problem.topo)
    for g in pl.model_groups():
        assert [len(h) for h in problem.topo.host_groups(g)] == [6, 6]
    assert len(set(pl.devices)) == outer.num_devices


def test_balanced_placement_backfills_uneven_hosts():
    """Hosts with free slots [8, 4] and a TP-12 block: an even 6+6 split
    is infeasible, so the share sizing must backfill the larger host
    (8+4) instead of bailing."""
    base = fat_tree(num_hosts=2, gpus_per_host=8)
    topo = dataclasses.replace(base, accelerators=base.accelerators[:12],
                               hosts=(base.hosts[0], base.hosts[1][:4]))
    mesh = MeshConfig(shape=(1, 12), axis_names=("data", "model"))
    pl = balanced_placement(mesh, topo)
    assert pl is not None
    assert [len(h) for h in topo.host_groups(pl.model_groups()[0])] == [8, 4]
    assert sorted(pl.devices) == list(topo.accelerators)


def test_heuristic_placements_are_deduped_and_packed_first():
    problem = _placement_search_problem()
    cands = heuristic_placements(problem.mesh, problem.topo)
    assert cands[0].strategy == "packed"
    assert "balanced" in {c.strategy for c in cands}
    devsets = [c.devices for c in cands]
    assert len(devsets) == len(set(devsets))
    for c in cands:  # all are valid bijections onto real accelerators
        assert len(set(c.devices)) == len(c.devices)
        assert set(c.devices) <= set(problem.topo.accelerators)


def test_axis_permuted_placement_is_a_bijection():
    topo = dgx_cluster(2)
    pl = axis_permuted_placement(DP2_TP8, topo, (1, 0))
    assert sorted(pl.devices) == list(range(16))
    assert pl.devices != tuple(range(16))  # actually permuted


def test_swap_neighbors_deterministic_and_valid():
    topo = dgx_cluster(2)
    pl = Placement(mesh=DP2_TP8, devices=tuple(range(16)),
                   strategy="packed", topology=topo.name)
    n1 = [p.devices for _, p in zip(range(20), swap_neighbors(pl, topo))]
    n2 = [p.devices for _, p in zip(range(20), swap_neighbors(pl, topo))]
    assert n1 == n2
    for devs in n1:
        assert len(set(devs)) == 16 and devs != pl.devices


def test_search_placement_beats_packed_on_oversubscribed_fat_tree():
    """Acceptance: search() over the placement knob finds a Placement with
    strictly lower FlowSim JCT than packed, and attributes the win."""
    problem = _placement_search_problem()
    assert problem.topo.name.startswith("fattree")
    res = search(problem, budget=12)
    packed = plan(problem.pinned(placement="packed"))
    assert res.best.jct < packed.jct - 1e-9
    assert res.best.placement.strategy == "balanced"
    assert res.best.cost_model == "flowsim"
    # the win is the hierarchical unlock, and attribution prices it
    assert "hierarchical" in res.best.algorithms_by_primitive()["all_reduce"]
    assert res.attribution["placement"] == \
        pytest.approx(packed.jct - res.best.jct)
    # the frontier contains the packed baseline, ranked behind the winner
    strategies = [c.assignment["placement"].strategy for c in res.frontier]
    assert "packed" in strategies
    assert res.frontier[0].jct == res.best.jct


def test_search_is_deterministic():
    problem = _placement_search_problem()
    r1 = search(problem, budget=10)
    r2 = search(problem, budget=10)
    assert r1.best.placement.devices == r2.best.placement.devices
    assert r1.best.jct == r2.best.jct
    assert r1.attribution == r2.attribution
    assert [c.jct for c in r1.frontier] == [c.jct for c in r2.frontier]
    assert [c.assignment["placement"].devices for c in r1.frontier] == \
        [c.assignment["placement"].devices for c in r2.frontier]


def test_search_budget_caps_evaluations():
    problem = _placement_search_problem()
    res = search(problem, budget=1)
    assert res.evaluated == 1 and res.truncated
    assert res.best.placement.strategy == "packed"  # first candidate
    with pytest.raises(ValueError):
        search(problem, budget=0)
    # budget exactly covering the heuristic sweep still reports truncated:
    # the swap-neighborhood refinement never got to run
    n_heuristics = len(heuristic_placements(problem.mesh, problem.topo))
    exact = search(problem, budget=n_heuristics)
    assert exact.evaluated == n_heuristics and exact.truncated
    # only the winning candidate keeps its full report alive
    assert exact.frontier[0].report is exact.best
    assert all(c.report is None for c in exact.frontier[1:])


def test_search_enumerates_choice_knobs_with_attribution():
    topo = dgx_cluster(2)
    problem = CodesignProblem(
        CFG, SHAPE, DP2_TP8, topo,
        space=PlanSpace(placement=Choice("strided", "packed"),
                        policy=Choice("serial", "priority")))
    res = search(problem, budget=8)
    assert res.evaluated == 4 and not res.truncated
    assert res.best_assignment["placement"] == "packed"
    # attribution reverts each knob to its declared baseline (first option)
    reverted = plan(problem.pinned(placement="strided",
                                   policy=res.best_assignment["policy"]))
    assert res.attribution["placement"] == \
        pytest.approx(reverted.jct - res.best.jct)
    assert set(res.attribution) == {"placement", "policy"}


def test_search_without_free_knobs_prices_single_point():
    topo = dgx_cluster(2)
    problem = CodesignProblem(CFG, SHAPE, DP2_TP8, topo)
    res = search(problem, budget=4)
    assert res.evaluated == 1 and not res.truncated
    _reports_equal(res.best, plan(problem))
    assert res.attribution == {}


def test_search_infeasible_objective_raises():
    topo = dgx_cluster(2)
    problem = CodesignProblem(
        CFG, SHAPE, DP2_TP8, topo,
        space=PlanSpace(placement=Choice("packed", "strided")),
        objective=Objective(max_worst_link_bytes=1.0))
    with pytest.raises(ValueError, match="feasible"):
        search(problem, budget=4)


def test_search_rejects_open_non_placement_knobs():
    topo = dgx_cluster(2)
    problem = CodesignProblem(CFG, SHAPE, DP2_TP8, topo,
                              space=PlanSpace(policy=Search()))
    with pytest.raises(ValueError, match="placement"):
        search(problem, budget=4)


# ---------------------------------------------------------------------------
# JSON serialization round-trips
# ---------------------------------------------------------------------------


def test_codesign_report_round_trips_through_json():
    topo = dgx_cluster(2)
    rep = plan_iteration(CFG, SHAPE, DP2_TP8, topo,
                         error_budget={"all_reduce": 0.01})
    d = json.loads(json.dumps(rep.to_dict()))
    back = CodesignReport.from_dict(d)
    assert back.to_dict() == rep.to_dict()
    # placement comes back as a real Placement (device list + mesh) and
    # hotspots as hottest-first link tuples with string keys en route
    assert back.placement.devices == rep.placement.devices
    assert back.placement.mesh == rep.placement.mesh
    assert back.link_hotspots == rep.link_hotspots
    assert back.algorithms_by_primitive() == rep.algorithms_by_primitive()
    assert back.codecs_by_primitive() == rep.codecs_by_primitive()
    assert back.worst_link_bytes == rep.worst_link_bytes
    assert back.error_budget == {"all_reduce": 0.01}
    assert all("->" in k for k in d["link_hotspots"])
    assert back.sim is None  # the live trace intentionally does not travel


def test_search_result_round_trips_through_json():
    res = search(_placement_search_problem(), budget=6)
    d = json.loads(json.dumps(res.to_dict()))
    back = SearchResult.from_dict(d)
    assert back.to_dict() == res.to_dict()
    assert back.best.jct == res.best.jct
    assert back.evaluated == res.evaluated
    assert [c.jct for c in back.frontier] == [c.jct for c in res.frontier]
    assert isinstance(back.frontier[0], Candidate)
    # placement assignments come back as real Placements, like a live
    # result (not as raw serialized dicts)
    assert isinstance(back.best_assignment["placement"], Placement)
    assert [c.assignment["placement"].strategy for c in back.frontier] == \
        [c.assignment["placement"].strategy for c in res.frontier]


# ---------------------------------------------------------------------------
# JobSpec carries a CodesignProblem
# ---------------------------------------------------------------------------


def _cluster_topo():
    return fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=2,
                    nic_bw=2e9, agg_bw=8e9, oversub=4.0, pcie_bw=4e9)


def test_jobspec_problem_equivalent_to_flat_fields():
    topo = _cluster_topo()
    mesh = MeshConfig(shape=(4,), axis_names=("data",), data_axes=("data",),
                      model_axes=())
    dpp = DemandParams(zero1=False)
    flat = [JobSpec("jobA", CFG, SHAPE, mesh,
                    devices=topo.hosts[0] + topo.hosts[2], dp_params=dpp),
            JobSpec("jobB", CFG, SHAPE, mesh,
                    devices=topo.hosts[1] + topo.hosts[3], dp_params=dpp)]
    carried = [JobSpec("jobA", devices=topo.hosts[0] + topo.hosts[2],
                       problem=CodesignProblem(CFG, SHAPE, mesh, topo,
                                               dp_params=dpp)),
               JobSpec("jobB", devices=topo.hosts[1] + topo.hosts[3],
                       problem=CodesignProblem(CFG, SHAPE, mesh, topo,
                                               dp_params=dpp))]
    a = plan_cluster(flat, topo, grid=4)
    b = plan_cluster(carried, topo, grid=4)
    assert a.phases == b.phases
    assert a.naive_jct == b.naive_jct and a.staggered_jct == b.staggered_jct
    assert list(a.contended) == list(b.contended)
    # the carried problem fills the flat views
    assert carried[0].cfg is CFG and carried[0].mesh is mesh
    assert carried[0].policy == "priority" and carried[0].error_budget == 0.0


def test_jobspec_validation():
    mesh = MeshConfig(shape=(4,), axis_names=("data",), data_axes=("data",),
                      model_axes=())
    prob = CodesignProblem(CFG, SHAPE, mesh, _cluster_topo())
    with pytest.raises(ValueError, match="cfg/shape/mesh"):
        JobSpec("bare")
    with pytest.raises(ValueError, match="per-job knobs"):
        JobSpec("mixed", CFG, SHAPE, mesh, problem=prob)
    with pytest.raises(ValueError, match="fully specified"):
        JobSpec("free", problem=dataclasses.replace(
            prob, space=PlanSpace(policy=Choice("serial", "priority"))))
    # a carried force surfaces through the flat view and the plan
    forced = JobSpec("forced", problem=dataclasses.replace(
        prob, space=PlanSpace(algorithm={"all_reduce": Fixed("ring")})))
    assert forced.force == {"all_reduce": "ring"}
