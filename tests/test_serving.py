"""Serving co-design (repro.codesign.serving): SLO objectives over the
shared metric registry, prefill/decode/KV pricing through the CCL and
network layers, co-tenant contention, and the stagger search."""
import dataclasses
import json
import os
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# canonical contended scenarios live next to the benchmark harness so CI
# assertions, recorded numbers, and this suite cannot drift
from benchmarks.paper_claims import (_mixed_serving_cluster,
                                     _serving_cotenant_problem)

from repro.codesign import (ClusterReport, CotenantPulse, Objective,
                            ServingReport, ServingSLO, ServingSpec,
                            kv_bytes_per_token, plan, plan_cluster,
                            search, serving_problem)
from repro.codesign.report import OBJECTIVE_METRICS, metric_value
from repro.codesign.serving import _advance, _percentile
from repro.core.knobs import Search
from repro.core.types import ModelConfig
from repro.net.topology import fat_tree
from repro.obs import validate_chrome
from repro.obs.export import build_trace, detect_kind
from repro.sched.arrivals import Arrival, PoissonArrivals, TraceArrivals

CFG = ModelConfig(name="tiny", family="dense", source="[test]",
                  num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                  d_ff=1024, vocab_size=1000)
MLA = dataclasses.replace(CFG, name="tiny-mla", attention="mla",
                          kv_lora_rank=64, qk_rope_head_dim=16)


def _spec(**kw):
    base = dict(name="svc", cfg=CFG, prefill_devices=2, decode_devices=2,
                arrivals=PoissonArrivals(rate_rps=25.0, prompt_tokens=128,
                                         decode_tokens=8, seed=3),
                slo=ServingSLO(ttft_s=0.5, tpot_s=0.05), horizon_s=1.0)
    base.update(kw)
    return ServingSpec(**base)


# ---------------------------------------------------------------------------
# metric registry + Objective SLO semantics (shared by training & serving)
# ---------------------------------------------------------------------------


def test_unknown_metric_raises_with_valid_set():
    with pytest.raises(ValueError) as ei:
        Objective(minimize="ttft_p42")
    msg = str(ei.value)
    assert "ttft_p42" in msg and "valid metrics" in msg
    # the error names the registry, which spans both problem kinds
    assert "jct" in msg and "ttft_p99" in msg


def test_unknown_constraint_metric_raises():
    with pytest.raises(ValueError, match="valid metrics"):
        Objective(minimize="jct", constraints={"nope": 1.0})


def test_serving_metrics_registered_with_directions():
    for m in ("ttft_p50", "ttft_p95", "ttft_p99", "tpot_p50", "tpot_p99"):
        assert OBJECTIVE_METRICS[m] is False   # minimized
    for m in ("goodput", "slo_attainment"):
        assert OBJECTIVE_METRICS[m] is True    # maximized


def test_metric_value_wrong_report_kind():
    rep = plan(serving_problem(_spec(), fat_tree(16)))
    assert metric_value(rep, "ttft_p99") == rep.ttft_p99
    with pytest.raises(ValueError, match="different problem kind"):
        metric_value(rep, "wire_bytes_saved")


def test_constraints_feasibility_both_directions():
    rep = plan(serving_problem(_spec(), fat_tree(16)))
    ok = Objective(minimize="ttft_p99",
                   constraints={"ttft_p99": rep.ttft_p99 + 1.0,
                                "slo_attainment": 0.0})
    assert ok.feasible(rep) and ok.infeasible_reason(rep) is None
    # upper bound on a minimized metric
    low = Objective(minimize="ttft_p99",
                    constraints={"ttft_p99": rep.ttft_p99 / 2})
    assert "ttft_p99" in low.infeasible_reason(rep)
    # lower bound on a maximized metric
    hi = Objective(minimize="ttft_p99",
                   constraints={"goodput": rep.goodput + 1.0})
    assert "goodput" in hi.infeasible_reason(rep)


# ---------------------------------------------------------------------------
# percentile / contention-advance properties
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=50))
@settings(max_examples=20, deadline=None)
def test_percentile_monotone_and_bounded(vals):
    ps = [_percentile(vals, q) for q in (0.50, 0.95, 0.99)]
    assert ps == sorted(ps)
    assert min(vals) <= ps[0] and ps[-1] <= max(vals)


@given(st.floats(0.0, 0.02), st.floats(0.001, 0.02), st.floats(0.0, 0.01))
@settings(max_examples=20, deadline=None)
def test_advance_contention_only_slows(compute, comm, phase):
    """A co-tenant pulse can only delay a work item, and never below the
    solo duration; with no shared links it is exactly solo."""
    dem = {("a", "b"): 0.8}
    pulse = CotenantPulse("t", period_s=0.01, comm_s=0.004, phase_s=phase,
                          demand={("a", "b"): 1.0})
    solo = _advance(0.0, compute, comm, dem, ())
    assert solo == pytest.approx(compute + comm)
    shared = _advance(0.0, compute, comm, dem, (pulse,))
    assert shared >= solo - 1e-12
    foreign = CotenantPulse("t", period_s=0.01, comm_s=0.004,
                            demand={("x", "y"): 1.0})
    assert _advance(0.0, compute, comm, dem, (foreign,)) == \
        pytest.approx(solo)


# ---------------------------------------------------------------------------
# plan_serving: determinism, accounting invariants, persistence
# ---------------------------------------------------------------------------


def test_kv_bytes_per_token_gqa_vs_mla():
    gqa = kv_bytes_per_token(CFG)
    hd = CFG.head_dim or CFG.d_model // CFG.num_heads
    assert gqa == CFG.num_layers * 2 * CFG.num_kv_heads * hd * 2
    mla = kv_bytes_per_token(MLA)
    assert mla == MLA.num_layers * (64 + 16) * 2
    assert mla < gqa  # the latent cache is the point of MLA


def test_plan_serving_deterministic_and_goodput_bounded():
    prob = serving_problem(_spec(), fat_tree(16))
    r1, r2 = plan(prob), plan(prob)
    assert r1.to_dict() == r2.to_dict()
    assert r1.goodput <= r1.offered_rps + 1e-9
    assert 0.0 <= r1.slo_attainment <= 1.0
    assert len(r1.requests) > 0
    for r in r1.requests:
        assert r["t_arrive"] <= r["t_prefill"] <= r["t_first"] \
            <= r["t_finish"]
        assert r["ttft"] >= 0 and r["tpot"] >= 0
    # KV hand-off priced as p2p tasks in the prefill plan
    kv = [c for c in r1.prefill.choices if c.primitive == "p2p"]
    assert len(kv) == 2  # one per prefill rank
    assert r1.kv_bytes_per_request > 0


def test_serving_report_json_round_trip():
    rep = plan(serving_problem(_spec(), fat_tree(16)))
    d = json.loads(json.dumps(rep.to_dict()))
    rep2 = ServingReport.from_dict(d)
    assert rep2.to_dict() == rep.to_dict()
    assert rep2.ttft_p99 == rep.ttft_p99


def test_serving_trace_valid_and_kind_detected():
    spec = _spec(slo=ServingSLO(ttft_s=1e-5, tpot_s=1e-6))  # all violate
    rep = plan(serving_problem(spec, fat_tree(16)))
    assert rep.slo_violations()
    d = rep.to_dict()
    assert detect_kind(d) == "serving"
    doc = build_trace(d).to_chrome()
    assert validate_chrome(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert any(n.startswith("slo_violation:") for n in names)
    assert any(n.startswith("prefill:") for n in names)


def test_tpot_percentiles_monotone_in_report():
    rep = plan(serving_problem(_spec(), fat_tree(16)))
    assert rep.ttft_p50 <= rep.ttft_p95 <= rep.ttft_p99
    assert rep.tpot_p50 <= rep.tpot_p99


# ---------------------------------------------------------------------------
# co-tenancy: the stagger knob beats the naive zero-stagger baseline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cost_model", ["alphabeta", "flowsim"])
def test_stagger_search_beats_naive_cotenant(cost_model):
    """Acceptance: search() over the stagger knob returns an SLO-feasible
    plan whose p99 TTFT strictly beats the naive co-tenant baseline."""
    prob = _serving_cotenant_problem(cost_model)
    naive = plan(prob)
    sp = dataclasses.replace(prob.space, stagger=Search())
    res = search(dataclasses.replace(prob, space=sp), budget=16)
    assert res.best.stagger_s != 0.0
    assert res.best.ttft_p99 < naive.ttft_p99 - 1e-9
    assert prob.objective.feasible(res.best)
    assert res.best.slo_attainment == 1.0


def test_mixed_cluster_cotenancy():
    """plan_cluster over a training tenant + a serving tenant sharing
    uplinks: serving metrics surface in ClusterReport.serving, staggering
    never hurts the serving tenant, the training JCT barely regresses
    against its solo plan, and the report round-trips."""
    jobs, topo = _mixed_serving_cluster()
    rep = plan_cluster(jobs, topo, grid=6)
    assert rep.contended, "tenants must share tor<->agg uplinks"
    sm = rep.serving["svc"]
    assert sm["naive_burst_stretch"] >= 1.0
    assert sm["staggered_burst_stretch"] <= \
        sm["naive_burst_stretch"] + 1e-12
    assert 0.0 <= sm["staggered_slo_attainment"] <= 1.0
    assert sm["staggered_ttft_p99"] > 0.0
    # the serving tenant's presence costs the training job <= 1% JCT
    assert rep.staggered_jct["train"] <= 1.01 * rep.solo_jct["train"]
    # determinism + persistence of the mixed report
    rep2 = plan_cluster(jobs, topo, grid=6)
    assert rep2.to_dict() == rep.to_dict()
    wire = json.loads(json.dumps(rep.to_dict()))
    back = ClusterReport.from_dict(wire, {j.name: j for j in jobs})
    assert back.to_dict() == rep.to_dict()
    assert back.serving["svc"] == sm
