"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface the
test suite uses, activated by ``conftest.py`` only when the real package is
not installed (the CI container bakes it in; minimal dev boxes may not).

It runs each ``@given`` test on a deterministic pseudo-random sample of the
strategy space (seeded per test name) plus the strategy bounds, rather than
doing real property-based shrinking — enough to keep the invariants
exercised and the suite collectable without the dependency.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random, edge: bool = False):
        return self._draw(rng, edge)


class strategies:  # noqa: N801 — mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value=0, max_value=2 ** 63 - 1):
        return _Strategy(lambda rng, edge:
                         min_value if edge else rng.randint(min_value,
                                                            max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng, edge:
                         min_value if edge else rng.uniform(min_value,
                                                            max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng, edge:
                         elements[0] if edge else rng.choice(elements))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng, edge):
            size = max(min_size, 1) if edge else rng.randint(min_size,
                                                             max_size)
            return [elements.example(rng) for _ in range(size)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elems):
        return _Strategy(lambda rng, edge:
                         tuple(e.example(rng, edge) for e in elems))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng, edge: False if edge else
                         rng.choice([False, True]))


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        inner = getattr(fn, "__wrapped__", fn)
        max_examples = getattr(inner, "_stub_max_examples", 20)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(fn.__name__)
            ran = 0
            for i in range(min(max_examples, 25)):
                edge = i == 0  # first example pins every strategy's lower bound
                gen_args = tuple(s.example(rng, edge)
                                 for s in arg_strategies)
                gen_kw = {k: s.example(rng, edge)
                          for k, s in kw_strategies.items()}
                try:
                    fn(*args, *gen_args, **kwargs, **gen_kw)
                    ran += 1
                except _UnsatisfiedAssumption:
                    continue
            if not ran:
                raise AssertionError(
                    f"{fn.__name__}: assume() rejected every generated "
                    f"example — vacuous property test")
        wrapper.hypothesis_stub = True
        # hide the generated params from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples: int = 20, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def assume(condition) -> bool:
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
