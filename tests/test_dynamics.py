"""Event-driven cluster dynamics: topology degradation views, the
incremental re-planning loop, warm starts, and JSON persistence
(paper Sec. V fault tolerance / elasticity)."""
import json
import math
import os
import sys

import networkx as nx
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.codesign import ClusterDynamics, DynamicsReport, Event, JobSpec
from repro.codesign.cluster import ClusterReport
from repro.configs import get_config
from repro.core.demand_builder import DemandParams
from repro.core.types import MeshConfig, SHAPES_BY_NAME
from repro.net.topology import fat_tree

DP2 = MeshConfig(shape=(2,), axis_names=("data",), data_axes=("data",),
                 model_axes=())
SHAPE = SHAPES_BY_NAME["train_4k"]
DPP = DemandParams(zero1=False)
CFG = get_config("qwen2-0.5b")


def _job(name, devices):
    return JobSpec(name, CFG, SHAPE, DP2, policy="serial", devices=devices,
                   dp_params=DPP)


def _small_cluster():
    """Four single-GPU hosts, one per rack/pod, redundant agg tier: two
    DP-2 tenants whose cross-pod routes share only the core links."""
    topo = fat_tree(num_hosts=4, gpus_per_host=1, hosts_per_rack=1,
                    racks_per_pod=1, agg_redundancy=2, nic_bw=2e9,
                    agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    return [_job("a", (0, 2)), _job("b", (1, 3))], topo


# ---------------------------------------------------------------------------
# Event validation
# ---------------------------------------------------------------------------


def test_event_validation():
    with pytest.raises(ValueError):
        Event("meteor_strike")
    # each kind demands its target field
    with pytest.raises(ValueError):
        Event("job_arrive")
    with pytest.raises(ValueError):
        Event("job_depart")
    with pytest.raises(ValueError):
        Event("link_fail")
    with pytest.raises(ValueError):
        Event("host_fail")
    with pytest.raises(ValueError):
        Event("straggler")
    # factor ranges: degrade in (0, 1), straggle > 1
    with pytest.raises(ValueError):
        Event("link_degrade", link=("tor0", "agg0.0"), factor=1.5)
    with pytest.raises(ValueError):
        Event("link_degrade", link=("tor0", "agg0.0"), factor=0.0)
    with pytest.raises(ValueError):
        Event("straggler", name="a", factor=0.9)
    assert Event("straggler", name="a", factor=2.0).target == "a"
    assert Event("host_fail", host=3).target == "host3"
    assert Event("link_fail", link=("tor0", "agg0")).target == "tor0->agg0"


# ---------------------------------------------------------------------------
# Topology degradation views (the network layer under the event loop)
# ---------------------------------------------------------------------------


def test_without_link_views():
    topo = fat_tree(num_hosts=2, gpus_per_host=1, hosts_per_rack=1)
    cut = topo.without_link("tor0", "agg0")
    assert not cut.graph.has_edge("tor0", "agg0")
    assert not cut.graph.has_edge("agg0", "tor0")
    one_way = topo.without_link("tor0", "agg0", symmetric=False)
    assert not one_way.graph.has_edge("tor0", "agg0")
    assert one_way.graph.has_edge("agg0", "tor0")
    # missing edges are ignored: stacked failures are idempotent
    again = cut.without_link("tor0", "agg0")
    assert set(again.graph.edges()) == set(cut.graph.edges())
    # views are snapshots — the base is untouched
    assert topo.graph.has_edge("tor0", "agg0")


def test_without_host_view():
    topo = fat_tree(num_hosts=3, gpus_per_host=2, hosts_per_rack=1)
    dead = set(topo.hosts[1])
    view = topo.without_host(1)
    assert set(view.accelerators) == set(topo.accelerators) - dead
    # surviving hosts keep relative order; indices shift
    assert view.hosts == (topo.hosts[0], topo.hosts[2])
    for d in dead:
        assert d not in view.graph.nodes
    with pytest.raises(ValueError):
        topo.without_host(3)


def test_scaled_bw_view():
    topo = fat_tree(num_hosts=2, gpus_per_host=1, hosts_per_rack=1)
    base = topo.graph["tor0"]["agg0"]["bw"]
    # dict form scales both orientations of the named link only
    view = topo.scaled_bw({("tor0", "agg0"): 0.5})
    assert view.graph["tor0"]["agg0"]["bw"] == pytest.approx(base / 2)
    assert view.graph["agg0"]["tor0"]["bw"] == pytest.approx(base / 2)
    assert view.graph["tor1"]["agg0"]["bw"] == pytest.approx(base)
    # scalar form scales every link
    allhalf = topo.scaled_bw(0.5)
    for u, v in topo.graph.edges():
        assert allhalf.graph[u][v]["bw"] == \
            pytest.approx(topo.graph[u][v]["bw"] / 2)
    with pytest.raises(ValueError):
        topo.scaled_bw({("tor0", "agg0"): 0.0})


def test_fat_tree_agg_redundancy():
    with pytest.raises(ValueError):
        fat_tree(num_hosts=2, agg_redundancy=0)
    # redundancy=1 keeps the legacy single-agg node names
    legacy = fat_tree(num_hosts=2, gpus_per_host=1, hosts_per_rack=1)
    assert "agg0" in legacy.graph.nodes
    # redundancy=2: two parallel aggs per pod, per-uplink bw halved so
    # pod capacity is unchanged
    red = fat_tree(num_hosts=2, gpus_per_host=1, hosts_per_rack=1,
                   agg_redundancy=2)
    assert {"agg0.0", "agg0.1"} <= set(red.graph.nodes)
    assert "agg0" not in red.graph.nodes
    total = sum(red.graph["tor0"][f"agg0.{k}"]["bw"] for k in (0, 1))
    assert total == pytest.approx(legacy.graph["tor0"]["agg0"]["bw"])
    # the multi-path tier is the point: a single tor<->agg failure still
    # leaves a path, where the legacy tree partitions
    cut = red.without_link("tor0", "agg0.0")
    assert nx.has_path(cut.graph, 0, 1)
    legacy_cut = legacy.without_link("tor0", "agg0")
    assert not nx.has_path(legacy_cut.graph, 0, 1)


# ---------------------------------------------------------------------------
# ClusterReport JSON persistence
# ---------------------------------------------------------------------------


def test_cluster_report_json_round_trip():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    rep = dyn.report
    wire = json.loads(json.dumps(rep.to_dict()))
    back = ClusterReport.from_dict(wire, {s.name: s for s in jobs})
    assert back.phases == rep.phases
    assert back.staggered_jct == rep.staggered_jct
    assert back.naive_jct == rep.naive_jct
    assert list(back.contended) == list(rep.contended)
    assert [jp.devices for jp in back.jobs] == \
        [jp.devices for jp in rep.jobs]
    assert back.jobs[0].profile == rep.jobs[0].profile
    # specs are required by name — a missing one is an explicit error
    with pytest.raises(ValueError, match="'b'"):
        ClusterReport.from_dict(wire, {"a": jobs[0]})


# ---------------------------------------------------------------------------
# The event loop
# ---------------------------------------------------------------------------


def test_dynamics_rejects_duplicate_and_unknown_jobs():
    jobs, topo = _small_cluster()
    with pytest.raises(ValueError):
        ClusterDynamics([jobs[0], jobs[0]], topo)
    dyn = ClusterDynamics(jobs, topo, grid=4)
    with pytest.raises(ValueError):
        dyn.apply(Event("job_arrive", job=jobs[0]))  # already running
    with pytest.raises(ValueError):
        dyn.apply(Event("job_depart", name="ghost"))
    with pytest.raises(ValueError):
        dyn.apply(Event("straggler", name="ghost", factor=2.0))


def test_straggler_is_incremental_and_local():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    before = dict(dyn.report.staggered_jct)
    rec = dyn.apply(Event("straggler", name="a", factor=1.5))
    assert rec.mode == "incremental"
    assert rec.dirty_jobs == ["a"]
    # a's compute stretches; b is untouched by a compute-side slowdown
    assert dyn.report.staggered_jct["a"] > before["a"] * 1.2
    assert dyn.report.staggered_jct["b"] == pytest.approx(before["b"],
                                                          rel=0.05)
    # straggle factors compound
    rec2 = dyn.apply(Event("straggler", name="a", factor=1.5))
    assert dyn.report.staggered_jct["a"] > before["a"] * 1.8


def test_arrival_and_departure():
    base = fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=1,
                    racks_per_pod=1, agg_redundancy=2, nic_bw=2e9,
                    agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    dyn = ClusterDynamics([_job("a", (0, 4))], base, grid=4)
    rec = dyn.apply(Event("job_arrive", job=_job("c", (1, 5))))
    assert rec.mode == "incremental"
    assert set(dyn.report.staggered_jct) == {"a", "c"}
    # the arrival shares a's uplinks, so a's phase was re-opened too
    assert set(rec.dirty_jobs) == {"a", "c"}
    rec = dyn.apply(Event("job_depart", name="c"))
    assert set(dyn.report.staggered_jct) == {"a"}
    assert "c" not in dyn.specs
    # departing frees the shared links: the survivor is re-staggered
    assert rec.dirty_jobs == ["a"]


def test_link_fail_reroutes_on_redundant_tree():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    before = dict(dyn.report.staggered_jct)
    rec = dyn.apply(Event("link_fail", link=("tor0", "agg0.0")))
    # job a routes through pod 0; b (pods 1/3) is clean
    assert rec.dirty_jobs == ["a"]
    assert all(math.isfinite(v)
               for v in dyn.report.staggered_jct.values())
    # half the uplink capacity is gone: a cannot get faster
    assert dyn.report.staggered_jct["a"] >= before["a"] * 0.999


def test_link_degrade_compounds():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    dyn.apply(Event("link_degrade", link=("tor0", "agg0.0"), factor=0.5))
    dyn.apply(Event("link_degrade", link=("tor0", "agg0.0"), factor=0.5))
    assert dyn.bw_scale[("tor0", "agg0.0")] == pytest.approx(0.25)
    assert dyn._view().graph["tor0"]["agg0.0"]["bw"] == \
        pytest.approx(topo.graph["tor0"]["agg0.0"]["bw"] * 0.25)


def test_host_fail_recarves_onto_survivors():
    topo = fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=1,
                    racks_per_pod=1, agg_redundancy=2, nic_bw=2e9,
                    agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    dyn = ClusterDynamics([_job("a", (0, 4)), _job("b", (2, 6))], topo,
                          grid=4)
    dead = set(topo.hosts[2])      # devices {4, 5} — a loses device 4
    rec = dyn.apply(Event("host_fail", host=2))
    assert "a" in rec.dirty_jobs
    new_devs = {jp.spec.name: set(jp.devices) for jp in dyn.report.jobs}
    assert not new_devs["a"] & dead          # re-carved off the dead host
    assert new_devs["b"] == {2, 6}           # clean job keeps its pin
    assert not new_devs["a"] & new_devs["b"]
    assert all(math.isfinite(v)
               for v in dyn.report.staggered_jct.values())


def test_host_fail_evicts_lifo_when_cluster_too_small():
    jobs, topo = _small_cluster()   # 4 single-GPU hosts, 2 DP-2 jobs
    dyn = ClusterDynamics(jobs, topo, grid=4)
    rec = dyn.apply(Event("host_fail", host=3))   # 3 devices left for 4
    assert rec.mode == "full"
    assert rec.evicted == ["b"]     # most recently arrived goes first
    assert set(dyn.specs) == {"a"}
    assert set(dyn.report.staggered_jct) == {"a"}


def test_warm_start_from_persisted_report():
    jobs, topo = _small_cluster()
    fresh = ClusterDynamics(jobs, topo, grid=4)
    wire = json.loads(json.dumps(fresh.report.to_dict()))
    warmed = ClusterDynamics(jobs, topo, grid=4, warm_start=wire)
    assert warmed.report.staggered_jct == fresh.report.staggered_jct
    # both engines evolve identically from the shared standing plan
    ev = Event("straggler", name="b", factor=1.4)
    r1, r2 = fresh.apply(ev), warmed.apply(ev)
    assert r1.mode == r2.mode == "incremental"
    for name in r1.jct:
        assert r1.jct[name] == pytest.approx(r2.jct[name], rel=1e-6)


def test_compare_full_bounds_regret():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4, compare_full=True)
    rep = dyn.run([Event("straggler", time=1.0, name="a", factor=1.3),
                   Event("link_degrade", time=2.0,
                         link=("tor0", "agg0.0"), factor=0.5)])
    assert len(rep.records) == 2
    assert rep.incremental_speedup is not None
    assert rep.worst_regret is not None and rep.worst_regret <= 0.05
    assert rep.mean_replan_s > 0


def test_dynamics_report_json_round_trip():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4, compare_full=True)
    rep = dyn.run([Event("link_fail", time=1.0, link=("tor2", "agg2.1")),
                   Event("straggler", time=2.0, name="b", factor=2.0)])
    wire = json.loads(json.dumps(rep.to_dict()))
    back = DynamicsReport.from_dict(wire, {s.name: s for s in jobs})
    assert [r.kind for r in back.records] == [r.kind for r in rep.records]
    for r1, r2 in zip(back.records, rep.records):
        assert r1.target == r2.target and r1.mode == r2.mode
        assert r1.dirty_links == r2.dirty_links
        assert r1.jct == r2.jct and r1.regret == r2.regret
    assert back.final.staggered_jct == rep.final.staggered_jct
    assert back.incremental_speedup == \
        pytest.approx(rep.incremental_speedup)


def test_events_applied_in_time_order():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    rep = dyn.run([Event("straggler", time=5.0, name="a", factor=1.2),
                   Event("job_depart", time=1.0, name="b")])
    assert [r.kind for r in rep.records] == ["job_depart", "straggler"]


def test_bench_trace_stays_incremental():
    """The benchmark's 8-event trace (arrival, stragglers, degrade, fail,
    depart, host loss) never needs the full-search fallback, and every
    standing plan along the way is finite."""
    from benchmarks.paper_claims import _dynamic_cluster
    jobs, topo, events = _dynamic_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    rep = dyn.run(events)
    assert len(rep.records) == 8
    assert all(r.mode == "incremental" for r in rep.records)
    for r in rep.records:
        assert all(math.isfinite(v) for v in r.jct.values())
    # the trace's net effect: E arrived, B departed, host 2 took A's and
    # E's devices — everyone still placed on live hardware
    assert set(rep.final.staggered_jct) == {"jobA", "jobC", "jobD", "jobE"}
    dead = set(topo.hosts[2])
    for jp in rep.final.jobs:
        assert not set(jp.devices) & dead


# ---------------------------------------------------------------------------
# restore billing (checkpoint-restore cost on eviction / re-placement)
# ---------------------------------------------------------------------------


def test_checkpoint_state_bytes_arithmetic():
    from repro.checkpoint import checkpoint_state_bytes
    total = CFG.param_counts()["total"]
    # f32 master copy + two AdamW f32 moments = 12 bytes per parameter
    assert checkpoint_state_bytes(CFG) == total * 12
    assert checkpoint_state_bytes(CFG, param_bytes=2, moments=0) == \
        total * 2


def test_host_fail_bills_restore_time():
    """A re-placed job pays checkpoint-restore: optimizer state bytes
    over the job's surviving ingress bandwidth on the degraded fabric."""
    from repro.checkpoint import checkpoint_state_bytes
    topo = fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=1,
                    racks_per_pod=1, agg_redundancy=2, nic_bw=2e9,
                    agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    dyn = ClusterDynamics([_job("a", (0, 4)), _job("b", (2, 6))], topo,
                          grid=4)
    rec = dyn.apply(Event("host_fail", host=2))   # job a loses device 4
    assert rec.restore_s > 0.0                    # a moved, a pays
    assert dyn.report is not None
    # ingress of a 2-device job is at most 2 NICs' worth
    lower = checkpoint_state_bytes(CFG) / (2 * 4e9)
    assert rec.restore_s >= lower
    # the untouched straggler path bills nothing
    rec2 = dyn.apply(Event("straggler", name="b", factor=1.5))
    assert rec2.restore_s == 0.0


def test_eviction_bills_restore_and_report_totals():
    jobs, topo = _small_cluster()
    dyn = ClusterDynamics(jobs, topo, grid=4)
    rec = dyn.apply(Event("host_fail", host=3))   # evicts "b"
    assert rec.evicted == ["b"]
    assert rec.restore_s > 0.0                    # eviction is billed too
    rep = dyn.run([])
    assert rep.total_restore_s == pytest.approx(
        sum(r.restore_s for r in rep.records))
    # restore_s survives the JSON round trip (and defaults on old docs)
    wire = json.loads(json.dumps(rep.to_dict()))
    back = DynamicsReport.from_dict(wire, {s.name: s for s in jobs})
    assert [r.restore_s for r in back.records] == \
        [r.restore_s for r in rep.records]
    del wire["records"][0]["restore_s"]
    old = DynamicsReport.from_dict(wire, {s.name: s for s in jobs})
    assert old.records[0].restore_s == 0.0
