"""Overlap rewrites and per-edge exposure attribution.

Properties of the scheduler's exposure accounting (serial exposes
exactly the comm time; overlap never exposes more), the pipelined
gradient-bucket DAG (``build_demand(bucket_bytes=...)``), the
collective-matmul decomposition (``decompose_demand``), and the
codesign knobs that search them — plus the forced-8-device numerics
leg backing the decomposed-TP pricing."""
import inspect
import math
import os
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from helpers import run_multidevice

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.ccl.cost import CostParams, algo_cost
from repro.ccl.select import select_algorithm
from repro.codesign import (CodesignProblem, PlanSpace, Search, plan,
                            plan_iteration, search)
from repro.configs import get_config
from repro.core.demand_builder import (DECOMPOSABLE_PRIMITIVES, DemandParams,
                                       build_demand, decompose_demand)
from repro.core.types import MeshConfig, SHAPES_BY_NAME, SINGLE_POD_MESH
from repro.net.topology import dgx_cluster
from repro.sched.tasks import simulate_iteration

SHAPE = SHAPES_BY_NAME["train_4k"]
TP_MESH = MeshConfig(shape=(2, 8), axis_names=("data", "model"))
DP_MESH = MeshConfig(shape=(16,), axis_names=("data",),
                     data_axes=("data",), model_axes=())


def _cost(cp: CostParams):
    def cost(t):
        if t.primitive == "all_reduce":
            return select_algorithm(t.primitive, t.size_bytes, len(t.group),
                                    cp)[1]
        algo = "direct" if t.primitive == "all_to_all" else "ring"
        return algo_cost(t.primitive, algo, t.size_bytes, len(t.group), cp)
    return cost


# ---------------------------------------------------------------------------
# Exposure accounting invariants
# ---------------------------------------------------------------------------


def test_serial_exposes_exactly_comm_time():
    """No overlap means every second on the wire is a second of stall —
    and the per-task attribution says the same thing task by task."""
    dem = build_demand(get_config("granite-3-8b"), SHAPE, SINGLE_POD_MESH)
    r = simulate_iteration(dem, _cost(CostParams(alpha=5e-6, link_bw=25e9)),
                           "serial")
    assert r.exposed_comm == pytest.approx(r.comm_time, rel=1e-9)
    for tid, dur in r.task_comm_s.items():
        assert r.task_exposed_s[tid] == pytest.approx(dur, rel=1e-9)


@pytest.mark.parametrize("policy", ["fifo", "priority", "slack", "preempt"])
@pytest.mark.parametrize("arch", ["granite-3-8b", "dbrx-132b"])
def test_overlap_never_exposes_more_than_serial(policy, arch):
    dem = build_demand(get_config(arch), SHAPE, SINGLE_POD_MESH,
                       DemandParams(grad_chunks=4))
    cost = _cost(CostParams(alpha=5e-6, link_bw=10e9))
    serial = simulate_iteration(dem, cost, "serial")
    r = simulate_iteration(dem, cost, policy)
    assert r.exposed_comm <= serial.exposed_comm + 1e-9
    assert r.jct <= serial.jct + 1e-9


@pytest.mark.parametrize("policy", ["serial", "fifo", "priority", "slack",
                                    "preempt"])
def test_task_exposure_sums_to_total(policy):
    dem = build_demand(get_config("granite-3-8b"), SHAPE, SINGLE_POD_MESH,
                       DemandParams(grad_chunks=2))
    r = simulate_iteration(dem, _cost(CostParams(alpha=5e-6, link_bw=10e9)),
                           policy)
    assert sum(r.task_exposed_s.values()) == pytest.approx(r.exposed_comm,
                                                           abs=1e-9)
    assert all(v >= 0 for v in r.task_exposed_s.values())
    # every comm task has an attribution slot, exposed or not
    assert set(r.task_exposed_s) == {t.task_id for t in dem.comm_tasks}


@given(k=st.integers(min_value=2, max_value=16))
@settings(max_examples=8, deadline=None)
def test_grad_chunking_monotone_on_compute_bound(k):
    """Lina-style splitting never hurts a compute-bound DP workload under
    fifo, net of the per-chunk startup cost (alpha=0 isolates the
    pipelining direction of the tradeoff): chunk i becomes ready no
    later than the unsplit sync and hides under remaining backward."""
    cost = _cost(CostParams(alpha=0.0, link_bw=100e9))
    dem1 = build_demand(get_config("granite-3-8b"), SHAPE, DP_MESH,
                        DemandParams(grad_chunks=1))
    demk = build_demand(get_config("granite-3-8b"), SHAPE, DP_MESH,
                        DemandParams(grad_chunks=k))
    r1 = simulate_iteration(dem1, cost, "fifo")
    rk = simulate_iteration(demk, cost, "fifo")
    assert r1.compute_time > r1.comm_time  # compute-bound premise
    assert rk.exposed_comm <= r1.exposed_comm + 1e-9


# ---------------------------------------------------------------------------
# Pipelined gradient-bucket DAG
# ---------------------------------------------------------------------------


def test_build_demand_mutable_default_fixed():
    """The shared-instance default (``dp_params=DemandParams()`` evaluated
    once at def time) is gone: the default is None, constructed per call."""
    assert inspect.signature(build_demand) \
        .parameters["dp_params"].default is None


def test_bucket_dag_shape_and_byte_conservation():
    cfg = get_config("granite-3-8b")
    legacy = build_demand(cfg, SHAPE, SINGLE_POD_MESH)
    bucketed = build_demand(cfg, SHAPE, SINGLE_POD_MESH,
                            bucket_bytes=64 * 2 ** 20)
    grads = [t for t in legacy.comm_tasks if t.task_id.startswith("grad")]
    buckets = [t for t in bucketed.comm_tasks
               if t.task_id.startswith("gbucket")]
    assert buckets and not any(t.task_id.startswith("grad")
                               for t in bucketed.comm_tasks)
    # same bytes on the wire, just re-cut
    assert sum(t.size_bytes for t in buckets) == \
        sum(t.size_bytes for t in grads)
    # every bucket is full-size except at most the final remainder
    assert sum(1 for t in buckets if t.size_bytes != 64 * 2 ** 20) <= 1
    # each bucket chains off one backward layer and gates the optimizer
    for t in buckets:
        assert len(t.after_compute) == 1
        assert t.after_compute[0].startswith("bwd")
        assert t.before_compute == "opt"
    # buckets fill in backward order: the anchoring layer never increases
    layers = [int(t.after_compute[0][3:]) for t in buckets]
    assert layers == sorted(layers, reverse=True)


def test_bucket_size_tradeoff_visible_to_scheduler():
    """One giant bucket (max alpha amortization, zero pipelining) must
    lose to many early-starting buckets on a compute-bound iteration —
    the MG-WFBP/ByteScheduler tradeoff the simulator now resolves."""
    cfg = get_config("granite-3-8b")
    cost = _cost(CostParams(alpha=5e-6, link_bw=25e9))
    total = sum(t.size_bytes
                for t in build_demand(cfg, SHAPE, SINGLE_POD_MESH).comm_tasks
                if t.task_id.startswith("grad"))
    one = build_demand(cfg, SHAPE, SINGLE_POD_MESH, bucket_bytes=total)
    many = build_demand(cfg, SHAPE, SINGLE_POD_MESH,
                        bucket_bytes=max(1, total // 16))
    r_one = simulate_iteration(one, cost, "fifo")
    r_many = simulate_iteration(many, cost, "fifo")
    assert r_many.exposed_comm < r_one.exposed_comm
    assert r_many.jct < r_one.jct


# ---------------------------------------------------------------------------
# Collective-matmul decomposition
# ---------------------------------------------------------------------------


def test_decompose_structure_and_conservation():
    cfg = get_config("h2o-danube-1.8b")
    dem = build_demand(cfg, SHAPE, TP_MESH)
    ddem = decompose_demand(dem)
    assert ddem is not dem
    # total compute is conserved exactly (p partials of duration/p)
    assert sum(c.duration for c in ddem.compute_tasks) == \
        pytest.approx(sum(c.duration for c in dem.compute_tasks), rel=1e-12)
    # every decomposed AR becomes 2(p-1) permutes of S/p: ring-AR wire
    # bytes, so the win is overlap, not fewer bytes
    for t in dem.comm_tasks:
        if t.axis != "model" or t.primitive not in DECOMPOSABLE_PRIMITIVES:
            continue
        steps = [s for s in ddem.comm_tasks
                 if s.task_id.startswith(t.task_id + ".")]
        if not steps:  # no compute anchors -> legitimately skipped
            continue
        p = len(t.group)
        assert all(s.primitive == "permute" for s in steps)
        assert all(s.size_bytes == t.size_bytes // p for s in steps)
        if t.primitive == "all_reduce":
            assert len(steps) == 2 * (p - 1)
        else:
            assert len(steps) == p - 1
    # data-parallel gradient syncs pass through untouched
    assert {s.task_id for s in ddem.comm_tasks if s.axis == "data"} == \
        {t.task_id for t in dem.comm_tasks if t.axis == "data"}


def test_decompose_noop_without_model_axis():
    """A pure-DP job has no TP collectives to rewrite: the demand comes
    back untouched (same object), so the knob is free when irrelevant."""
    dem = build_demand(get_config("granite-3-8b"), SHAPE, DP_MESH)
    assert decompose_demand(dem) is dem


def test_decompose_cuts_exposure_not_compute():
    dem = build_demand(get_config("h2o-danube-1.8b"), SHAPE, TP_MESH)
    ddem = decompose_demand(dem)
    cost = _cost(CostParams(alpha=1e-6, link_bw=64e9))
    r_bulk = simulate_iteration(dem, cost, "fifo")
    r_dec = simulate_iteration(ddem, cost, "fifo")
    assert r_dec.compute_time == pytest.approx(r_bulk.compute_time,
                                               rel=1e-12)
    assert r_dec.exposed_comm < r_bulk.exposed_comm
    assert r_dec.jct < r_bulk.jct


# ---------------------------------------------------------------------------
# Codesign surface: knobs, attribution, report round-trip
# ---------------------------------------------------------------------------


def test_search_walks_overlap_knobs_jointly():
    problem = CodesignProblem(
        get_config("h2o-danube-1.8b"), SHAPE, TP_MESH,
        dgx_cluster(2, nvlink_bw=64e9),
        space=PlanSpace(bucket_bytes=Search(), decompose=Search())
        .pinned(policy="fifo"))
    res = search(problem, budget=40)
    assert {"bucket_bytes", "decompose"} <= set(res.best_assignment)
    assert {"bucket_bytes", "decompose"} <= set(res.attribution)
    # the baseline point (legacy grads, bulk collectives) is in the walk,
    # so the winner can never lose to it
    naive = plan(problem.pinned(bucket_bytes=None, decompose=False))
    assert res.best.jct <= naive.jct + 1e-9
    # on this TP-heavy, slower-fabric box the rewrite must actually win
    assert res.best_assignment["decompose"] is True
    assert res.attribution["decompose"] > 0


def test_report_task_exposure_roundtrips():
    rep = plan_iteration(get_config("qwen2-0.5b"), SHAPE, TP_MESH,
                         dgx_cluster(2), policy="fifo")
    assert rep.task_exposed_s
    assert sum(rep.task_exposed_s.values()) == \
        pytest.approx(rep.exposed_comm, abs=1e-9)
    top = rep.top_exposed_tasks(3)
    assert all(s > 0 for _, s in top)
    assert [s for _, s in top] == sorted((s for _, s in top), reverse=True)
    back = type(rep).from_dict(rep.to_dict())
    assert back.task_exposed_s == rep.task_exposed_s
    assert back.top_exposed_tasks(3) == top


def test_plan_iteration_overlap_knobs_lower_jct():
    base = plan_iteration(get_config("h2o-danube-1.8b"), SHAPE, TP_MESH,
                          dgx_cluster(2, nvlink_bw=64e9), policy="fifo")
    dec = plan_iteration(get_config("h2o-danube-1.8b"), SHAPE, TP_MESH,
                         dgx_cluster(2, nvlink_bw=64e9), policy="fifo",
                         decompose=True)
    assert dec.jct < base.jct
    assert dec.exposed_comm < base.exposed_comm


# ---------------------------------------------------------------------------
# Executable ground truth: the kernels the decomposed pricing mirrors
# ---------------------------------------------------------------------------


def test_decomposed_kernels_exact_on_8_forced_devices():
    """The priced p-step structure must correspond to kernels that are
    numerically exact at p=8 (the TP width the benchmark searches)."""
    from benchmarks.paper_claims import _COLLECTIVE_MATMUL_NUMERICS
    run_multidevice(_COLLECTIVE_MATMUL_NUMERICS, num_devices=8)
