"""Gradient-compression subsystem: codec round trips, Pallas kernel vs
reference parity, the error-feedback property, compressed-candidate pricing,
error-budget selection, and the end-to-end codesign integration."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ccl.algorithms import (ALGORITHMS, COMPRESSED_CANDIDATES,
                                  generate_flows)
from repro.ccl.cost import CostParams, algo_cost
from repro.ccl.select import (AlphaBeta, FlowSim, select_for_task,
                              structurally_eligible)
from repro.compress import (SPECS, base_algorithm, codec_spec, get_codec,
                            split_algorithm)
from repro.core.demand import CommTask
from repro.core.demand_builder import DemandParams
from repro.core.types import MeshConfig, SHAPES_BY_NAME
from repro.codesign import JobSpec, plan_cluster, plan_iteration
from repro.configs import get_config
from repro.kernels.compress.ops import (dequantize, lowrank_project,
                                        quantize, sparsify)
from repro.kernels.compress.ref import (dequantize_ref, matmul_ref,
                                        quantize_ref, sparsify_ref)
from repro.net.topology import fat_tree, torus2d

SHAPE = SHAPES_BY_NAME["train_4k"]


# ---------------------------------------------------------------------------
# codec API: round trips, wire accounting, spec consistency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,max_err", [
    ("q8", 0.02), ("q4", 0.25), ("topk", 1.0), ("lowrank", 1.0),
])
def test_codec_roundtrip_error_within_spec_regime(name, max_err):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    codec = get_codec(name)
    enc, _ = codec.encode(x, codec.init_state(x))
    dec = codec.decode(enc)
    assert dec.shape == x.shape
    rel = float(jnp.linalg.norm(dec - x) / jnp.linalg.norm(x))
    assert rel <= max_err, (name, rel)
    assert enc.wire_bytes < x.size * 4
    # at gradient-like payload sizes the measured wire bytes must be within
    # 2x of the spec's advertised ratio (specs are nominal constants; the
    # low-rank ratio is shape-dependent and only amortizes at scale)
    big = jax.random.normal(jax.random.PRNGKey(1), (512, 512))
    enc_big, _ = codec.encode(big)
    assert enc_big.wire_bytes <= \
        big.size * 4 * codec_spec(name).wire_ratio * 2


def test_quantized_codec_decode_is_unbiased_with_stochastic_rounding():
    from repro.compress import QuantCodec

    x = jax.random.normal(jax.random.PRNGKey(1), (512,))
    codec = QuantCodec(bits=8, stochastic=True)
    dec = jnp.mean(jnp.stack([
        codec.decode(codec.encode(x, key=jax.random.PRNGKey(i))[0])
        for i in range(200)]), axis=0)
    det = get_codec("q8").decode(get_codec("q8").encode(x)[0])
    # the 200-sample mean must beat a single deterministic rounding
    assert float(jnp.abs(dec - x).max()) < float(jnp.abs(det - x).max())
    # a stochastic codec refuses to silently degrade to biased rounding
    with pytest.raises(ValueError):
        codec.encode(x)


def test_q4_payload_is_nibble_packed():
    """The q4 wire claim must be real: half of q8's payload bytes, and the
    pack/unpack transform is lossless."""
    from repro.kernels.compress.ref import pack_int4, unpack_int4

    x = jax.random.normal(jax.random.PRNGKey(9), (1001,))
    e8, _ = get_codec("q8").encode(x)
    e4, _ = get_codec("q4").encode(x)
    assert e4.arrays[0].nbytes == math.ceil(e8.arrays[0].nbytes / 2)
    assert get_codec("q4").decode(e4).shape == x.shape
    q = jnp.arange(-7, 8, dtype=jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(q), q.size)), np.asarray(q))


def test_topk_codec_keeps_largest_magnitudes():
    # distinct magnitudes, alternating signs, shuffled deterministically
    mags = jnp.arange(1.0, 65.0) * jnp.where(jnp.arange(64) % 2 == 0, 1, -1)
    x = jax.random.permutation(jax.random.PRNGKey(5), mags)
    codec = get_codec("topk")
    dec = codec.decode(codec.encode(x)[0])
    kept = np.nonzero(np.asarray(dec))[0]
    k = max(1, int(x.size * codec.fraction))
    assert len(kept) == k
    top = np.argsort(-np.abs(np.asarray(x)))[:k]
    assert set(kept) == set(top)


def test_lowrank_codec_exact_on_low_rank_input():
    u = jax.random.normal(jax.random.PRNGKey(2), (40, 3))
    v = jax.random.normal(jax.random.PRNGKey(3), (3, 30))
    x = u @ v  # true rank 3 < codec rank 4
    codec = get_codec("lowrank")
    dec = codec.decode(codec.encode(x)[0])
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x), atol=1e-3)


def test_specs_effective_error_orders_budgets():
    # the budget knob's semantics depend on this ordering: q8 admitted at
    # tight budgets, sparsification/low-rank only at loose ones
    assert SPECS["q8"].effective_error < SPECS["q4"].effective_error \
        < SPECS["lowrank"].effective_error
    for name, spec in SPECS.items():
        assert 0 < spec.wire_ratio < 1 and spec.passes >= 1, name
        if spec.error_feedback:
            assert spec.effective_error == spec.rel_error * 0.5


def test_algorithm_name_parsing():
    assert split_algorithm("ring+q8") == ("ring", "q8")
    assert split_algorithm("ring") == ("ring", None)
    assert base_algorithm("ps+topk") == "atp"
    assert base_algorithm("hierarchical+q8") == "hierarchical"
    with pytest.raises(KeyError):
        codec_spec("zstd")


# ---------------------------------------------------------------------------
# error feedback: the residual provably bounds the accumulated bias
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_error_feedback_bounds_accumulated_bias(seed):
    """Transmitting the same gradient T times: without error feedback the
    accumulated bias grows linearly in T; with the residual it converges
    to a bounded fixed point (the bias at 4T barely exceeds the bias at
    T).  This is the property that makes a 97%-lossy top-k codec usable
    for training."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    codec = get_codec("topk")
    t_short, t_long = 25, 100

    def bias(steps, with_ef):
        state = codec.init_state(x)
        acc = jnp.zeros_like(x)
        for _ in range(steps):
            enc, new_state = codec.encode(x, state)
            if with_ef:
                state = new_state  # else: drop the residual every step
            acc = acc + codec.decode(enc)
        return float(jnp.linalg.norm(acc - steps * x))

    ef_s, ef_l = bias(t_short, True), bias(t_long, True)
    raw_s, raw_l = bias(t_short, False), bias(t_long, False)
    assert raw_l == pytest.approx(raw_s * t_long / t_short, rel=1e-3)
    assert ef_l < raw_l / 2          # EF strictly shrinks the bias
    assert ef_l < ef_s * 1.5         # ...and it has stopped growing


def test_error_feedback_residual_equals_accumulated_bias():
    """The invariant behind the bound: after any number of steps the
    carried residual IS exactly the total un-transmitted mass."""
    x = jax.random.normal(jax.random.PRNGKey(7), (128,))
    codec = get_codec("lowrank")
    state = codec.init_state(x)
    acc = jnp.zeros_like(x)
    for _ in range(5):
        enc, state = codec.encode(x, state)
        acc = acc + codec.decode(enc)
    np.testing.assert_allclose(np.asarray(5 * x - acc),
                               np.asarray(state.reshape(x.shape)),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas kernels vs references (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("shape", [(256,), (8, 256), (3, 100)])
def test_quantize_kernel_matches_ref(bits, shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    q, scales, orig = quantize(x, bits=bits)
    dec = dequantize(q, scales, orig)
    rows, _ = q.shape
    x_rows = jnp.pad(x.reshape(-1), (0, q.size - x.size)).reshape(rows, -1)
    q_ref, s_ref = quantize_ref(x_rows, bits=bits, per_row=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(s_ref),
                               rtol=1e-6)
    dec_ref = dequantize_ref(q_ref, s_ref).reshape(-1)[:x.size].reshape(shape)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(dec_ref),
                               rtol=1e-6)
    qmax = 2 ** (bits - 1) - 1
    assert float(jnp.abs(dec - x).max()) <= float(jnp.abs(x).max()) / qmax


def test_quantize_kernel_stochastic_is_unbiased():
    # values that do NOT land on integer steps after absmax scaling
    x = jnp.linspace(-0.9994, 1.0, 256)
    decs = []
    for i in range(300):
        q, s, shape = quantize(x, stochastic=True, key=jax.random.PRNGKey(i))
        decs.append(dequantize(q, s, shape))
    mean = jnp.mean(jnp.stack(decs), axis=0)
    det = dequantize(*quantize(x))
    assert float(jnp.abs(mean - x).max()) < float(jnp.abs(det - x).max())


def test_sparsify_kernel_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(2), (512,))
    thresh = float(jnp.quantile(jnp.abs(x), 0.9))
    out = sparsify(x, thresh)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(sparsify_ref(x, thresh)))
    assert 0 < int((out != 0).sum()) < x.size


def test_lowrank_matmul_kernel_matches_ref():
    m = jax.random.normal(jax.random.PRNGKey(3), (128, 64))
    q = jax.random.normal(jax.random.PRNGKey(4), (64, 4))
    np.testing.assert_allclose(np.asarray(lowrank_project(m, q)),
                               np.asarray(matmul_ref(m, q)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# compressed candidates: flow schedules + pricing
# ---------------------------------------------------------------------------


def test_compressed_flowset_scales_wire_bytes():
    task = CommTask("t", "all_reduce", 1024 * 8, tuple(range(8)))
    base = generate_flows(task, "ring")
    comp = generate_flows(task, "ring+q8")
    assert comp.algorithm == "ring+q8"
    assert comp.num_steps == base.num_steps
    assert len(comp.flows) == len(base.flows)
    ratio = codec_spec("q8").wire_ratio
    assert comp.bytes_on_wire() == pytest.approx(
        base.bytes_on_wire() * ratio, rel=0.01)
    # ad hoc composition beyond the canonical registry also works
    adhoc = generate_flows(task, "ring+q4")
    assert adhoc.bytes_on_wire() < comp.bytes_on_wire()


def test_ps_topk_uses_atp_flow_pattern():
    task = CommTask("t", "all_reduce", 2 ** 20, tuple(range(8)))
    ps = generate_flows(task, "ps+topk")
    atp = generate_flows(task, "atp")
    assert ps.num_steps == atp.num_steps == 2
    assert len(ps.flows) == len(atp.flows)
    assert ps.bytes_on_wire() < atp.bytes_on_wire()


def test_compressed_candidates_registered_and_guarded():
    for name in COMPRESSED_CANDIDATES:
        assert name in ALGORITHMS["all_reduce"]
    # structural guards come from the base algorithm
    assert structurally_eligible("ring+q8", 6)
    assert not structurally_eligible("halving_doubling+q8", 6)


def test_algo_cost_compressed_decomposition():
    """cost(compressed) = latency + ratio * bandwidth + codec overhead."""
    cp = CostParams(alpha=1e-6, link_bw=10e9, codec_bw=200e9,
                    codec_alpha=2e-6)
    n, p = 64 * 2 ** 20, 8
    full = algo_cost("all_reduce", "ring", n, p, cp)
    lat = algo_cost("all_reduce", "ring", 0, p, cp)
    spec = codec_spec("q8")
    steps = 2 * (p - 1)
    want = lat + (full - lat) * spec.wire_ratio \
        + steps * cp.codec_alpha + spec.passes * n / cp.codec_bw
    got = algo_cost("all_reduce", "ring+q8", n, p, cp)
    assert got == pytest.approx(want, rel=1e-9)
    # bandwidth regime: compression wins; latency regime: overhead loses
    assert got < full
    small = 2 ** 10
    assert algo_cost("all_reduce", "ring+q8", small, p, cp) > \
        algo_cost("all_reduce", "ring", small, p, cp)
    # the per-step codec launch latency is charged even when the fabric
    # alpha is 0 (steps cannot be inferred from a zero latency term)
    cp0 = CostParams(alpha=0.0, link_bw=10e9, codec_alpha=2e-6)
    assert algo_cost("all_reduce", "ring+q8", small, p, cp0) > \
        algo_cost("all_reduce", "ring", small, p, cp0) + \
        2 * (p - 1) * cp0.codec_alpha * 0.99


def test_flowsim_prices_codec_overhead():
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=4.0)
    task = CommTask("t", "all_reduce", 64 * 2 ** 20,
                    tuple(topo.accelerators))
    free = FlowSim(topo, codec_bw=1e30, codec_alpha=0.0)
    priced = FlowSim(topo)
    assert priced.cost(task, "ring+q8") > free.cost(task, "ring+q8")
    assert free.cost(task, "ring+q8") < free.cost(task, "ring")


# ---------------------------------------------------------------------------
# error-budget selection
# ---------------------------------------------------------------------------


def test_default_budget_excludes_all_lossy_candidates():
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    task = CommTask("g", "all_reduce", 64 * 2 ** 20,
                    tuple(topo.accelerators))
    for model in (AlphaBeta.from_topology(topo), FlowSim(topo)):
        sel = select_for_task(task, model)
        assert "+" not in sel.algorithm
        assert all("+" not in a for a in sel.costs)
        assert any("+" in a for a in sel.excluded)


def test_budget_admits_codecs_by_effective_error():
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    task = CommTask("g", "all_reduce", 64 * 2 ** 20,
                    tuple(topo.accelerators))
    model = FlowSim(topo)
    tight = select_for_task(task, model, error_budget=0.01)
    loose = select_for_task(task, model, error_budget=0.5)
    assert "ring+q8" in tight.costs and "ring+topk" not in tight.costs
    assert "ring+topk" in loose.costs
    # a budget below every codec's error behaves like the default
    none = select_for_task(task, model, error_budget=1e-6)
    assert all("+" not in a for a in none.costs)


def test_explicit_force_bypasses_budget_but_whitelist_does_not():
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    task = CommTask("g", "all_reduce", 64 * 2 ** 20,
                    tuple(topo.accelerators))
    # a single-name force is an explicit accuracy decision
    sel = select_for_task(task, FlowSim(topo), allow=("ring+q8",))
    assert sel.algorithm == "ring+q8"
    # a generic whitelist must still respect the (default 0) budget
    sel = select_for_task(task, FlowSim(topo), allow=("ring", "ring+q8"))
    assert sel.algorithm == "ring" and "ring+q8" in sel.excluded
    # ad hoc base+codec combos beyond the canonical registry are forceable
    # (the executable ring_q4 has a priceable selection counterpart)
    sel = select_for_task(task, FlowSim(topo), allow=("ring+q4",))
    assert sel.algorithm == "ring+q4"
    assert sel.cost < select_for_task(
        task, FlowSim(topo), allow=("ring+q8",)).cost


def test_compression_rejected_in_latency_regime():
    """Tiny payloads: the wire saving is negligible but the per-step codec
    latency is not — selection must keep the uncompressed candidate."""
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    task = CommTask("g", "all_reduce", 2 ** 10, tuple(topo.accelerators))
    for model in (AlphaBeta.from_topology(topo), FlowSim(topo)):
        sel = select_for_task(task, model, error_budget=0.5)
        assert "+" not in sel.algorithm, (type(model).__name__,
                                          sel.algorithm)


def test_compressed_hierarchical_inherits_host_guard():
    # single-host-per-gpu fat-tree cannot run hierarchical, compressed or not
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=4.0)
    task = CommTask("g", "all_reduce", 64 * 2 ** 20,
                    tuple(topo.accelerators))
    sel = select_for_task(task, FlowSim(topo), error_budget=0.01)
    assert "hierarchical+q8" in sel.excluded
    # ICI fabrics exclude the ps/atp-based compressed candidates too
    ici = torus2d(4, 4)
    t2 = CommTask("g", "all_reduce", 64 * 2 ** 20, tuple(ici.accelerators))
    sel2 = select_for_task(t2, FlowSim(ici), error_budget=0.5)
    assert "ps+topk" in sel2.excluded


# ---------------------------------------------------------------------------
# end-to-end: plan_iteration / plan_cluster with a budget
# ---------------------------------------------------------------------------


def _grad_mesh(p):
    return MeshConfig(shape=(p,), axis_names=("data",), data_axes=("data",),
                      model_axes=())


def test_plan_iteration_budget_lowers_jct_and_reports_savings():
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    cfg = get_config("qwen2-0.5b")
    dpp = DemandParams(zero1=False)
    base = plan_iteration(cfg, SHAPE, _grad_mesh(8), topo, policy="serial",
                          dp_params=dpp)
    comp = plan_iteration(cfg, SHAPE, _grad_mesh(8), topo, policy="serial",
                          dp_params=dpp, error_budget=0.01)
    assert comp.jct < base.jct
    assert comp.wire_bytes_saved > 0 and base.wire_bytes_saved == 0
    assert comp.error_budget == 0.01
    compressed = [c for c in comp.choices if c.codec]
    assert compressed and all(c.codec == "q8" for c in compressed)
    assert all(0 < c.wire_ratio < 1 for c in compressed)
    assert "q8" in comp.codecs_by_primitive()["all_reduce"]


def test_plan_iteration_per_primitive_budget():
    """The dict form compresses gradients while keeping other primitives
    exact — the per-CommTask knob."""
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    cfg = get_config("qwen2-0.5b")
    rep = plan_iteration(cfg, SHAPE, _grad_mesh(8), topo, policy="serial",
                         dp_params=DemandParams(zero1=False),
                         error_budget={"all_reduce": 0.01})
    assert any(c.codec for c in rep.choices
               if c.primitive == "all_reduce")
    assert all(c.codec is None for c in rep.choices
               if c.primitive != "all_reduce")
    # the report records the dict verbatim, not a collapsed global number
    assert rep.error_budget == {"all_reduce": 0.01}


def test_plan_cluster_compression_shrinks_contended_bytes():
    """Horizontal integration: compressed tenants put fewer bytes on the
    shared uplinks, so contention (and the stagger problem) shrinks."""
    topo = fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=2,
                    nic_bw=2e9, agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    mesh = MeshConfig(shape=(4,), axis_names=("data",), data_axes=("data",),
                      model_axes=())
    cfg = get_config("qwen2-0.5b")
    dpp = DemandParams(zero1=False)

    def jobs(budget):
        return [JobSpec("jobA", cfg, SHAPE, mesh,
                        devices=topo.hosts[0] + topo.hosts[2],
                        dp_params=dpp, error_budget=budget),
                JobSpec("jobB", cfg, SHAPE, mesh,
                        devices=topo.hosts[1] + topo.hosts[3],
                        dp_params=dpp, error_budget=budget)]

    base = plan_cluster(jobs(0.0), topo, grid=4)
    comp = plan_cluster(jobs(0.01), topo, grid=4)
    assert base.contended and comp.contended
    total = lambda rep: sum(b for users in rep.contended.values()
                            for b in users.values())
    assert total(comp) < total(base)
    for name in ("jobA", "jobB"):
        assert comp.solo_jct[name] < base.solo_jct[name]