"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,sq,sk,d", [
    (1, 2, 2, 128, 128, 64),
    (2, 4, 2, 256, 256, 64),   # GQA group 2
    (1, 8, 1, 256, 512, 128),  # MQA, rectangular
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (False, None), (True, 128),
])
def test_flash_attention_sweep(b, h, kv, sq, sk, d, causal, window, dtype):
    key = jax.random.PRNGKey(b * 100 + h)
    q = jax.random.normal(key, (b, h, sq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, sk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block", [(64, 64), (128, 128), (128, 64)])
def test_flash_attention_block_shapes(block):
    bq, bk = block
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    out = flash_attention(q, k, v, bq=bq, bk=bk)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,l,p,n,chunk", [
    (1, 2, 256, 64, 32, 64),
    (2, 4, 512, 64, 128, 128),  # mamba2-130m-like state
    (1, 2, 256, 128, 64, 256),  # jamba-like head dim
])
def test_ssd_scan_sweep(b, h, l, p, n, chunk, dtype):
    key = jax.random.PRNGKey(l + p)
    x = (jax.random.normal(key, (b, h, l, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 1), (b, h, l))).astype(
        jnp.float32)
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    bb = (jax.random.normal(jax.random.fold_in(key, 3), (b, l, n)) * 0.3
          ).astype(dtype)
    cc = (jax.random.normal(jax.random.fold_in(key, 4), (b, l, n)) * 0.3
          ).astype(dtype)
    out = ssd_scan(x, dt, a, bb, cc, chunk=chunk)
    ref = ssd_scan_ref(x, dt, a, bb, cc, chunk=chunk)
    scale = max(float(jnp.abs(ref.astype(jnp.float32)).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32) / scale,
        np.asarray(ref, np.float32) / scale,
        atol=3e-2 if dtype == jnp.bfloat16 else 3e-5, rtol=3e-2)


def test_ssd_scan_state_continuity():
    """Scanning 2 chunks must differ from treating them independently —
    proves the VMEM carry state crosses the chunk boundary."""
    key = jax.random.PRNGKey(0)
    b, h, l, p, n = 1, 1, 256, 32, 16
    x = jax.random.normal(key, (b, h, l, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, h, l)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    bb = jax.random.normal(jax.random.fold_in(key, 3), (b, l, n)) * 0.3
    cc = jax.random.normal(jax.random.fold_in(key, 4), (b, l, n)) * 0.3
    joint = ssd_scan(x, dt, a, bb, cc, chunk=128)
    # independent halves
    h1 = ssd_scan(x[:, :, :128], dt[:, :, :128], a, bb[:, :128], cc[:, :128],
                  chunk=128)
    h2 = ssd_scan(x[:, :, 128:], dt[:, :, 128:], a, bb[:, 128:], cc[:, 128:],
                  chunk=128)
    assert np.allclose(np.asarray(joint[:, :, :128]), np.asarray(h1),
                       atol=1e-5)
    assert not np.allclose(np.asarray(joint[:, :, 128:]), np.asarray(h2),
                           atol=1e-3), "second chunk ignored carried state"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f,blocks", [
    (2, 128, 256, 128, dict()),
    (4, 256, 512, 384, dict(bd=128)),
    (16, 128, 256, 256, dict(bc=64, bf=128, bd=64)),  # dbrx-like E
])
def test_moe_gmm_sweep(e, c, d, f, blocks, dtype):
    key = jax.random.PRNGKey(e * 10 + f)
    x = jax.random.normal(key, (e, c, d), dtype)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05
         ).astype(dtype)
    out = moe_gmm(x, w, **blocks)
    ref = moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
