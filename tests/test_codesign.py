"""Codesign engine: placement, CostModel protocol, hierarchical collectives,
selection guards, and the end-to-end plan_iteration pipeline."""
import time

import pytest

from repro.ccl.algorithms import generate_flows
from repro.ccl.cost import CostParams, algo_cost
from repro.ccl.select import (AlphaBeta, FlowSim, is_square, select_algorithm,
                              select_for_task, structurally_eligible)
from repro.codesign import Placement, place_mesh, plan_iteration
from repro.configs import get_config
from repro.core.demand import CommTask
from repro.core.demand_builder import DemandParams, build_demand
from repro.core.types import MeshConfig, SHAPES_BY_NAME
from repro.net.topology import dgx_cluster, fat_tree, torus2d

DP16 = MeshConfig(shape=(16,), axis_names=("data",), data_axes=("data",),
                  model_axes=())
DP2_TP8 = MeshConfig(shape=(2, 8), axis_names=("data", "model"))
SHAPE = SHAPES_BY_NAME["train_4k"]


# ---------------------------------------------------------------------------
# selection guards (satellites)
# ---------------------------------------------------------------------------


def test_empty_candidate_set_raises_descriptive_error():
    # p=3 excludes halving_doubling (not a power of two); allow-listing only
    # it must fail with a message naming the primitive, p, and the guards
    with pytest.raises(ValueError) as ei:
        select_algorithm("all_reduce", 2 ** 20, 3, CostParams(),
                         allow=("halving_doubling",))
    msg = str(ei.value)
    assert "all_reduce" in msg and "p=3" in msg and "halving_doubling" in msg


def test_square_guard_uses_exact_isqrt():
    r = 2 ** 60 + 3
    p = r * r
    assert int(p ** 0.5) ** 2 != p  # the seed's float guard mis-rounds here
    assert is_square(p)
    assert not is_square(p + 1)
    assert structurally_eligible("torus2d", p)
    assert not structurally_eligible("torus2d", p + 1)
    assert not structurally_eligible("halving_doubling", 12)


def test_select_for_task_matches_legacy_entry_point():
    cp = CostParams(alpha=2e-6, link_bw=40e9)
    for size in (2 ** 12, 2 ** 24):
        legacy = select_algorithm("all_reduce", size, 16, cp)
        task = CommTask("t", "all_reduce", size, tuple(range(16)))
        sel = select_for_task(task, AlphaBeta(cp))
        assert legacy == (sel.algorithm, sel.cost, sel.costs)


# ---------------------------------------------------------------------------
# hierarchical all-reduce (satellite: wire bytes + decomposition)
# ---------------------------------------------------------------------------


def test_hierarchical_decomposition_structure():
    topo = dgx_cluster(2)
    group = tuple(topo.accelerators)  # 16 = 2 hosts x 8
    m, hcount = 8, 2
    n = 1024 * 16
    task = CommTask("ar", "all_reduce", n, group)
    fs = generate_flows(task, "hierarchical", hosts=topo.hosts)
    assert fs.num_steps == 2 * (m - 1) + 2 * (hcount - 1) + 2
    leaders = {h[0] for h in topo.hosts}
    inter_steps = range(m, m + 2 * (hcount - 1))  # after RS + relay-in
    for f in fs.flows:
        same_host = topo.host_of(f.src) == topo.host_of(f.dst)
        if f.step in inter_steps:
            assert {f.src, f.dst} <= leaders and not same_host
        else:
            assert same_host  # every other phase stays on NVLink


def test_hierarchical_wire_bytes_vs_flat_ring():
    topo = dgx_cluster(2)
    group = tuple(topo.accelerators)
    p, m, hcount = 16, 8, 2
    n = 1024 * 16
    task = CommTask("ar", "all_reduce", n, group)
    fs = generate_flows(task, "hierarchical", hosts=topo.hosts)
    # closed-form byte accounting: 2 intra ring passes + leader relay both
    # ways + leader ring all-reduce
    expected = 2 * hcount * (m - 1) * n + 2 * hcount * (m - 1) * (n // m) \
        + 2 * (hcount - 1) * n
    assert sum(f.size_bytes for f in fs.flows) == expected
    # NIC-tier (cross-host) bytes: hierarchical crosses only via leaders,
    # strictly less than the flat ring's crossings
    def crossing(flows):
        return sum(f.size_bytes for f in flows
                   if topo.host_of(f.src) != topo.host_of(f.dst))
    ring_fs = generate_flows(task, "ring")
    assert crossing(fs.flows) == 2 * (hcount - 1) * n
    assert crossing(fs.flows) < crossing(ring_fs.flows)


def test_hierarchical_closed_form_registered():
    cp = CostParams(alpha=1e-6, link_bw=150e9, inter_bw=25e9, gpus_per_host=8)
    c = algo_cost("all_reduce", "hierarchical", 2 ** 24, 16, cp)
    assert c > 0
    # large payload: hierarchical beats flat ring priced at the NIC tier
    ring = algo_cost("all_reduce", "ring", 2 ** 24, 16,
                     CostParams(alpha=1e-6, link_bw=25e9))
    assert c < ring
    with pytest.raises(KeyError):
        algo_cost("all_reduce", "hierarchical", 2 ** 24, 16, CostParams())


def test_flowsim_vs_alphabeta_crossover_on_dgx():
    """Selection must flip latency-optimal -> hierarchical as payload grows,
    under BOTH models, near where the closed form predicts (satellite)."""
    topo = dgx_cluster(2)
    group = tuple(topo.accelerators)
    ab, fsim = AlphaBeta.from_topology(topo), FlowSim(topo)
    assert ab.params.gpus_per_host == 8
    assert ab.params.inter_bw == pytest.approx(25e9)

    def pick(model, size):
        return select_for_task(
            CommTask("t", "all_reduce", size, group), model).algorithm

    def flip_size(model):
        lo, hi = 2 ** 10, 2 ** 30
        while lo < hi:
            mid = (lo + hi) // 2
            if pick(model, mid) == "hierarchical":
                hi = mid
            else:
                lo = mid + 1
        return lo

    for model in (ab, fsim):
        assert pick(model, 2 ** 12) != "hierarchical"  # latency regime
        assert pick(model, 2 ** 26) == "hierarchical"  # bandwidth regime
    ab_flip, fs_flip = flip_size(ab), flip_size(fsim)
    assert ab_flip / 8 <= fs_flip <= ab_flip * 8


def test_flowsim_memoizes_selection_key():
    topo = dgx_cluster(2)
    fsim = FlowSim(topo)
    g = tuple(topo.accelerators)
    c1 = fsim.cost(CommTask("a", "all_reduce", 2 ** 20, g), "ring")
    c2 = fsim.cost(CommTask("b", "all_reduce", 2 ** 20, g), "ring")
    assert c1 == c2
    assert len(fsim._cost_memo) == 1  # task_id is not part of the key


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------


def test_packed_placement_keeps_tp_groups_intra_host():
    topo = dgx_cluster(2)
    pl = place_mesh(DP2_TP8, topo, "packed")
    assert pl.model_groups() == [tuple(range(8)), tuple(range(8, 16))]
    for g in pl.model_groups():
        assert len({topo.host_of(d) for d in g}) == 1
    # DP pairs necessarily cross hosts
    for g in pl.data_groups():
        assert len({topo.host_of(d) for d in g}) == 2


def test_strided_placement_scatters_tp_groups():
    topo = dgx_cluster(2)
    pl = place_mesh(DP2_TP8, topo, "strided")
    assert sorted(pl.devices) == list(topo.accelerators)
    for g in pl.model_groups():
        assert len({topo.host_of(d) for d in g}) == 2  # the anti-pattern


def test_place_demand_resolves_axis_tagged_groups():
    topo = dgx_cluster(2)
    pl = place_mesh(DP2_TP8, topo, "packed")
    dem = build_demand(get_config("granite-3-8b"), SHAPE, DP2_TP8)
    placed = pl.place_demand(dem)
    assert len(placed.comm_tasks) == len(dem.comm_tasks)
    accel = set(topo.accelerators)
    for t in placed.comm_tasks:
        assert set(t.group) <= accel
        if t.axis == "model":
            assert t.group == tuple(range(8))
        if t.axis == "data":
            assert t.group == (0, 8)


def test_placement_validation_errors():
    topo = dgx_cluster(2)
    with pytest.raises(ValueError):
        place_mesh(MeshConfig(shape=(64,), axis_names=("data",),
                              data_axes=("data",), model_axes=()), topo)
    with pytest.raises(ValueError):
        place_mesh(DP2_TP8, topo, "diagonal")
    with pytest.raises(ValueError):
        Placement(mesh=DP2_TP8, devices=(0,) * 16)  # duplicates
    with pytest.raises(ValueError):
        place_mesh(DP2_TP8, topo, "custom", custom=list(range(100, 116)))


def test_strided_placement_on_hostless_topology():
    topo = torus2d(4, 4)
    pl = place_mesh(DP2_TP8, topo, "strided")
    assert sorted(pl.devices) == list(topo.accelerators)


# ---------------------------------------------------------------------------
# end-to-end plan_iteration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["granite-3-8b", "dbrx-132b", "mamba2-130m"])
@pytest.mark.parametrize("make_topo", [lambda: dgx_cluster(2),
                                       lambda: fat_tree(2)],
                         ids=["dgx_cluster", "fat_tree"])
def test_plan_iteration_end_to_end(arch, make_topo):
    topo = make_topo()
    rep = plan_iteration(get_config(arch), SHAPE, DP2_TP8, topo,
                         policy="priority", hotspot_k=64)
    dem = build_demand(get_config(arch), SHAPE, DP2_TP8)
    assert rep.jct >= rep.compute_time - 1e-9
    assert len(rep.choices) == len(dem.comm_tasks)
    accel = set(topo.accelerators)
    for c in rep.choices:
        assert set(c.group) <= accel
        assert c.algorithm in c.costs and c.cost_s == c.costs[c.algorithm]
    assert rep.sim.algo_choices  # scheduler recorded the CCL's answers
    loads = [b for _, b in rep.link_hotspots]
    assert loads == sorted(loads, reverse=True) and loads
    # the hot-spot map covers every communicator replica, not just the
    # representative one — host 1's devices must carry traffic too
    hot_devices = {d for (u, v), _ in rep.link_hotspots
                   for d in (u, v) if isinstance(d, int)}
    assert hot_devices & set(range(8, 16))


def test_hierarchical_wins_for_large_gradient_all_reduce_on_dgx():
    """Acceptance: on dgx_cluster with >=2 hosts the selected algorithm for
    large gradient all-reduces is hierarchical, with lower simulated JCT
    than forcing the flat ring."""
    topo = dgx_cluster(2)
    dp = DemandParams(zero1=False)  # gradient sync as all-reduce
    auto = plan_iteration(get_config("granite-3-8b"), SHAPE, DP16, topo,
                          policy="serial", dp_params=dp)
    ring = plan_iteration(get_config("granite-3-8b"), SHAPE, DP16, topo,
                          policy="serial", dp_params=dp,
                          force={"all_reduce": "ring"})
    grads = [c for c in auto.choices if c.primitive == "all_reduce"]
    assert grads and all(c.algorithm == "hierarchical" for c in grads)
    assert all(c.algorithm == "ring" for c in ring.choices)
    assert auto.jct < ring.jct
    assert auto.comm_time < ring.comm_time


def test_alphabeta_rejects_hierarchical_on_uneven_host_partition():
    """16 ranks strided over 3 hosts split 6/5/5: divisibility by
    gpus_per_host alone would accept hierarchical, but the physical
    partition cannot run it — selection must fall back, not crash later."""
    topo = dgx_cluster(3)
    rep = plan_iteration(get_config("qwen2-0.5b"), SHAPE, DP16, topo,
                         placement="strided", cost_model="alphabeta",
                         dp_params=DemandParams(zero1=False))
    algos = rep.algorithms_by_primitive()["all_reduce"]
    assert "hierarchical" not in algos and algos


def test_plan_iteration_selection_stays_fast():
    """Selection over a 40-layer demand must stay well under a second; the
    bound is loose for slow CI boxes but catches a lost memoization."""
    cfg = get_config("granite-3-8b")
    assert cfg.num_layers == 40
    t0 = time.perf_counter()
    plan_iteration(cfg, SHAPE, DP16, dgx_cluster(2), policy="priority",
                   dp_params=DemandParams(zero1=False))
    assert time.perf_counter() - t0 < 5.0


def test_placement_and_plan_iteration_are_deterministic():
    """plan_cluster (and everything stacked on plan_iteration) assumes
    replanning the same job yields the identical report."""
    topo = dgx_cluster(2)
    for strategy in ("packed", "strided"):
        assert place_mesh(DP2_TP8, topo, strategy).devices == \
            place_mesh(DP2_TP8, topo, strategy).devices
    cfg = get_config("qwen2-0.5b")
    r1 = plan_iteration(cfg, SHAPE, DP2_TP8, topo, policy="priority")
    r2 = plan_iteration(cfg, SHAPE, DP2_TP8, topo, policy="priority")
    assert r1.jct == r2.jct and r1.comm_time == r2.comm_time
    assert [c.algorithm for c in r1.choices] == \
        [c.algorithm for c in r2.choices]
    assert r1.link_hotspots == r2.link_hotspots


def test_flowsim_second_plan_hits_cache(monkeypatch):
    """Pricing the same demand twice through one FlowSim must not re-run
    the network simulator (the memoization plan_iteration relies on)."""
    import repro.ccl.select as select_mod
    topo = dgx_cluster(2)
    fsim = FlowSim(topo)
    calls = []
    real = select_mod.simulate_flowset
    monkeypatch.setattr(select_mod, "simulate_flowset",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    cfg = get_config("qwen2-0.5b")
    r1 = plan_iteration(cfg, SHAPE, DP16, topo, cost_model=fsim,
                        dp_params=DemandParams(zero1=False))
    first = len(calls)
    assert first > 0
    memo = len(fsim._cost_memo)
    r2 = plan_iteration(cfg, SHAPE, DP16, topo, cost_model=fsim,
                        dp_params=DemandParams(zero1=False))
    assert len(calls) == first          # second pass fully cached
    assert len(fsim._cost_memo) == memo
    assert r1.jct == r2.jct
    assert [c.algorithm for c in r1.choices] == \
        [c.algorithm for c in r2.choices]


# ---------------------------------------------------------------------------
# ATP in-network aggregation as a first-class selection candidate
# ---------------------------------------------------------------------------


def test_atp_wins_gradient_reduction_on_fat_tree_both_models():
    """Host-Net co-design: on a switched fat-tree (one worker per host) the
    in-network-aggregation all-reduce beats every host-level algorithm for
    latency-regime gradient chunks, under BOTH cost models."""
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=4.0)
    group = tuple(topo.accelerators)
    task = CommTask("grad", "all_reduce", 2 ** 20, group)
    for model in (AlphaBeta.from_topology(topo), FlowSim(topo)):
        sel = select_for_task(task, model)
        assert sel.algorithm == "atp", type(model).__name__
        assert sel.costs["atp"] < sel.costs["ring"]


def test_atp_degrades_with_switch_capacity():
    """Multi-tenant fallback: a group larger than the switch-memory budget
    loses the aggregation discount and atp stops winning."""
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=4.0)
    group = tuple(topo.accelerators)
    task = CommTask("grad", "all_reduce", 2 ** 20, group)
    full = FlowSim(topo)
    capped = FlowSim(topo, switch_capacity=4)
    assert capped.cost(task, "atp") > full.cost(task, "atp")
    assert select_for_task(task, capped).algorithm != "atp"
    ab = AlphaBeta.from_topology(topo)
    import dataclasses
    ab_capped = dataclasses.replace(
        ab, params=dataclasses.replace(ab.params, atp_capacity=4))
    assert ab_capped.cost(task, "atp") > ab.cost(task, "atp")
    assert select_for_task(task, ab_capped).algorithm != "atp"
    # capacity 0 = switch memory exhausted under BOTH models (None is the
    # unlimited sentinel, matching sched.atp.aggregation_switches)
    ab_zero = dataclasses.replace(
        ab, params=dataclasses.replace(ab.params, atp_capacity=0))
    assert select_for_task(task, ab_zero).algorithm != "atp"
    assert select_for_task(
        task, FlowSim(topo, switch_capacity=0)).algorithm != "atp"


def test_atp_selected_end_to_end_for_chunked_gradients():
    """plan_iteration offers atp for Lina-style chunked gradient syncs on a
    fat-tree and a tight switch budget pushes it back out."""
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=4.0)
    mesh = MeshConfig(shape=(8,), axis_names=("data",), data_axes=("data",),
                      model_axes=())
    dpp = DemandParams(zero1=False, grad_chunks=16)
    rep = plan_iteration(get_config("qwen2-0.5b"), SHAPE, mesh, topo,
                         dp_params=dpp)
    assert "atp" in rep.algorithms_by_primitive()["all_reduce"]
    capped = plan_iteration(get_config("qwen2-0.5b"), SHAPE, mesh, topo,
                            dp_params=dpp, switch_capacity=4)
    assert "atp" not in capped.algorithms_by_primitive()["all_reduce"]
    assert capped.comm_time >= rep.comm_time


def test_switch_capacity_rejected_for_unconfigured_instance_model():
    """switch_capacity must not silently diverge from what an instance
    cost model prices with: either they match or plan_iteration refuses."""
    topo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=4.0)
    mesh = MeshConfig(shape=(8,), axis_names=("data",), data_axes=("data",),
                      model_axes=())
    dpp = DemandParams(zero1=False, grad_chunks=16)
    with pytest.raises(ValueError):
        plan_iteration(get_config("qwen2-0.5b"), SHAPE, mesh, topo,
                       dp_params=dpp, cost_model=FlowSim(topo),
                       switch_capacity=4)
    # a matching budget passes, and a self-configured instance behaves
    # like the named model with the same capacity
    rep = plan_iteration(get_config("qwen2-0.5b"), SHAPE, mesh, topo,
                         dp_params=dpp,
                         cost_model=FlowSim(topo, switch_capacity=4),
                         switch_capacity=4)
    named = plan_iteration(get_config("qwen2-0.5b"), SHAPE, mesh, topo,
                           dp_params=dpp, switch_capacity=4)
    assert rep.algorithms_by_primitive() == named.algorithms_by_primitive()
    assert rep.link_hotspots == named.link_hotspots
    # an AlphaBeta instance carrying the same budget is accepted too
    import dataclasses
    ab = AlphaBeta.from_topology(topo)
    ab4 = dataclasses.replace(
        ab, params=dataclasses.replace(ab.params, atp_capacity=4))
    plan_iteration(get_config("qwen2-0.5b"), SHAPE, mesh, topo,
                   dp_params=dpp, cost_model=ab4, switch_capacity=4)


def test_atp_not_offered_on_switchless_fabrics():
    """ICI-style fabrics have no programmable aggregation point."""
    topo = torus2d(4, 4)
    group = tuple(topo.accelerators)
    task = CommTask("grad", "all_reduce", 2 ** 20, group)
    sel = select_for_task(task, FlowSim(topo))
    assert "atp" not in sel.costs and "atp" in sel.excluded


def test_packed_beats_strided_placement_for_tp():
    """Placement matters (the codesign claim): TP all-reduces priced on the
    real topology are cheaper when the TP group stays on NVLink."""
    topo = dgx_cluster(2)
    cfg = get_config("granite-3-8b")
    packed = plan_iteration(cfg, SHAPE, DP2_TP8, topo, policy="serial",
                            placement="packed")
    strided = plan_iteration(cfg, SHAPE, DP2_TP8, topo, policy="serial",
                             placement="strided")
    assert packed.comm_time < strided.comm_time
    assert packed.jct <= strided.jct + 1e-9
