"""Continuous batching: staggered requests must produce exactly the same
tokens as running each request alone (per-slot positions + cache isolation
across recycled slots)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher


def _solo_reference(cfg, params, prompt, max_new):
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    b.submit(prompt, max_new, rid=0)
    done = b.run()
    return done[0].out


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-v2-236b",
                                  "mamba2-130m", "jamba-1.5-large-398b"])
def test_staggered_requests_match_solo(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12, 13, 14]]
    refs = [_solo_reference(cfg, params, p, 6) for p in prompts]

    # 2 slots, 3 requests: the third is admitted mid-flight into a
    # recycled slot while another slot is still generating
    b = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        b.submit(p, 6, rid=i)
    done = {r.rid: r.out for r in b.run()}
    assert set(done) == {0, 1, 2}
    for i in range(3):
        assert done[i] == refs[i], (arch, i, done[i], refs[i])


def test_slot_recycling_isolated():
    """A recycled slot must not leak the previous request's context."""
    cfg = smoke_config("qwen2-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    ref = _solo_reference(cfg, params, [3, 1, 4], 5)
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    b.submit([9, 9, 9, 9, 9, 9], 4, rid=0)  # pollute the slot first
    b.submit([3, 1, 4], 5, rid=1)
    done = {r.rid: r.out for r in b.run()}
    assert done[1] == ref


def test_long_prompt_rejected_up_front():
    """A prompt that cannot fit the cache (plus one generated token) must
    be rejected at submit (regression: it was admitted, hit the length
    stop mid-replay, and came back 'done' with garbage output)."""
    cfg = smoke_config("qwen2-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=8)
    with pytest.raises(ValueError, match="prompt"):
        b.submit(list(range(1, 10)), 3, rid=0)  # 9 tokens, 7 fit
    # the boundary prompt (max_len - 1 tokens) is admitted and generates
    b.submit(list(range(1, 8)), 3, rid=1)
    done = {r.rid: r.out for r in b.run()}
    assert len(done[1]) >= 1


def test_reset_slot_skips_aliased_axes():
    """Slot recycling must only zero axes that actually index slots.
    llama-3.2-vision interleaves cross-attention layers whose cache axis
    1 is the *context* batch — with max_slots equal to it (here 1), the
    old shape[1] == max_slots heuristic wiped the precomputed cross K/V
    for every tenant on every admit."""
    cfg = smoke_config("llama-3.2-vision-90b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ctx = jnp.ones((1, 6, cfg.d_model))
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=16, context=ctx)
    # every leaf set to ones, so zeroing is observable everywhere
    b.cache = jax.tree.map(jnp.ones_like, b.cache)
    b._reset_slot_state(0)
    axes = jax.tree_util.tree_leaves(b._slot_axis)
    leaves = jax.tree_util.tree_leaves(b.cache)
    assert any(ax < 0 for ax in axes), "no context-derived leaf found"
    for ax, leaf in zip(axes, leaves):
        if ax < 0:
            # cross K/V: no slot axis, must survive the recycle intact
            assert bool(jnp.all(leaf == 1.0))
        else:
            idx = (slice(None),) * ax + (0,)
            assert bool(jnp.all(leaf[idx] == 0.0))


def test_cross_attn_arch_recycles_slots_consistently():
    """End to end on the cross-attention arch: a request admitted into a
    recycled slot reproduces its solo output (needs the cross K/V to
    survive the earlier tenants' admits)."""
    cfg = smoke_config("llama-3.2-vision-90b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    ctx = jnp.ones((1, 6, cfg.d_model))

    solo = ContinuousBatcher(cfg, params, max_slots=1, max_len=32,
                             context=ctx)
    solo.submit([3, 1, 4], 5, rid=0)
    ref = solo.run()[0].out

    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=32, context=ctx)
    b.submit([9, 9, 9, 9], 4, rid=0)  # pollute the slot first
    b.submit([3, 1, 4], 5, rid=1)
    done = {r.rid: r.out for r in b.run()}
    assert done[1] == ref


def test_request_lifecycle_step_indices():
    """Each request records the batcher step at which it was admitted,
    emitted its first token, and finished — the measured-side mirror of
    the serving model's t_prefill/t_first/t_finish timestamps."""
    cfg = smoke_config("qwen2-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    b.submit([1, 2, 3], 4, rid=0)
    b.submit([5, 6], 3, rid=1)  # queued behind rid 0 (one slot)
    done = {r.rid: r for r in b.run()}
    for r in done.values():
        assert r.t_admit is not None
        assert r.t_first is not None
        assert r.t_finish is not None
        assert r.t_admit <= r.t_first <= r.t_finish
        # decode emits one token per step after the first
        assert r.t_finish - r.t_first == len(r.out) - 1
    # rid 1 waited for the slot: admitted strictly after rid 0 finished
    assert done[1].t_admit > done[0].t_admit
    assert done[1].t_admit >= done[0].t_finish
