"""Continuous batching: staggered requests must produce exactly the same
tokens as running each request alone (per-slot positions + cache isolation
across recycled slots)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher


def _solo_reference(cfg, params, prompt, max_new):
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    b.submit(prompt, max_new, rid=0)
    done = b.run()
    return done[0].out


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-v2-236b",
                                  "mamba2-130m", "jamba-1.5-large-398b"])
def test_staggered_requests_match_solo(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12, 13, 14]]
    refs = [_solo_reference(cfg, params, p, 6) for p in prompts]

    # 2 slots, 3 requests: the third is admitted mid-flight into a
    # recycled slot while another slot is still generating
    b = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        b.submit(p, 6, rid=i)
    done = {r.rid: r.out for r in b.run()}
    assert set(done) == {0, 1, 2}
    for i in range(3):
        assert done[i] == refs[i], (arch, i, done[i], refs[i])


def test_slot_recycling_isolated():
    """A recycled slot must not leak the previous request's context."""
    cfg = smoke_config("qwen2-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    ref = _solo_reference(cfg, params, [3, 1, 4], 5)
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    b.submit([9, 9, 9, 9, 9, 9], 4, rid=0)  # pollute the slot first
    b.submit([3, 1, 4], 5, rid=1)
    done = {r.rid: r.out for r in b.run()}
    assert done[1] == ref
