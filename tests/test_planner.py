"""Sharding planner: every param/cache spec must divide cleanly on both
production meshes for all 10 architectures; FSDP and ZeRO-1 extensions
must stay valid and never double-assign a mesh axis."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.core.types import (INPUT_SHAPES, MULTI_POD_MESH, SHAPES_BY_NAME,
                              SINGLE_POD_MESH)
from repro.launch.specs import cache_shapes
from repro.models.transformer import init_params
from repro.parallel.planner import (apply_fsdp, cache_specs, param_specs,
                                    validate_spec, zero1_spec)

MESHES = [SINGLE_POD_MESH, MULTI_POD_MESH]


def _shapes(cfg):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16))


def _check_tree(specs, shapes, mcfg):
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    leaves_h = jax.tree.leaves(shapes)
    assert len(leaves_s) == len(leaves_h)
    for sp, sh in zip(leaves_s, leaves_h):
        assert validate_spec(sp, sh.shape, mcfg), (sp, sh.shape)
        # no duplicate axis use
        used = [a for e in sp for a in
                (e if isinstance(e, tuple) else (e,)) if a]
        assert len(used) == len(set(used)), sp


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mcfg", MESHES, ids=["1pod", "2pod"])
def test_param_specs_valid(arch, mcfg):
    cfg = get_config(arch)
    shapes = _shapes(cfg)
    specs = param_specs(cfg, mcfg)
    _check_tree(specs, shapes, mcfg)
    fsdp = apply_fsdp(specs, shapes, mcfg)
    _check_tree(fsdp, shapes, mcfg)
    z1 = jax.tree.map(lambda sp, sh: zero1_spec(sp, sh.shape, mcfg),
                      fsdp, shapes, is_leaf=lambda x: isinstance(x, P))
    _check_tree(z1, shapes, mcfg)


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-130m",
                                  "deepseek-v2-236b",
                                  "jamba-1.5-large-398b"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    shapes = _shapes(cfg)
    c_shapes = cache_shapes(cfg, shape, shapes)
    for mcfg in MESHES:
        specs = cache_specs(cfg, mcfg, shape.global_batch, c_shapes)
        _check_tree(specs, c_shapes, mcfg)


def test_tp_shards_the_big_weights():
    """The planner must actually shard the dominant weights (not fall back
    to replication) for TP-friendly archs."""
    cfg = get_config("granite-3-8b")
    specs = param_specs(cfg, SINGLE_POD_MESH)
    g = specs["group0"]["pos0"]
    assert g["mixer"]["wq"] == P(None, None, "model", None)
    assert g["ffn"]["w_gate"] == P(None, None, "model")
    assert g["ffn"]["w_down"] == P(None, "model", None)


def test_qwen2_attention_replicates_with_note():
    """14 heads don't divide tp=16: attention weights stay replicated and
    the planner records why."""
    cfg = get_config("qwen2-0.5b")
    notes = []
    specs = param_specs(cfg, SINGLE_POD_MESH, notes)
    g = specs["group0"]["pos0"]
    assert g["mixer"]["wq"] == P(None, None, None, None)
    assert any("wq" in n for n in notes)
    # but the FFN still shards
    assert g["ffn"]["w_gate"] == P(None, None, "model")


def test_moe_experts_shard_over_model_axis():
    cfg = get_config("deepseek-v2-236b")
    specs = param_specs(cfg, SINGLE_POD_MESH)
    moe = specs["group1"]["pos0"]["ffn"]
    assert moe["w_gate"] == P(None, "model", None, None)  # 160 experts / 16
    assert moe["router"] == P(None, None, None)


def test_zero1_adds_data_axis():
    sp = zero1_spec(P(None, "model"), (4096, 12800), SINGLE_POD_MESH)
    assert sp == P("data", "model")
    # already-fsdp spec unchanged
    sp2 = zero1_spec(P("data", "model"), (4096, 12800), SINGLE_POD_MESH)
    assert sp2 == P("data", "model")
