"""Horizontal multi-job cluster planner: device carving, contention
detection via the network layer, and CASSINI staggering wired to real
CodesignReports (paper Sec. IV-A "Horizontal")."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# the canonical contended two-tenant scenario lives next to the benchmark
# harness so CI assertions, recorded numbers, and this suite cannot drift
from benchmarks.paper_claims import _contended_cluster

from repro.codesign import JobSpec, plan_cluster
from repro.configs import get_config
from repro.core.demand_builder import DemandParams
from repro.core.types import MeshConfig, SHAPES_BY_NAME
from repro.net.topology import dgx_cluster

DP8 = MeshConfig(shape=(8,), axis_names=("data",), data_axes=("data",),
                 model_axes=())
SHAPE = SHAPES_BY_NAME["train_4k"]
DPP = DemandParams(zero1=False)


def test_two_jobs_on_hot_link_stagger_strictly_beats_naive():
    """Acceptance: two jobs pressing the same uplinks — staggered worst-case
    JCT is strictly better than the zero-phase naive plan."""
    jobs, topo = _contended_cluster()
    rep = plan_cluster(jobs, topo, grid=6)
    assert rep.contended, "jobs spanning both racks must share uplinks"
    for users in rep.contended.values():
        assert set(users) == {"jobA", "jobB"}
    # naive collision visibly stretches the worst job ...
    assert rep.naive_worst_stretch > 1.01
    # ... and phase staggering strictly recovers it
    assert rep.staggered_worst_stretch < rep.naive_worst_stretch - 1e-6
    assert rep.stagger_speedup > 1.0
    assert rep.phases["jobA"] == 0.0  # job 0 is the pinned reference
    assert any(p > 0 for p in rep.phases.values())
    # contended-link demands were derived for both jobs
    for name in ("jobA", "jobB"):
        assert rep.link_demands[name]
        assert all(0 < d <= 1.0 for d in rep.link_demands[name].values())


def test_cluster_report_consistency():
    jobs, topo = _contended_cluster()
    rep = plan_cluster(jobs, topo, grid=4)
    assert set(rep.naive_jct) == {"jobA", "jobB"} == set(rep.staggered_jct)
    for jp in rep.jobs:
        # profile compresses the job's own CodesignReport
        assert jp.profile.period == pytest.approx(jp.report.jct)
        # the burst pressed onto shared links is the *exposed* comm: an
        # overlapped plan hides most of comm_time behind compute, and the
        # horizontal layer must not bill the hidden part as a pulse
        assert jp.profile.comm_s == pytest.approx(jp.report.exposed_comm)
        assert jp.report.exposed_comm <= jp.report.comm_time + 1e-9
        # the per-job link map covers the links it was contended on
        for link, users in rep.contended.items():
            if jp.spec.name in users:
                assert jp.link_bytes[link] > 0
    # stretches are relative to the solo period
    for name, jct in rep.staggered_jct.items():
        assert jct >= rep.solo_jct[name] * 0.97


def test_single_job_staggering_is_noop():
    jobs, topo = _contended_cluster()
    rep = plan_cluster([jobs[0]], topo)
    assert rep.contended == {}
    assert rep.phases == {jobs[0].name: 0.0}
    assert rep.naive_jct == rep.staggered_jct == rep.solo_jct
    assert rep.stagger_speedup == 1.0


def test_disjoint_jobs_have_no_contention():
    """Two jobs each inside its own DGX host share no links: naive ==
    staggered == solo."""
    topo = dgx_cluster(2)
    cfg = get_config("qwen2-0.5b")
    jobs = [JobSpec("a", cfg, SHAPE, DP8, devices=topo.hosts[0],
                    dp_params=DPP),
            JobSpec("b", cfg, SHAPE, DP8, devices=topo.hosts[1],
                    dp_params=DPP)]
    rep = plan_cluster(jobs, topo)
    assert rep.contended == {}
    assert rep.naive_jct == rep.staggered_jct == rep.solo_jct


def test_first_fit_carving_assigns_disjoint_blocks():
    topo = dgx_cluster(2)
    cfg = get_config("qwen2-0.5b")
    jobs = [JobSpec("a", cfg, SHAPE, DP8, dp_params=DPP),
            JobSpec("b", cfg, SHAPE, DP8, dp_params=DPP)]
    rep = plan_cluster(jobs, topo)
    assert rep.jobs[0].devices == tuple(range(8))
    assert rep.jobs[1].devices == tuple(range(8, 16))
    # explicit devices are honored and first-fit fills around them
    jobs2 = [JobSpec("a", cfg, SHAPE, DP8, dp_params=DPP),
             JobSpec("b", cfg, SHAPE, DP8, devices=tuple(range(8)),
                     dp_params=DPP)]
    rep2 = plan_cluster(jobs2, topo)
    assert rep2.jobs[1].devices == tuple(range(8))
    assert rep2.jobs[0].devices == tuple(range(8, 16))


def test_cluster_validation_errors():
    topo = dgx_cluster(2)
    cfg = get_config("qwen2-0.5b")
    with pytest.raises(ValueError):
        plan_cluster([], topo)
    with pytest.raises(ValueError):  # duplicate names
        plan_cluster([JobSpec("x", cfg, SHAPE, DP8, dp_params=DPP),
                      JobSpec("x", cfg, SHAPE, DP8, dp_params=DPP)], topo)
    with pytest.raises(ValueError):  # overlapping explicit devices
        plan_cluster(
            [JobSpec("a", cfg, SHAPE, DP8, devices=tuple(range(8))),
             JobSpec("b", cfg, SHAPE, DP8, devices=tuple(range(4, 12)))],
            topo)
    with pytest.raises(ValueError):  # cluster too small
        plan_cluster([JobSpec("a", cfg, SHAPE, DP8),
                      JobSpec("b", cfg, SHAPE, DP8),
                      JobSpec("c", cfg, SHAPE, DP8)], topo)
    with pytest.raises(ValueError):  # device count != mesh size
        plan_cluster([JobSpec("a", cfg, SHAPE, DP8,
                              devices=tuple(range(4)))], topo)


def test_plan_cluster_is_deterministic():
    jobs, topo = _contended_cluster()
    r1 = plan_cluster(jobs, topo, grid=4)
    r2 = plan_cluster(jobs, topo, grid=4)
    assert r1.phases == r2.phases
    assert r1.naive_jct == r2.naive_jct
    assert r1.staggered_jct == r2.staggered_jct
    assert list(r1.contended) == list(r2.contended)
