"""Per-architecture smoke tests: reduced config (2 layers, d_model<=256,
<=4 experts), one forward + one train step on CPU; output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.core.types import TrainConfig
from repro.data.stubs import audio_frames, vision_patches
from repro.models import encode, forward, init_params
from repro.optim.adamw import init_opt_state
from repro.train.step import make_train_step

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encoder_decoder:
        batch["context"] = jnp.asarray(audio_frames(cfg, B))
    elif cfg.cross_attn_period:
        batch["context"] = jnp.asarray(vision_patches(cfg, B))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    context = batch.get("context")
    if cfg.is_encoder_decoder:
        context = encode(cfg, params, context)
    logits, aux = forward(cfg, params, batch["tokens"], context=context)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"
    if cfg.is_moe:
        assert float(aux) > 0.0  # load-balance loss active


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_and_finite(arch):
    cfg = smoke_config(arch)
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=1, total_steps=20,
                       remat=False, weight_decay=0.0)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, key)
    losses = []
    for _ in range(5):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), f"{arch}: NaN loss {losses}"
    assert losses[-1] < losses[0], \
        f"{arch}: loss should drop on repeated batch {losses}"


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks)."""
    g = get_config("granite-3-8b")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads,
            g.d_ff, g.vocab_size) == (40, 4096, 32, 8, 12800, 49155)
    d = get_config("deepseek-v2-236b")
    assert (d.num_layers, d.d_model, d.num_experts, d.top_k,
            d.kv_lora_rank, d.num_shared_experts) == (60, 5120, 160, 6,
                                                      512, 2)
    j = get_config("jamba-1.5-large-398b")
    assert (j.num_layers, j.attn_period, j.num_experts, j.top_k,
            j.moe_layer_period) == (72, 8, 16, 2, 2)
    specs = j.layer_specs()
    assert sum(1 for s in specs if s.mixer == "attn") == 9
    assert sum(1 for s in specs if s.ffn == "moe") == 36
    lv = get_config("llama-3.2-vision-90b")
    assert sum(1 for s in lv.layer_specs() if s.mixer == "cross_attn") == 20
    q = get_config("qwen2-0.5b")
    assert q.qkv_bias and q.tie_embeddings
    m = get_config("mamba2-130m")
    assert m.attention == "none" and m.ssm_state == 128


def test_param_counts_match_names():
    """Total parameter counts should match the model names (~+-15%)."""
    expected = {
        "granite-3-8b": 8e9, "mamba2-130m": 0.13e9,
        "h2o-danube-1.8b": 1.8e9, "deepseek-v2-236b": 236e9,
        "dbrx-132b": 132e9, "llama-3.2-vision-90b": 90e9,
        "jamba-1.5-large-398b": 398e9, "qwen2-0.5b": 0.5e9,
        "starcoder2-3b": 3e9,
    }
    for arch, n in expected.items():
        total = get_config(arch).param_counts()["total"]
        assert 0.8 * n < total < 1.25 * n, (arch, total, n)
