"""Decode-path equivalence: step-by-step decode against the cache must
reproduce the full forward logits for every architecture family (GQA ring
buffers, MLA latent cache, Mamba recurrence, hybrid, enc-dec, VLM)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro.data.stubs import audio_frames, vision_patches
from repro.models import decode_step, encode, forward, init_cache, init_params

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    context = None
    if cfg.is_encoder_decoder:
        context = encode(cfg, params, jnp.asarray(audio_frames(cfg, B)))
    elif cfg.cross_attn_period:
        context = jnp.asarray(vision_patches(cfg, B))
    full, _ = forward(cfg, params, tokens, context=context)
    cache = init_cache(cfg, params, B, S, context=context)
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], t)
        err = float(jnp.abs(logits[:, 0] - full[:, t]).max())
        assert err < 2e-4, f"{arch} step {t}: err={err}"


def test_sliding_window_ring_buffer():
    """With window W, the ring-buffer decode must equal a full forward that
    uses the same window, even past the buffer wrap-around."""
    cfg = smoke_config("h2o-danube-1.8b")
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=8)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    s = 24  # > 2x window: buffer wraps
    tokens = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    full, _ = forward(cfg, params, tokens)
    cache = init_cache(cfg, params, B, s)
    assert cache["group0"]["pos0"]["k"].shape[2] == 8  # ring slots == window
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for t in range(s):
        logits, cache = step(params, cache, tokens[:, t:t + 1], t)
        err = float(jnp.abs(logits[:, 0] - full[:, t]).max())
        assert err < 2e-4, f"wrap step {t}: err={err}"


def test_long_context_window_policy():
    from repro.core.types import LONG_500K, DECODE_32K
    from repro.configs import get_config
    from repro.launch.specs import decode_window, uses_swa_variant
    # native long-context archs
    for arch in ("mamba2-130m", "jamba-1.5-large-398b", "deepseek-v2-236b",
                 "h2o-danube-1.8b"):
        assert decode_window(get_config(arch), LONG_500K) is None, arch
    # SWA-variant archs
    for arch in ("granite-3-8b", "qwen2-0.5b", "starcoder2-3b", "dbrx-132b",
                 "llama-3.2-vision-90b", "seamless-m4t-medium"):
        assert uses_swa_variant(get_config(arch), LONG_500K), arch
        assert not uses_swa_variant(get_config(arch), DECODE_32K), arch
