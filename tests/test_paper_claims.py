"""Regression guards for benchmarks/paper_claims.py headline numbers.

The benchmark harness prints derived metrics but nothing failed CI when
they drifted; these tests lock in the orderings PR 1 claimed (and the
cluster/ATP claims this PR adds) without pinning fragile exact values."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.ccl.select import AlphaBeta, FlowSim, select_for_task
from repro.core.demand import CommTask
from repro.net.topology import dgx_cluster


def test_hierarchical_beats_flat_ring_on_dgx_under_both_cost_models():
    """PR 1's benchmark claim: for large gradient syncs on dgx_cluster the
    Intra-Inter hierarchical all-reduce beats the topology-blind flat ring
    — under the closed-form AND the topology-priced model."""
    topo = dgx_cluster(2)
    group = tuple(topo.accelerators)
    task = CommTask("grad", "all_reduce", 64 * 2 ** 20, group)
    for model in (AlphaBeta.from_topology(topo), FlowSim(topo)):
        sel = select_for_task(task, model)
        assert sel.algorithm == "hierarchical", type(model).__name__
        assert sel.costs["hierarchical"] < sel.costs["ring"]
        # the win is structural (NIC-tier bytes), not a rounding artifact
        assert sel.costs["ring"] / sel.costs["hierarchical"] > 1.1


def test_bench_codesign_hierarchical_number_holds():
    """The end-to-end benchmark (demand -> placement -> selection -> JCT)
    must keep showing auto-selection beating forced flat ring."""
    from benchmarks.paper_claims import bench_codesign_hierarchical
    derived, details = bench_codesign_hierarchical()
    assert derived > 1.2  # comm-time speedup of auto vs forced ring
    assert "hierarchical" in details["selected"]
    assert details["auto_jct_s"] <= details["ring_jct_s"]


def test_bench_cluster_stagger_number_holds():
    """The horizontal-planner benchmark: staggering two tenants on shared
    uplinks must recover worst-case JCT."""
    from benchmarks.paper_claims import bench_cluster_planner
    derived, details = bench_cluster_planner()
    assert derived > 1.0
    assert details["contended_links"] >= 1
    assert details["staggered_worst_stretch"] < \
        details["naive_worst_stretch"]


def test_bench_atp_candidate_number_holds():
    """The Host-Net benchmark: atp wins the latency-regime gradient chunk
    on a switched fat-tree and loses it when switch memory is exhausted."""
    from benchmarks.paper_claims import bench_atp_candidate
    derived, details = bench_atp_candidate()
    assert derived > 1.0
    assert details["selected"] == "atp"
    assert details["capped_selected"] != "atp"


def test_bench_placement_search_number_holds():
    """The placement-search benchmark: search() over the placement knob
    strictly beats packed on the oversubscribed fat-tree (the balanced
    host split unlocks hierarchical) and attributes the win."""
    from benchmarks.paper_claims import bench_placement_search
    derived, details = bench_placement_search()
    assert derived > 1.2  # packed/searched JCT
    assert details["best_strategy"] == "balanced"
    assert details["searched_jct_s"] < details["packed_jct_s"]
    assert details["attribution_jct_s"]["placement"] > 0
    assert "hierarchical" in details["best_algorithms"]["all_reduce"]
    # the persisted plan is a JSON-able device list
    import json
    assert json.dumps(details["best_plan"])
    assert sorted(set(details["best_plan"]["devices"])) == \
        details["best_plan"]["devices"] != list(range(24))


def test_bench_overlap_search_number_holds():
    """The overlap-search benchmark: jointly searched bucket-size +
    decompose + policy strictly beats the naive overlap schedule under
    BOTH cost models, and beats the policy-only syndicate row (1.16x) —
    reshaping the DAG must buy more than reordering it."""
    from benchmarks.paper_claims import bench_overlap_search
    derived, details = bench_overlap_search()
    assert derived > 1.16
    for cm in ("alphabeta", "flowsim"):
        d = details[cm]
        assert d["searched_jct_s"] < d["naive_jct_s"]
        assert d["searched_exposed_s"] < d["naive_exposed_s"]
        assert d["best_assignment"]["decompose"] is True
        assert d["attribution_jct_s"]["decompose"] > 0


def test_bench_compression_candidate_number_holds():
    """The compression benchmark: a 1% error budget wins the bandwidth-
    regime gradient sync on the oversubscribed fat-tree, rejects
    compression in the latency regime, and strictly lowers e2e JCT."""
    from benchmarks.paper_claims import bench_compression_candidate
    derived, details = bench_compression_candidate()
    assert derived > 1.5  # compressed vs best lossless candidate
    assert details["selected_64MiB"].endswith("+q8")
    assert "+" not in details["latency_regime_pick"]
    assert details["e2e_jct_s"]["budget_1pct"] < \
        details["e2e_jct_s"]["lossless"]
    assert details["wire_GiB_saved"] > 0


def test_bench_synth_codesign_number_holds():
    """The synthesis benchmark: synthesized schedules beat the registry
    under BOTH cost models where topology-specific routing pays (fat-tree
    broadcast at 1-4 MiB, flat-mesh latency-regime all-reduce end to
    end), never get selected where they lose, and search() attributes
    the JCT win to the synthesize knob."""
    from benchmarks.paper_claims import bench_synth_codesign
    derived, details = bench_synth_codesign()
    assert derived > 1.2  # knob-off/knob-on JCT, weaker cost model
    ft = details["fat_tree_broadcast"]
    for size in ("1024KiB", "4096KiB"):
        for cm in ("alphabeta", "flowsim"):
            assert ft[size][cm]["picked"] == "synthesized", (size, cm)
            assert ft[size][cm]["speedup"] > 1.0
    # the losing regime stays lost: binomial's fewer alphas win at 64KiB
    assert ft["64KiB"]["alphabeta"]["picked"] == "binomial"
    assert details["ring_never_selected"]["n_synthesized_tasks"] == 0
    for cm in ("alphabeta", "flowsim"):
        d = details[cm]
        assert d["searched_jct_s"] < d["off_jct_s"]
        assert d["best_assignment"] == {"synthesize": True}
        assert d["attribution_jct_s"]["synthesize"] > 0
        assert d["n_synthesized_tasks"] > 0
