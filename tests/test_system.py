"""End-to-end behaviour: real training run on the synthetic pipeline (loss
must drop well below the uniform baseline), checkpoint round-trip,
serving loop, pipeline parallelism."""
import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_multidevice
from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.configs import smoke_config
from repro.core.types import TrainConfig
from repro.data.pipeline import SyntheticLM, make_batches
from repro.models import decode_step, forward, init_cache, init_params
from repro.optim.adamw import init_opt_state
from repro.serve.step import make_serve_step
from repro.train.step import make_train_step


def test_training_learns_synthetic_pattern():
    cfg = smoke_config("qwen2-0.5b")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                       remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    batches = make_batches(cfg, batch_size=8, seq_len=64)
    first = last = None
    for i, batch in zip(range(40), batches):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, b)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    uniform = math.log(cfg.vocab_size)
    assert first == pytest.approx(uniform, rel=0.2)
    assert last < 0.8 * uniform, f"loss {first}->{last}, uniform {uniform}"


def test_data_pipeline_deterministic():
    ds = SyntheticLM(vocab_size=97, seq_len=32, seed=5)
    a = ds.batch(0, 0, 4)
    b = ds.batch(0, 0, 4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(0, 4, 4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_checkpoint_roundtrip():
    cfg = smoke_config("starcoder2-3b")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 7, params, opt, extra={"note": "t"})
        p2, o2, step = restore_checkpoint(path, params, opt)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_greedy_matches_forward_argmax():
    cfg = smoke_config("granite-3-8b")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    cache = init_cache(cfg, params, 2, 32)
    serve = jax.jit(make_serve_step(cfg))
    # feed the prompt through decode steps, then generate 4 tokens
    tok = None
    for t in range(8):
        tok, logits, cache = serve(params, cache, prompt[:, t:t + 1], t,
                                   key)
    full, _ = forward(cfg, params, prompt)
    np.testing.assert_array_equal(
        np.asarray(tok[:, 0]), np.asarray(jnp.argmax(full[:, -1], -1)))
    # sampled tokens stay inside the true vocab (padding masked)
    for t in range(8, 12):
        tok, _, cache = serve(params, cache, tok, t, key)
        assert int(tok.max()) < cfg.vocab_size


PIPELINE_SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import make_pipeline_fn, bubble_fraction

P_STAGES, M, MB, D = 4, 8, 2, 16
mesh = jax.make_mesh((P_STAGES,), ("pipe",))
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (P_STAGES, D, D)) * 0.2

def stage_fn(wi, x):
    return jnp.tanh(x @ wi)

pipe = make_pipeline_fn(stage_fn, mesh, "pipe")
x = jax.random.normal(jax.random.fold_in(key, 1), (M, MB, D))
got = pipe({"w": w}["w"], x)
# sequential reference
ref = x
for s in range(P_STAGES):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
print("fwd ok")

# autodiff through the pipeline (backward = reverse ppermutes)
def loss(w_, x_):
    return jnp.sum(pipe(w_, x_) ** 2)
g = jax.grad(lambda w_: loss(w_, x))(w)
def loss_ref(w_):
    r = x
    for s in range(P_STAGES):
        r = jnp.tanh(r @ w_[s])
    return jnp.sum(r ** 2)
g_ref = jax.grad(loss_ref)(w)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-4)
print("grad ok")
assert abs(bubble_fraction(4, 8, 1) - 3/8) < 1e-9
assert abs(bubble_fraction(4, 8, 2) - 3/16) < 1e-9
print("OK")
"""


def test_pipeline_parallelism_multidevice():
    """GPipe pipeline over a 4-stage mesh axis: forward and gradients match
    the sequential model; PTD-P interleave halves the bubble."""
    run_multidevice(PIPELINE_SCRIPT, num_devices=4)


INTERLEAVED_SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.pipeline import interleaved_pipeline_apply

P_, V, M, MB, D = 4, 2, 6, 2, 8
mesh = jax.make_mesh((P_,), ("pipe",))
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (P_, V, D, D)) * 0.3
x = jax.random.normal(jax.random.fold_in(key, 1), (M, MB, D))

def stage_fn(wc, xx):
    return jnp.tanh(xx @ wc)

def body(w_local, x_all):
    return interleaved_pipeline_apply(stage_fn, w_local[0], x_all,
                                      "pipe", P_, V)
got = jax.jit(jax.shard_map(body, mesh=mesh,
                            in_specs=(P("pipe"), P()),
                            out_specs=P()))(w, x)
# sequential reference: virtual stage k = device k%p, chunk k//p
ref = x
for k in range(V * P_):
    ref = jnp.tanh(ref @ w[k % P_, k // P_])
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
print("interleaved fwd ok")

def loss(w_):
    return jnp.sum(jax.shard_map(
        lambda wl, xa: interleaved_pipeline_apply(
            stage_fn, wl[0], xa, "pipe", P_, V),
        mesh=mesh, in_specs=(P("pipe"), P()), out_specs=P())(w_, x) ** 2)
def loss_ref(w_):
    r = x
    for k in range(V * P_):
        r = jnp.tanh(r @ w_[k % P_, k // P_])
    return jnp.sum(r ** 2)
np.testing.assert_allclose(np.asarray(jax.grad(loss)(w)),
                           np.asarray(jax.grad(loss_ref)(w)), atol=1e-4)
print("interleaved grad ok")
print("OK")
"""


def test_interleaved_pipeline_multidevice():
    """PTD-P interleaved schedule (v=2 chunks/device): forward + gradients
    match the sequential virtual-stage composition."""
    run_multidevice(INTERLEAVED_SCRIPT, num_devices=4)
