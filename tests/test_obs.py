"""Observability layer (ISSUE 8): trace recorder + Chrome Trace export,
deterministic meters, search/FlowSim/dynamics telemetry, the export CLI,
and measured-vs-modeled collective probes."""
import itertools
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_claims import _placement_search_problem
from benchmarks.roofline import ARCH_ORDER, SHAPE_ORDER, _rank, load

from repro.ccl.cost import CostParams, algo_cost, cost_terms
from repro.ccl.select import FlowSim
from repro.codesign import (ClusterDynamics, CodesignProblem, DynamicsReport,
                            Event, JobSpec, PlanSpace, SearchResult, plan,
                            plan_cluster, search)
from repro.codesign.report import CodesignReport
from repro.configs import get_config
from repro.core.demand import CommDemand, CommTask, ComputeTask
from repro.core.demand_builder import DemandParams, build_demand
from repro.core.types import MeshConfig, SHAPES_BY_NAME
from repro.net.simulate import link_rate_series
from repro.net.topology import dgx_cluster, fat_tree, ring
from repro.obs import (EXPOSED_CNAME, Meters, Trace, timeline_tracks,
                       trace_from_cluster, trace_from_dynamics,
                       trace_from_report, trace_from_search, validate_chrome)
from repro.obs.export import build_trace, detect_kind, export_file
from repro.obs.export import main as export_main
from repro.sched.flows import JobProfile, stagger_jobs
from repro.sched.tasks import simulate_iteration
from repro.ccl.select import select_for_task

CFG = get_config("qwen2-0.5b")
SHAPE = SHAPES_BY_NAME["train_4k"]
DP2_TP8 = MeshConfig(shape=(2, 8), axis_names=("data", "model"))


@pytest.fixture(scope="module")
def dgx_plan():
    topo = dgx_cluster(2)
    rep = plan(CodesignProblem(CFG, SHAPE, DP2_TP8, topo,
                               space=PlanSpace().pinned(policy="priority")))
    return rep, topo


@pytest.fixture(scope="module")
def placement_search_result():
    problem = _placement_search_problem()
    return search(problem, budget=6), problem.topo


# ---------------------------------------------------------------------------
# Meters
# ---------------------------------------------------------------------------


def test_meters_counters_and_observations():
    m = Meters()
    m.incr("a")
    m.incr("a", 2.0)
    m.incr("b")
    assert m.get("a") == 3.0 and m.get("b") == 1.0 and m.get("zzz") == 0.0
    assert m.ratio("a", "b") == 0.75  # a / (a + b)
    assert m.ratio("nope", "also_nope") is None
    m.observe("x", 2.0)
    m.observe("x", 4.0)
    snap = m.snapshot()
    assert snap["x.count"] == 2.0 and snap["x.sum"] == 6.0
    assert snap["x.min"] == 2.0 and snap["x.max"] == 4.0
    assert list(snap) == sorted(snap)  # key-sorted flat dict


def test_meters_time_uses_injected_clock():
    ticks = itertools.count()
    m = Meters(clock=lambda: float(next(ticks)))
    with m.time("work"):
        pass
    snap = m.snapshot()
    assert snap["work.count"] == 1.0 and snap["work.sum"] == 1.0


def test_meters_merge():
    a, b = Meters(), Meters()
    a.incr("n", 2.0)
    b.incr("n", 3.0)
    b.observe("o", 1.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["n"] == 5.0 and snap["o.count"] == 1.0


# ---------------------------------------------------------------------------
# Trace recorder + validator
# ---------------------------------------------------------------------------


def test_trace_event_format_and_ordering():
    tr = Trace()
    tr.process(2, "late", sort_index=5)
    tr.process(1, "early")
    tr.thread(1, 0, "t0")
    tr.span("s", 1e-6, 2e-6, pid=1, tid=0, cat="c", args={"k": 1})
    tr.counter("cnt", 0.0, {"b": 2.0, "a": 1.0}, pid=1, tid=1)
    tr.instant("i", 0.0, pid=2, tid=0, scope="p")
    evs = tr.events()
    # metadata first, then events sorted by (pid, tid, ts, ph, name)
    metas = [e for e in evs if e["ph"] == "M"]
    assert evs[:len(metas)] == metas
    assert [e["name"] for e in metas] == ["process_name", "process_name",
                                         "process_sort_index", "thread_name"]
    span = next(e for e in evs if e["ph"] == "X")
    assert span["ts"] == 1.0 and span["dur"] == 2.0  # seconds -> us
    assert validate_chrome(tr.to_chrome()) == []
    # negative durations are clamped at record time
    tr.span("neg", 0.0, -1.0, pid=1, tid=0)
    assert validate_chrome(tr.to_chrome()) == []


def test_validate_chrome_catches_malformed_docs():
    assert validate_chrome({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 0, "tid": 0, "ts": 0},
        {"ph": "X", "pid": 0, "tid": 0, "ts": 0, "dur": 1},
        {"ph": "X", "name": "x", "pid": 0, "tid": 0, "ts": "soon", "dur": 1},
        {"ph": "X", "name": "x", "pid": 0, "tid": 0, "ts": 0, "dur": -5},
        {"ph": "i", "name": "x", "pid": 0, "tid": 0, "ts": 0, "s": "q"},
    ]}
    problems = validate_chrome(bad)
    assert len(problems) == 5
    # overlapping spans on one (pid, tid) track
    overlap = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 0, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"ph": "X", "name": "b", "pid": 0, "tid": 0, "ts": 5.0, "dur": 10.0},
    ]}
    assert any("overlaps" in p for p in validate_chrome(overlap))
    # same spans on different tracks: fine
    ok = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 0, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"ph": "X", "name": "b", "pid": 0, "tid": 1, "ts": 5.0, "dur": 10.0},
    ]}
    assert validate_chrome(ok) == []


def test_timeline_tracks_exposed_spans():
    tr = Trace()
    timeline = [("comp:c0", 0.0, 1.0), ("comm:g", 0.0, 2.0),
                ("comp:c1", 2.0, 3.0)]
    timeline_tracks(tr, 1, "job", timeline, task_exposed_s={"g": 1.0})
    evs = tr.events()
    exposed = [e for e in evs if e["ph"] == "X"
               and e["name"] == "exposed:g"]
    assert len(exposed) == 1
    # stall interval = the last exposed_s seconds before the comm retires
    assert exposed[0]["ts"] == 1.0 * 1e6 and exposed[0]["dur"] == 1.0 * 1e6
    assert exposed[0]["cname"] == EXPOSED_CNAME
    comm = next(e for e in evs if e["ph"] == "X" and e["name"] == "g")
    assert comm["args"]["exposed_s"] == 1.0


# ---------------------------------------------------------------------------
# Report -> trace: determinism, round-trip, link counters
# ---------------------------------------------------------------------------


def test_report_trace_deterministic_and_roundtrips(dgx_plan):
    rep, topo = dgx_plan
    assert rep.timeline, "plan() must persist the executed timeline"
    doc = rep.to_trace(topo=topo).to_chrome()
    assert validate_chrome(doc) == []
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "C"} <= phs
    cats = {e.get("cat") for e in doc["traceEvents"]}
    assert {"compute", "comm"} <= cats
    # deterministic: same report, same bytes
    assert rep.to_trace(topo=topo).to_json() == \
        rep.to_trace(topo=topo).to_json()
    # from_dict-loaded report renders the identical trace (sim=None)
    loaded = CodesignReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert loaded.sim is None
    assert loaded.to_trace(topo=topo).to_json() == \
        rep.to_trace(topo=topo).to_json()
    # without the live topology there are no counter tracks, still valid
    bare = loaded.to_trace().to_chrome()
    assert validate_chrome(bare) == []
    assert not any(e["ph"] == "C" for e in bare["traceEvents"])


def test_report_trace_link_counters(dgx_plan):
    rep, topo = dgx_plan
    doc = rep.to_trace(topo=topo, max_links=4).to_chrome()
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert counters and all("bytes_per_s" in e["args"] for e in counters)
    names = {e["name"] for e in counters}
    assert all(n.startswith("link ") and n.endswith(" B/s") for n in names)
    assert len(names) <= 4


def test_sim_result_to_trace(dgx_plan):
    rep, _ = dgx_plan
    assert rep.sim is not None
    doc = rep.sim.to_trace(label="iter").to_chrome()
    assert validate_chrome(doc) == []
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# Scheduler timeline invariants under preemption
# ---------------------------------------------------------------------------


def test_preempt_truncates_stale_timeline_spans():
    """A preempted comm task's first timeline segment must end at the
    preemption point — the old code left the full-duration span in
    place, overlapping the preemptor on the single network resource."""
    dem = CommDemand()
    dem.compute_tasks = [ComputeTask("c0", 0, 10e-3)] + [
        ComputeTask(f"c{i}", 0, 25e-3) for i in range(1, 6)
    ] + [ComputeTask("opt", 0, 1e-3)]
    # grad starts right after c0; the blocking a2a only becomes ready
    # mid-grad (after c1), so the preemption truncates a span that has
    # genuinely run for a while
    dem.comm_tasks = [
        CommTask("grad", "all_reduce", int(100e-3 * 50e9), (0, 1),
                 after_compute=("c0",), before_compute="opt", slack=1.0),
        CommTask("a2a", "all_to_all", int(20e-3 * 50e9 * 2), (0, 1),
                 after_compute=("c1",), before_compute="c2", slack=0.0),
    ]
    cp = CostParams(alpha=1e-6, link_bw=50e9)

    def cost(t):
        algo = "direct" if t.primitive == "all_to_all" else "ring"
        return algo_cost(t.primitive, algo, t.size_bytes, len(t.group), cp)

    r = simulate_iteration(dem, cost, "preempt")
    comm = sorted((s, e, n) for n, s, e in r.timeline
                  if n.startswith("comm:"))
    assert len(comm) >= 3  # grad split around the preempting a2a
    for (s0, e0, n0), (s1, e1, n1) in zip(comm, comm[1:]):
        assert s1 >= e0 - 1e-12, f"{n1} overlaps {n0}"
    assert validate_chrome(r.to_trace().to_chrome()) == []


# ---------------------------------------------------------------------------
# net.simulate.link_rate_series
# ---------------------------------------------------------------------------


def test_link_rate_series_integrates_to_bytes():
    topo = ring(4)
    task = CommTask("ar", "all_reduce", 1 << 20, tuple(topo.accelerators))
    from repro.ccl.select import flows_on_topology
    fs = flows_on_topology(topo, task, "ring")
    series = link_rate_series(topo, [(fs, 0.0, 2.0), (fs, 3.0, 4.0)])
    assert series, "ring all-reduce must load some links"
    for points, ts in ((list(v), [t for t, _ in v])
                       for v in series.values()):
        assert ts == sorted(ts)          # breakpoints sorted
        assert points[-1][1] == 0.0      # closes back at zero rate
        assert all(r >= 0.0 for _, r in points)
    # integral over time recovers 2x the per-link bytes of one pass
    from repro.net.simulate import link_utilization
    util = link_utilization(topo, fs)
    for link, points in series.items():
        integral = sum(r * (points[i + 1][0] - t)
                       for i, (t, r) in enumerate(points[:-1]))
        assert integral == pytest.approx(2.0 * util[link], rel=1e-9)


# ---------------------------------------------------------------------------
# FlowSim memoization counters
# ---------------------------------------------------------------------------


def test_flowsim_cache_stats():
    topo = dgx_cluster(2)
    model = FlowSim(topo)
    task = CommTask("g", "all_reduce", 1 << 20, tuple(topo.accelerators))
    model.cost(task, "ring")
    model.cost(task, "ring")
    model.cost(task, "bidir_ring")
    stats = model.cache_stats()
    assert stats["flowsim[cap=None].cost.miss"] == 2.0
    assert stats["flowsim[cap=None].cost.hit"] == 1.0
    assert stats["flowsim[cap=None].cost.hit_rate"] == pytest.approx(1 / 3)
    assert stats["flowsim[cap=None].cost.entries"] == 2.0
    capped = FlowSim(topo, switch_capacity=4)
    capped.cost(task, "ring")
    assert "flowsim[cap=4].cost.miss" in capped.cache_stats()


# ---------------------------------------------------------------------------
# Search telemetry: per-candidate records + JSON round-trip
# ---------------------------------------------------------------------------


def test_search_telemetry_and_roundtrip(placement_search_result):
    res, topo = placement_search_result
    tel = res.telemetry
    assert tel["plan_evals"] == len(res.frontier)
    assert tel["charged_evals"] <= res.evaluated + tel["memo_hits"]
    assert tel["infeasible"] == sum(1 for c in res.frontier
                                    if not c.feasible)
    assert any(k.startswith("flowsim[") for k in tel["counters"])
    for c in res.frontier:
        assert c.phase in ("sweep", "hillclimb", "baseline")
        assert c.requests >= 1
        assert (c.reason is None) == c.feasible
    # JSON round-trip preserves the per-candidate telemetry
    d = json.loads(json.dumps(res.to_dict()))
    res2 = SearchResult.from_dict(d)
    assert res2.telemetry == tel
    assert [(c.phase, c.requests, c.reason) for c in res2.frontier] == \
        [(c.phase, c.requests, c.reason) for c in res.frontier]
    assert res2.to_dict() == d
    # search trace: winner tracks + frontier instants + jct counters,
    # identical when rebuilt from the persisted dict
    tr = res.to_trace(topo=topo)
    assert validate_chrome(tr.to_chrome()) == []
    evs = tr.to_chrome()["traceEvents"]
    assert sum(1 for e in evs if e["ph"] == "i"
               and e["name"] == "candidate") == len(res.frontier)
    assert any(e["ph"] == "i" and e["name"] == "telemetry" for e in evs)
    assert any(e["ph"] == "C" and e["name"] == "frontier jct" for e in evs)
    assert res2.to_trace(topo=topo).to_json() == tr.to_json()


def test_search_infeasible_candidates_carry_reason(
        placement_search_result):
    import dataclasses
    from repro.codesign import Objective
    res, _ = placement_search_result
    # a link-imbalance cap between the frontier's best and worst rules
    # out some candidates but keeps the winner feasible, so search()
    # returns and the pruned candidates carry their reason strings
    caps = sorted({c.worst_link_bytes for c in res.frontier})
    assert len(caps) >= 2, "fixture frontier must spread worst-link bytes"
    cap = (caps[0] + caps[-1]) / 2.0
    tight = dataclasses.replace(
        _placement_search_problem(),
        objective=Objective(max_worst_link_bytes=cap))
    tres = search(tight, budget=6)
    pruned = [c for c in tres.frontier if not c.feasible]
    assert pruned and tres.telemetry["infeasible"] == len(pruned)
    assert all("worst_link_bytes" in c.reason for c in pruned)
    assert all(c.reason is None for c in tres.frontier if c.feasible)


# ---------------------------------------------------------------------------
# Cluster + dynamics: stagger meters, fake clock, trace round-trips
# ---------------------------------------------------------------------------


def test_stagger_jobs_counts_evals():
    jobs = [JobProfile("a", 0.012, 0.008), JobProfile("b", 0.010, 0.010)]
    m = Meters()
    stagger_jobs(jobs, grid=5, meters=m)
    # zero-phase baseline + the 5-point grid over job b's phase
    assert m.get("flows.stagger.evals") == 6.0


def _dyn_setup():
    DP2 = MeshConfig(shape=(2,), axis_names=("data",), data_axes=("data",),
                     model_axes=())
    dpp = DemandParams(zero1=False)
    topo = fat_tree(num_hosts=4, gpus_per_host=1, hosts_per_rack=1,
                    racks_per_pod=1, agg_redundancy=2, nic_bw=2e9,
                    agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    jobs = [JobSpec("a", CFG, SHAPE, DP2, policy="serial", devices=(0, 2),
                    dp_params=dpp),
            JobSpec("b", CFG, SHAPE, DP2, policy="serial", devices=(1, 3),
                    dp_params=dpp)]
    return jobs, topo


def test_dynamics_injected_clock_is_deterministic():
    jobs, topo = _dyn_setup()
    ticks = itertools.count()
    dyn = ClusterDynamics(jobs, topo, grid=4, horizon_iters=6,
                          compare_full=True,
                          clock=lambda: float(next(ticks)))
    rep = dyn.run([Event("link_degrade", time=1.0,
                         link=("tor0", "agg0.0"), factor=0.5),
                   Event("straggler", time=2.0, name="a", factor=2.0)])
    # the fake clock advances 1.0 per call: replan_s and full_replan_s
    # are exact, not wall-clock noise
    assert [r.replan_s for r in rep.records] == [1.0, 1.0]
    assert [r.full_replan_s for r in rep.records] == [1.0, 1.0]
    tel = rep.telemetry
    assert tel["dynamics.mode.incremental"] == 2.0
    assert tel["dynamics.event.link_degrade"] == 1.0
    assert tel["dynamics.dirty_jobs.count"] == 2.0
    # report + trace round-trip through JSON
    d = json.loads(json.dumps(rep.to_dict()))
    rep2 = DynamicsReport.from_dict(d, {s.name: s for s in jobs})
    assert rep2.telemetry == tel and rep2.to_dict() == d
    tr = rep.to_trace(topo=topo)
    assert validate_chrome(tr.to_chrome()) == []
    assert rep2.to_trace(topo=topo).to_json() == tr.to_json()
    evs = tr.to_chrome()["traceEvents"]
    assert any(e["name"] == "link_degrade:tor0->agg0.0" for e in evs)
    assert any(e["ph"] == "X" and e["name"] == "replan[incremental]"
               for e in evs)
    assert any(e["ph"] == "C" and e["name"] == "worst stretch"
               for e in evs)


def test_cluster_report_trace(dgx_plan):
    jobs, topo = _dyn_setup()
    rep = plan_cluster(jobs, topo, grid=4, horizon_iters=6)
    tr = rep.to_trace(topo=topo)
    assert validate_chrome(tr.to_chrome()) == []
    evs = tr.to_chrome()["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert any(n.startswith("a phase=") for n in names)
    assert any(n.startswith("b phase=") for n in names)
    assert "cluster" in names


# ---------------------------------------------------------------------------
# cost_terms decomposition
# ---------------------------------------------------------------------------


def test_cost_terms_sum_to_algo_cost():
    cp = CostParams(alpha=1e-6, link_bw=50e9)
    for algo in ("ring", "bidir_ring", "halving_doubling", "ring+q8"):
        terms = cost_terms("all_reduce", algo, 1 << 24, 8, cp)
        total = algo_cost("all_reduce", algo, 1 << 24, 8, cp)
        assert terms["total_s"] == pytest.approx(total)
        assert terms["latency_s"] + terms["bandwidth_s"] + \
            terms["codec_s"] == pytest.approx(total)
        assert terms["latency_s"] >= 0 and terms["bandwidth_s"] >= 0
    assert cost_terms("all_reduce", "ring+q8", 1 << 24, 8,
                      cp)["codec_s"] > 0
    assert cost_terms("all_reduce", "ring", 1 << 24, 1, cp) == {
        "latency_s": 0.0, "bandwidth_s": 0.0, "codec_s": 0.0,
        "total_s": 0.0}


# ---------------------------------------------------------------------------
# Probes (single-device degenerate case; the 8-device path runs in the
# paper_claims smoke via run_multidevice)
# ---------------------------------------------------------------------------


def test_probe_all_reduce_local():
    from repro.obs.probe import (CollectiveProbe, model_vs_measured,
                                 probe_all_reduce, probes_to_trace)
    pr = probe_all_reduce("ring", 1 << 12, repeats=2, warmup=1)
    assert pr.measured_s > 0 and len(pr.runs_s) == 2
    assert pr.algorithm == "ring"
    d = pr.to_dict()
    assert CollectiveProbe.from_dict(d).to_dict() == d
    doc = probes_to_trace([pr]).to_chrome()
    assert validate_chrome(doc) == []
    # measured and modeled land on separate threads of one process
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert tids == {0, 1}
    mm = model_vs_measured([pr])
    assert mm["count"] == 1
    if pr.world > 1:
        assert pr.modeled_s > 0 and mm["rows"][0]["ratio"] == pr.ratio
    with pytest.raises(ValueError):
        probe_all_reduce("nope", 1 << 12)


# ---------------------------------------------------------------------------
# Export CLI
# ---------------------------------------------------------------------------


def test_detect_kind_and_export_file(tmp_path, dgx_plan):
    rep, topo = dgx_plan
    d = rep.to_dict()
    assert detect_kind(d) == "report"
    assert detect_kind({"best": d, "frontier": []}) == "search"
    assert detect_kind({"jobs": [], "staggered_jct": {}}) == "cluster"
    assert detect_kind({"records": [], "final": {}}) == "dynamics"
    with pytest.raises(ValueError):
        detect_kind({"mystery": 1})
    # build_trace == the report's own to_trace (minus link counters,
    # which need the live topology)
    assert build_trace(d).to_json() == rep.to_trace().to_json()
    src = tmp_path / "rep.json"
    src.write_text(json.dumps(d))
    out = export_file(str(src))
    assert out == str(tmp_path / "rep.trace.json")
    doc = json.loads((tmp_path / "rep.trace.json").read_text())
    assert validate_chrome(doc) == []
    # CLI entry point with explicit output path
    dst = tmp_path / "explicit.trace.json"
    assert export_main([str(src), "-o", str(dst)]) == 0
    assert json.loads(dst.read_text()) == doc


def test_export_cli_subprocess(tmp_path, dgx_plan):
    rep, _ = dgx_plan
    src = tmp_path / "rep.json"
    src.write_text(json.dumps(rep.to_dict()))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.export", str(src)],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    assert (tmp_path / "rep.trace.json").exists()


# ---------------------------------------------------------------------------
# Satellite: roofline unknown-arch/shape guard
# ---------------------------------------------------------------------------


def test_roofline_rank_unknowns_sort_last():
    assert _rank(ARCH_ORDER, ARCH_ORDER[0]) < _rank(ARCH_ORDER,
                                                    ARCH_ORDER[-1])
    assert _rank(ARCH_ORDER, ARCH_ORDER[-1]) < _rank(ARCH_ORDER,
                                                     "brand-new-arch")
    # unknowns order alphabetically among themselves
    assert _rank(SHAPE_ORDER, "aaa_new") < _rank(SHAPE_ORDER, "zzz_new")


def test_roofline_load_tolerates_unknown_entries(tmp_path):
    rows = [{"arch": "qwen2-0.5b", "shape": "train_4k"},
            {"arch": "never-heard-of-it", "shape": "train_4k"},
            {"arch": "qwen2-0.5b", "shape": "weird_shape"}]
    for i, r in enumerate(rows):
        (tmp_path / f"r{i}_16x16.json").write_text(json.dumps(r))
    loaded = load("16x16", results_dir=str(tmp_path))
    assert [r["arch"] for r in loaded] == [
        "qwen2-0.5b", "qwen2-0.5b", "never-heard-of-it"]
    assert loaded[1]["shape"] == "weird_shape"
