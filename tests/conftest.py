import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (multi-device tests spawn subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # environments without hypothesis fall back to a deterministic sampling
    # stub so the property tests stay collectable and keep running
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
