"""CCL layer: flow generators vs alpha-beta cost models vs simulation,
NCCL-style selection crossover, TACCL-style synthesis validity."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ccl.algorithms import ALGORITHMS, generate_flows
from repro.ccl.cost import CostParams, algo_cost
from repro.ccl.select import select_algorithm
from repro.ccl.synth import Sketch, synthesize
from repro.core.demand import CommTask
from repro.net.simulate import link_utilization, simulate_flowset
from repro.net.topology import dgx_cluster, full_mesh, ring, torus2d


def _task(prim, size, p):
    return CommTask("t", prim, size, tuple(range(p)))


# ---------------------------------------------------------------------------
# wire-byte invariants of the generated schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [2, 4, 8, 16])
def test_ring_all_reduce_wire_bytes(p):
    n = 1024 * p  # divisible payload
    fs = generate_flows(_task("all_reduce", n, p), "ring")
    per_node = sum(f.size_bytes for f in fs.flows) / p
    assert per_node == 2 * n * (p - 1) / p


@pytest.mark.parametrize("p", [2, 4, 8, 16, 32])
def test_halving_doubling_step_count(p):
    fs = generate_flows(_task("all_reduce", 1024 * p, p), "halving_doubling")
    assert fs.num_steps == 2 * int(math.log2(p))


@pytest.mark.parametrize("algo", ["ring", "bidir_ring", "halving_doubling",
                                  "tree"])
def test_cost_model_matches_simulation_on_mesh(algo):
    """On a full mesh (no contention), the simulated schedule time must be
    within ~latency slop of the alpha-beta prediction."""
    p, n = 8, 64 * 2 ** 20
    cp = CostParams(alpha=1e-6, link_bw=50e9)
    task = _task("all_reduce", n, p)
    fs = generate_flows(task, algo)
    topo = full_mesh(p, bw=cp.link_bw, lat=cp.alpha)
    sim = simulate_flowset(topo, fs)
    model = algo_cost("all_reduce", algo, n, p, cp)
    assert sim == pytest.approx(model, rel=0.15), (algo, sim, model)


def test_ring_beats_tree_for_large_tree_beats_ring_for_small():
    cp = CostParams(alpha=5e-6, link_bw=50e9)
    big = select_algorithm("all_reduce", 2 ** 30, 16, cp,
                           allow=("ring", "tree"))[0]
    small = select_algorithm("all_reduce", 2 ** 10, 16, cp,
                             allow=("ring", "tree"))[0]
    assert big == "ring" and small == "tree"


@given(size=st.integers(2 ** 10, 2 ** 32), p=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=50, deadline=None)
def test_cost_monotone_in_size(size, p):
    cp = CostParams()
    for algo in ("ring", "tree"):
        c1 = algo_cost("all_reduce", algo, size, p, cp)
        c2 = algo_cost("all_reduce", algo, size * 2, p, cp)
        assert c2 >= c1


@given(p=st.sampled_from([2, 4, 8, 16]),
       size=st.integers(2 ** 12, 2 ** 28))
@settings(max_examples=30, deadline=None)
def test_selection_is_argmin(p, size):
    cp = CostParams()
    best, cost, costs = select_algorithm("all_reduce", size, p, cp)
    assert cost == min(costs.values())
    assert costs[best] == cost


# ---------------------------------------------------------------------------
# topology sensitivity (the paper's Sec. II-E point)
# ---------------------------------------------------------------------------


def test_torus2d_all_reduce():
    """Dimension-ordered 2D AR: same wire bytes/node as ring, ~sqrt(p)
    fewer steps, and faster than 1D ring when simulated ON the torus for
    latency-sensitive sizes."""
    p = 256
    n = 256 * p  # divisible
    t = _task("all_reduce", n, p)
    fs = generate_flows(t, "torus2d")
    ring_fs = generate_flows(t, "ring")
    per_node_2d = sum(f.size_bytes for f in fs.flows) / p
    per_node_1d = sum(f.size_bytes for f in ring_fs.flows) / p
    assert per_node_2d == pytest.approx(per_node_1d, rel=0.01)
    assert fs.num_steps == 2 * 15 + 2 * 15
    assert ring_fs.num_steps == 2 * 255
    topo = torus2d(16, 16)
    small = _task("all_reduce", 64 * 2 ** 10 * p // p * p, p)
    t2d = simulate_flowset(topo, generate_flows(small, "torus2d"))
    t1d = simulate_flowset(topo, generate_flows(small, "ring"))
    assert t2d < t1d  # latency-dominated regime

    # cost model agrees with the schedule on a full mesh (no contention)
    cp = CostParams(alpha=1e-6, link_bw=50e9)
    model = algo_cost("all_reduce", "torus2d", n, p, cp)
    sim = simulate_flowset(full_mesh(p, bw=cp.link_bw, lat=cp.alpha),
                           generate_flows(t, "torus2d"))
    assert sim == pytest.approx(model, rel=0.2)


def test_ring_algorithm_prefers_ring_topology():
    """Ring AR simulated on a ring topo ~= on a full mesh (it only uses
    neighbor links), but halving-doubling degrades badly on a ring —
    algorithm/topology co-design matters (Sec. II-E)."""
    p, n = 16, 64 * 2 ** 20
    t = _task("all_reduce", n, p)
    ring_topo, mesh_topo = ring(p), full_mesh(p)
    ring_on_ring = simulate_flowset(ring_topo, generate_flows(t, "ring"))
    ring_on_mesh = simulate_flowset(mesh_topo, generate_flows(t, "ring"))
    hd_on_ring = simulate_flowset(ring_topo,
                                  generate_flows(t, "halving_doubling"))
    assert ring_on_ring == pytest.approx(ring_on_mesh, rel=0.01)
    assert hd_on_ring > 2 * ring_on_ring


# ---------------------------------------------------------------------------
# synthesis (TACCL-like)
# ---------------------------------------------------------------------------


def _delivered(task, fs):
    """Check every (chunk, dst) demand is satisfiable from the flow set by
    replaying transfers in step order."""
    have = {}
    if task.primitive == "all_gather":
        chunks = {ci: {task.group[ci]} for ci in range(len(task.group))}
    elif task.primitive == "broadcast":
        chunks = {0: {task.group[0]}}
    else:
        return True
    # replay (flows were appended in execution order)
    for f in fs.flows:
        for ci, holders in chunks.items():
            if f.src in holders:
                holders.add(f.dst)
    need_all = set(task.group)
    return all(holders >= need_all for holders in chunks.values())


@pytest.mark.parametrize("prim", ["all_gather", "broadcast"])
def test_synthesis_delivers_on_dgx(prim):
    topo = dgx_cluster(2)
    group = tuple(topo.accelerators)
    task = CommTask("syn", prim, 2 ** 20, group)
    fs = synthesize(topo, task)
    assert fs.flows, "no flows synthesized"
    assert _delivered(task, fs)


def test_synthesis_respects_sketch_links():
    topo = ring(8)
    allowed = {(u, v) for u, v, _ in topo.links()}
    task = CommTask("syn", "broadcast", 2 ** 20, tuple(range(8)))
    fs = synthesize(topo, task, Sketch(allowed_links=allowed, max_hops=3))
    assert fs.flows and _delivered(task, fs)
    for f in fs.flows:
        # each move stays within the sketch's hop bound
        assert len(topo.path_links(f.src, f.dst)) <= 3


def test_synthesis_steps_encode_concurrency():
    """Independent transfers must land in the same step: a broadcast on a
    ring fans out both ways, so the step count is ~p/2, not p (the old
    schedule serialized every move, making FlowSim price a disjoint
    schedule as a chain)."""
    p = 8
    topo = ring(p)
    task = CommTask("syn", "broadcast", 2 ** 20, tuple(range(p)))
    fs = synthesize(topo, task)
    assert _delivered(task, fs)
    assert len(fs.flows) == p - 1
    assert fs.num_steps < len(fs.flows)
    # both ring directions progress concurrently: some step carries > 1 flow
    per_step = {}
    for f in fs.flows:
        per_step[f.step] = per_step.get(f.step, 0) + 1
    assert max(per_step.values()) > 1
    # a chunk can only move after the step that delivered it to its source
    have_step = {task.group[0]: -1}
    for f in sorted(fs.flows, key=lambda f: f.step):
        assert f.src in have_step and have_step[f.src] < f.step
        have_step[f.dst] = min(have_step.get(f.dst, f.step), f.step)


def test_synthesis_asymmetric_sketch_reverse_edge():
    """A sketch naming each physical link in one orientation only must
    still synthesize (regression: tx_time KeyError when a shortest path
    crossed a listed link against its listed orientation)."""
    p = 6
    topo = ring(p)
    # list each physical link exactly once, in the u < v orientation
    allowed = {(u, v) for u, v, _ in topo.links() if u < v}
    task = CommTask("syn", "broadcast", 2 ** 18, tuple(range(p)))
    fs = synthesize(topo, task, Sketch(allowed_links=allowed))
    assert fs.flows and _delivered(task, fs)
    # reverse-orientation traffic actually flows (counter-clockwise arm)
    util = link_utilization(topo, fs)
    assert any(u > v and b > 0 for (u, v), b in util.items())
