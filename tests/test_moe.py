"""MoE: routing invariants, dense-vs-EP equivalence (single- and
multi-device), decode-vs-train path agreement."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from helpers import run_multidevice
from repro.configs import smoke_config
from repro.models import moe as moe_mod


def _cfg():
    return smoke_config("dbrx-132b")


def test_route_weights_normalized():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (4, 8, cfg.d_model))
    ids, w, aux = moe_mod.route(p, cfg, x)
    assert ids.shape == (4, 8, cfg.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, =1 balanced


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_dense_moe_is_convex_combination(seed):
    """moe_dense output must be inside the convex hull of expert outputs:
    ||y|| <= max_e ||ffn_e(x)|| per token (plus shared experts)."""
    cfg = dataclasses.replace(_cfg(), num_shared_experts=0)
    key = jax.random.PRNGKey(seed)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, cfg.d_model))
    y, _ = moe_mod.moe_dense(p, cfg, x)
    xt = x.reshape(-1, cfg.d_model)
    all_e = moe_mod._expert_ffn(
        p, cfg, jnp.broadcast_to(xt, (cfg.num_experts, *xt.shape)))
    max_norm = jnp.linalg.norm(all_e, axis=-1).max(axis=0)
    y_norm = jnp.linalg.norm(y.reshape(-1, cfg.d_model), axis=-1)
    assert bool((y_norm <= max_norm + 1e-4).all())


EP_SCRIPT = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.models import moe as moe_mod
from repro.parallel.planner import ParallelCtx

cfg = dataclasses.replace(smoke_config("dbrx-132b"), num_shared_experts=0)
mesh = jax.make_mesh((2, 2), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, data_axes=("data",), model_axis="model",
                  capacity_factor=float(cfg.num_experts))  # no drops
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model))

dense, _ = moe_mod.moe_dense(p, cfg, x)
ep, _ = jax.jit(lambda p_, x_: moe_mod.moe_ep_train(
    p_, cfg, x_, mesh, "model", ("data",),
    capacity_factor=float(cfg.num_experts)))(p, x)
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), atol=2e-5)
print("train ok")

xd = x[:, :1, :]
dense_d, _ = moe_mod.moe_dense(p, cfg, xd)
ep_d, _ = jax.jit(lambda p_, x_: moe_mod.moe_ep_decode(
    p_, cfg, x_, mesh, "model", ("data",),
    capacity_factor=float(cfg.num_experts)))(p, xd)
np.testing.assert_allclose(np.asarray(ep_d), np.asarray(dense_d), atol=2e-5)
print("decode ok")

ws_d, _ = jax.jit(lambda p_, x_: moe_mod.moe_ep_decode_ws(
    p_, cfg, x_, mesh, "model", ("data",),
    capacity_factor=float(cfg.num_experts)))(p, xd)
np.testing.assert_allclose(np.asarray(ws_d), np.asarray(dense_d), atol=2e-5)
print("ws decode ok")
print("OK")
"""


def test_ep_matches_dense_multidevice():
    """All-to-All EP train path and All-Reduce EP decode path both match
    the dense oracle on a 2x2 mesh (capacity high enough for no drops)."""
    run_multidevice(EP_SCRIPT, num_devices=4)


def test_capacity_drops_are_bounded():
    """With tiny capacity, output shrinks (dropped tokens) but stays finite
    and within the convex hull bound."""
    cfg = dataclasses.replace(_cfg(), num_shared_experts=0)
    key = jax.random.PRNGKey(1)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 16, cfg.d_model))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y, _ = moe_mod.moe_ep_train(p, cfg, x, mesh, "model", ("data",),
                                capacity_factor=0.25)
    assert bool(jnp.isfinite(y).all())
