"""Open-loop arrival processes (repro.sched.arrivals): seeded
determinism, Poisson statistics, trace round-trips, offered load."""
import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.sched.arrivals import (Arrival, PoissonArrivals, TraceArrivals,
                                  arrivals_from_dict, arrivals_to_dict,
                                  demand_series, offered_load)


@given(st.integers(0, 2 ** 32), st.floats(1.0, 200.0))
@settings(max_examples=10, deadline=None)
def test_poisson_seeded_determinism(seed, rate):
    """Same seed, same process — bit-identical arrivals, stdlib-random
    free."""
    p1 = PoissonArrivals(rate_rps=rate, seed=seed)
    p2 = PoissonArrivals(rate_rps=rate, seed=seed)
    a1, a2 = p1.sample(2.0), p2.sample(2.0)
    assert a1 == a2
    assert all(a.t < 2.0 for a in a1)
    # arrival times are sorted and rids unique
    ts = [a.t for a in a1]
    assert ts == sorted(ts)
    assert len({a.rid for a in a1}) == len(a1)


def test_poisson_different_seeds_differ():
    a = PoissonArrivals(rate_rps=50.0, seed=1).sample(2.0)
    b = PoissonArrivals(rate_rps=50.0, seed=2).sample(2.0)
    assert [x.t for x in a] != [x.t for x in b]


def test_poisson_interarrival_mean():
    """Mean inter-arrival gap approaches 1/rate (law of large numbers;
    the seed is fixed so the tolerance is deterministic)."""
    rate = 40.0
    arr = PoissonArrivals(rate_rps=rate, seed=7).sample(200.0)
    gaps = [b.t - a.t for a, b in zip(arr, arr[1:])]
    mean = sum(gaps) / len(gaps)
    assert mean == pytest.approx(1.0 / rate, rel=0.1)
    # and the count matches the offered load
    assert offered_load(arr, 200.0) == pytest.approx(rate, rel=0.1)


def test_trace_round_trip_and_sorting():
    raw = (Arrival("b", 0.5, 128, 16), Arrival("a", 0.1, 256, 8))
    tr = TraceArrivals(raw)
    assert [a.rid for a in tr.sample(1.0)] == ["a", "b"]  # auto-sorted
    assert [a.rid for a in tr.sample(0.3)] == ["a"]       # horizon clip
    d = json.loads(json.dumps(arrivals_to_dict(tr)))
    tr2 = arrivals_from_dict(d)
    assert tr2.sample(1.0) == tr.sample(1.0)


def test_poisson_process_round_trip():
    p = PoissonArrivals(rate_rps=25.0, prompt_tokens=64, decode_tokens=4,
                        seed=9)
    d = json.loads(json.dumps(arrivals_to_dict(p)))
    p2 = arrivals_from_dict(d)
    assert p2.sample(3.0) == p.sample(3.0)


def test_demand_series_partitions_arrivals():
    arr = PoissonArrivals(rate_rps=30.0, prompt_tokens=10, decode_tokens=2,
                          seed=3).sample(4.0)
    series = demand_series(arr, 4.0, window_s=0.5)
    assert len(series["t"]) == 8
    assert sum(series["prefill"]) == 10 * len(arr)
    assert sum(series["decode"]) == 2 * len(arr)


def test_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(rate_rps=0.0)
    with pytest.raises(ValueError):
        PoissonArrivals(rate_rps=-1.0)
