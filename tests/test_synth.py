"""Collective synthesis as a plan-space lever (ccl.synth + the
``synthesize`` knob): schedule invariants as properties, solver
memoization, persisted warm-start seeds, selection pricing under both
cost models, and the executable shard_map lowering on 8 forced host
devices."""
import dataclasses
import json
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ccl.select import AlphaBeta, FlowSim, select_for_task
from repro.ccl.synth import (DEFAULT_SYNTH_CACHE, Sketch, SynthCache,
                             atp_schedule, sketch_from_hotspots,
                             synthesize_schedule, topology_fingerprint)
from repro.core.demand import CommTask
from repro.core.knobs import Fixed, Search
from repro.core.types import MeshConfig, ShapeConfig
from repro.net.topology import dgx_cluster, fat_tree, full_mesh, ring

from helpers import run_multidevice

TOPOS = {
    "ring8": lambda: ring(8),
    "mesh8": lambda: full_mesh(8),
    "fattree": lambda: fat_tree(2, 8, oversub=8.0, hosts_per_rack=1),
    "dgx2": lambda: dgx_cluster(2),
}


def _task(topo, primitive, size):
    return CommTask("t", primitive, size, tuple(topo.accelerators))


# ---------------------------------------------------------------------------
# schedule invariants (property tests)
# ---------------------------------------------------------------------------


@given(st.sampled_from(sorted(TOPOS)), st.integers(10, 24))
@settings(max_examples=16, deadline=None)
def test_all_reduce_wire_bytes_are_ring_equal(topo_name, log_size):
    """Wire-byte conservation: the mirrored-tree all-reduce moves exactly
    the ring algorithm's bytes — 2(p-1) chunks per rank, every
    contribution crossing every tree edge once — at any payload size."""
    topo = TOPOS[topo_name]()
    task = _task(topo, "all_reduce", 1 << log_size)
    p = len(task.group)
    s = synthesize_schedule(topo, task)
    assert s.chunk_bytes == max(task.size_bytes // p, 1)
    assert len(s.moves) == 2 * p * (p - 1)
    assert s.wire_bytes() == 2 * p * (p - 1) * s.chunk_bytes


@given(st.sampled_from(sorted(TOPOS)),
       st.sampled_from(["broadcast", "all_gather"]), st.integers(10, 24))
@settings(max_examples=16, deadline=None)
def test_gather_like_wire_bytes_match_bulk(topo_name, primitive, log_size):
    """Broadcast moves its full payload to p-1 receivers; all-gather moves
    each of the p shards to p-1 receivers — the bulk collectives' wire
    bytes, no duplicated or dropped chunks."""
    topo = TOPOS[topo_name]()
    task = _task(topo, primitive, 1 << log_size)
    p = len(task.group)
    s = synthesize_schedule(topo, task)
    n_demands = (p - 1) if primitive == "broadcast" else p * (p - 1)
    assert len(s.moves) == n_demands
    assert s.wire_bytes() == n_demands * s.chunk_bytes


def _replay(schedule):
    """Replay the move list with strict step semantics: every step reads
    the *previous* step's state (same-step forwarding would be a
    causality bug), reduce moves union contribution sets, gather moves
    overwrite.  Returns rank -> chunk -> frozenset of contributions."""
    group = schedule.group
    state = {r: {} for r in group}
    if schedule.primitive == "all_reduce":
        # every rank holds a partial contribution to every chunk slot
        for r in group:
            for c in range(schedule.num_chunks):
                state[r][c] = frozenset([r])
    elif schedule.primitive == "broadcast":
        state[group[0]][0] = frozenset([group[0]])
    else:  # all_gather: chunk c starts at rank group[c]
        for c, r in enumerate(group):
            state[r][c] = frozenset([r])
    by_step = {}
    for m in schedule.moves:
        by_step.setdefault(m.step, []).append(m)
    for step in sorted(by_step):
        pre = {r: dict(cs) for r, cs in state.items()}
        for m in by_step[step]:
            src_val = pre[m.src].get(m.chunk)
            assert src_val is not None, \
                f"step {step}: {m.src} forwards chunk {m.chunk} it does " \
                f"not hold (same-step forwarding?)"
            if m.reduce:
                state[m.dst][m.chunk] = \
                    state[m.dst].get(m.chunk, frozenset()) | src_val
            else:
                state[m.dst][m.chunk] = src_val
    return state


@given(st.sampled_from(sorted(TOPOS)),
       st.sampled_from(["all_reduce", "broadcast", "all_gather"]))
@settings(max_examples=12, deadline=None)
def test_replay_delivers_everything(topo_name, primitive):
    """Full delivery: after replaying the schedule, every rank holds every
    chunk, and all-reduce chunks carry every rank's contribution exactly
    (no double counting — contribution sets, not sums, so a chunk
    crossing an edge twice would still pass; the wire-byte test pins
    that side)."""
    topo = TOPOS[topo_name]()
    task = _task(topo, primitive, 1 << 18)
    s = synthesize_schedule(topo, task)
    state = _replay(s)
    group = s.group
    everyone = frozenset(group)
    for r in group:
        for c in range(s.num_chunks):
            assert c in state[r], f"rank {r} missing chunk {c}"
            if primitive == "all_reduce":
                assert state[r][c] == everyone, \
                    f"rank {r} chunk {c} reduced only {sorted(state[r][c])}"


@given(st.sampled_from(sorted(TOPOS)),
       st.sampled_from(["all_reduce", "broadcast", "all_gather"]))
@settings(max_examples=12, deadline=None)
def test_per_step_moves_use_disjoint_directed_links(topo_name, primitive):
    """Link concurrency: no two moves of one step share a directed link.
    Reduce-phase moves are mirrored fan-out edges, so their paths are
    taken in fan-out orientation and reversed — ``path_links(dst, src)``
    itself may break antipodal shortest-path ties the other way round a
    ring, which is a pricing artifact, not a schedule collision."""
    topo = TOPOS[topo_name]()
    task = _task(topo, primitive, 1 << 18)
    s = synthesize_schedule(topo, task)
    by_step = {}
    for m in s.moves:
        by_step.setdefault(m.step, []).append(m)
    for step, moves in by_step.items():
        seen = set()
        for m in moves:
            if m.reduce:
                path = [(b, a) for a, b in
                        reversed(list(topo.path_links(m.dst, m.src)))]
            else:
                path = list(topo.path_links(m.src, m.dst))
            for link in path:
                assert link not in seen, \
                    f"step {step}: directed link {link} carries two moves"
                seen.add(link)


def test_all_reduce_reduce_phase_mirrors_fanout():
    """The reduce phase is exactly the fan-out trees reversed, and every
    reduce move lands strictly before its mirrored fan-out move (a
    contribution must reach the owner before the sum fans out)."""
    topo = TOPOS["fattree"]()
    s = synthesize_schedule(topo, _task(topo, "all_reduce", 1 << 18))
    span = s.num_steps // 2
    fanout = {(m.chunk, m.src, m.dst, m.step - span)
              for m in s.moves if not m.reduce}
    mirrored = {(m.chunk, m.dst, m.src, span - 1 - m.step)
                for m in s.moves if m.reduce}
    assert fanout == mirrored
    for m in s.moves:
        if m.reduce:
            assert m.step < span


def test_atp_schedule_replays_exactly():
    """The executable analogue of the priced ``atp`` candidate: all
    contributions converge on the aggregation point at step 0, the sum
    multicasts at step 1."""
    topo = full_mesh(8)
    task = _task(topo, "all_reduce", 1 << 16)
    s = atp_schedule(task)
    assert s.num_steps == 2 and s.num_chunks == 1
    assert s.wire_bytes() == 2 * (len(task.group) - 1) * task.size_bytes
    state = _replay(s)
    everyone = frozenset(task.group)
    assert all(state[r][0] == everyone for r in task.group)


# ---------------------------------------------------------------------------
# memoization (SynthCache) + topology fingerprints
# ---------------------------------------------------------------------------


def test_synth_cache_hits_within_size_bucket_and_rescales():
    cache = SynthCache()
    topo = full_mesh(8)
    s1 = cache.schedule(topo, _task(topo, "all_reduce", 1 << 20))
    stats = cache.cache_stats()
    assert stats["synth.miss"] == 1 and "synth.hit" not in stats
    assert stats["synth.entries"] == 1

    # same power-of-two bucket, different exact size: hit + exact rescale
    t2 = CommTask("t2", "all_reduce", (1 << 20) + (1 << 19),
                  tuple(topo.accelerators))
    s2 = cache.schedule(topo, t2)
    stats = cache.cache_stats()
    assert stats["synth.hit"] == 1 and stats["synth.entries"] == 1
    assert stats["synth.hit_rate"] == 0.5
    assert s2.task_id == "t2" and s2.size_bytes == t2.size_bytes
    assert [(m.chunk, m.src, m.dst, m.step) for m in s2.moves] == \
        [(m.chunk, m.src, m.dst, m.step) for m in s1.moves]
    assert s2.wire_bytes() == len(s2.moves) * s2.chunk_bytes

    # a different sketch is a different solver problem
    cache.schedule(topo, _task(topo, "all_reduce", 1 << 20),
                   Sketch(max_hops=2))
    assert cache.cache_stats()["synth.entries"] == 2


def test_topology_fingerprint_is_wiring_identity():
    assert topology_fingerprint(ring(8)) == topology_fingerprint(ring(8))
    assert topology_fingerprint(ring(8)) != topology_fingerprint(ring(6))
    topo = fat_tree(2, 8, oversub=8.0, hosts_per_rack=1)
    u, v, _ = next(iter(topo.links()))
    assert topology_fingerprint(topo.without_link(u, v)) != \
        topology_fingerprint(topo)
    # cross-instance: a second identical build hits the first's entry
    cache = SynthCache()
    cache.schedule(ring(8), _task(ring(8), "broadcast", 1 << 16))
    cache.schedule(ring(8), _task(ring(8), "broadcast", 1 << 16))
    assert cache.cache_stats()["synth.hit"] == 1


# ---------------------------------------------------------------------------
# selection pricing: extras under both models, budget gate, whitelists
# ---------------------------------------------------------------------------


def _extras(topo, task, wire_ratio=None):
    s = synthesize_schedule(topo, task)
    out = {"synthesized": s.to_flowset(job_id=task.job_id)}
    if wire_ratio is not None:
        out["synthesized+q8"] = s.to_flowset(
            job_id=task.job_id, wire_ratio=wire_ratio,
            algorithm="synthesized+q8")
    return out


def test_synthesized_priced_under_both_models_and_wins_latency_regime():
    topo = full_mesh(8)
    task = _task(topo, "all_reduce", 112 << 10)
    for model in (AlphaBeta.from_topology(topo), FlowSim(topo)):
        sel = select_for_task(task, model, extra_flowsets=_extras(topo, task))
        assert sel.algorithm == "synthesized", type(model).__name__
        reg = min(v for k, v in sel.costs.items() if k != "synthesized")
        assert sel.costs["synthesized"] < reg


def test_synthesized_never_selected_where_registry_matches_fabric():
    """On a plain ring at bandwidth-regime sizes the registered ring
    algorithms already match the fabric — the synthesized candidate is
    priced but loses."""
    topo = ring(8)
    task = _task(topo, "all_reduce", 8 << 20)
    for model in (AlphaBeta.from_topology(topo), FlowSim(topo)):
        sel = select_for_task(task, model, extra_flowsets=_extras(topo, task))
        assert sel.algorithm != "synthesized", type(model).__name__
        assert "synthesized" in sel.costs  # competed, lost


def test_synthesized_q8_faces_error_budget_and_whitelists():
    topo = fat_tree(2, 8, oversub=8.0, hosts_per_rack=1)
    task = _task(topo, "all_reduce", 8 << 20)
    model = FlowSim(topo)
    extras = _extras(topo, task, wire_ratio=0.25)
    zero = select_for_task(task, model, extra_flowsets=extras)
    assert "synthesized+q8" in zero.excluded  # default budget is exact
    budget = select_for_task(task, model, error_budget=0.01,
                             extra_flowsets=extras)
    assert "synthesized+q8" in budget.costs
    assert budget.costs["synthesized+q8"] < budget.costs["synthesized"]
    forced = select_for_task(task, model, constraint=Fixed("synthesized"),
                             extra_flowsets=extras)
    assert forced.algorithm == "synthesized"
    assert list(forced.costs) == ["synthesized"]


# ---------------------------------------------------------------------------
# the synthesize knob end to end: plan(), search(), warm-start seeds
# ---------------------------------------------------------------------------


def _knob_problem(cost_model="alphabeta", synthesize=Fixed(True)):
    from repro.codesign.api import CodesignProblem, PlanSpace
    from repro.configs import get_config
    mesh = MeshConfig(shape=(8,), axis_names=("model",), data_axes=(),
                      model_axes=("model",))
    return CodesignProblem(
        get_config("qwen2-0.5b"), ShapeConfig("synth_tiny", 64, 1, "train"),
        mesh, full_mesh(8), cost_model=cost_model,
        space=PlanSpace(synthesize=synthesize))


@pytest.mark.parametrize("cost_model", ["alphabeta", "flowsim"])
def test_plan_flips_latency_regime_tp_all_reduce(cost_model):
    from repro.codesign.api import plan
    rep = plan(_knob_problem(cost_model))
    base = plan(_knob_problem(cost_model, synthesize=Fixed(False)))
    synth = rep.synthesized_choices
    assert synth and len(synth) == len(rep.choices)
    assert rep.jct < base.jct
    for c in synth:
        reg = min(v for k, v in c.costs.items()
                  if not k.startswith("synthesized"))
        assert c.cost_s < reg
    # the report round-trips with the synthesized choices intact
    from repro.codesign.report import CodesignReport
    loaded = CodesignReport.from_dict(
        json.loads(json.dumps(rep.to_dict())))
    assert len(loaded.synthesized_choices) == len(synth)


@pytest.mark.parametrize("cost_model", ["alphabeta", "flowsim"])
def test_search_walks_synthesize_knob_with_attribution(cost_model):
    from repro.codesign.api import search
    res = search(_knob_problem(cost_model, synthesize=Search()), budget=8)
    assert res.best_assignment == {"synthesize": True}
    assert res.attribution["synthesize"] > 0
    assert res.best.synthesized_choices
    # solver cache telemetry rides along like FlowSim's cache stats
    assert res.telemetry["counters"]["synth.miss"] >= 0
    assert res.telemetry["counters"]["synth.hit"] >= 1
    assert res.telemetry["synth_hit_rate"] > 0


def test_search_persists_and_warm_starts_from_seed(tmp_path):
    from repro.codesign.api import search
    from repro.codesign.seeds import load_seed, seed_path
    prob = _knob_problem(synthesize=Search())
    res1 = search(prob, budget=8, seeds_dir=str(tmp_path))
    path = seed_path(str(tmp_path), prob)
    assert os.path.exists(path)
    assert load_seed(str(tmp_path), prob) == res1.best_assignment

    res2 = search(prob, budget=8, seeds_dir=str(tmp_path))
    warm = [c for c in res2.frontier if c.phase == "warm_start"]
    assert len(warm) == 1
    assert warm[0].assignment == res1.best_assignment
    assert res2.best_assignment == res1.best_assignment

    # a corrupt seed is treated as absent, never breaks the search
    with open(path, "w") as f:
        f.write("{not json")
    assert load_seed(str(tmp_path), prob) is None
    res3 = search(prob, budget=8, seeds_dir=str(tmp_path))
    assert res3.best_assignment == res1.best_assignment
    assert not [c for c in res3.frontier if c.phase == "warm_start"]

    # another topology's seed never leaks in: the key mismatches
    other = dataclasses.replace(prob, topo=ring(8))
    assert load_seed(str(tmp_path), other) is None


# ---------------------------------------------------------------------------
# executable lowering: synthesized schedules vs psum on 8 forced devices
# ---------------------------------------------------------------------------

_LOWERING = """
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.ccl.primitives import make_synthesized, synthesized_collective
from repro.ccl.synth import atp_schedule, synthesize_schedule
from repro.core.demand import CommTask
from repro.net.topology import fat_tree, full_mesh, ring

mesh = jax.make_mesh((8,), ("x",))
# integer-valued floats: float32 sums are exact, so lossless synthesized
# all-reduce must BIT-match psum (not just be close)
x = jnp.arange(8 * 48, dtype=jnp.float32).reshape(8, 48) - 150.0

def psum_ref(y):
    return jax.jit(jax.shard_map(lambda yl: jax.lax.psum(yl, "x"),
                                 mesh=mesh, in_specs=P("x", None),
                                 out_specs=P("x", None)))(y)

want = np.asarray(psum_ref(x))
topos = {"ring8": ring(8), "mesh8": full_mesh(8),
         "fattree": fat_tree(2, 4, oversub=8.0, hosts_per_rack=1)}
for name, topo in topos.items():
    task = CommTask("t", "all_reduce", x.nbytes, tuple(topo.accelerators))
    sched = synthesize_schedule(topo, task)
    got = np.asarray(make_synthesized(sched, mesh, "x")(x))
    np.testing.assert_array_equal(got, want, err_msg=name)
    print(name, "lossless exact")

# codec riding inside the send loop: within quantization tolerance
sched = synthesize_schedule(topos["fattree"],
                            CommTask("t", "all_reduce", x.nbytes,
                                     tuple(topos["fattree"].accelerators)))
got8 = np.asarray(make_synthesized(sched, mesh, "x", bits=8)(x))
# each of the 8 contributions quantizes to <= scale/2 = max|.|/(2^7-1)/2
# absolute error, and partial sums re-quantize along the reduce tree:
# bound by 2 * world * per-pass error on the largest partial magnitude
tol = 2 * 8 * float(np.max(np.abs(want))) / (2 ** 7 - 1)
assert np.max(np.abs(got8 - want)) <= tol, (np.max(np.abs(got8 - want)), tol)
print("q8 within tolerance")

# the executable analogue of the priced atp candidate: exact
atp = atp_schedule(CommTask("t", "all_reduce", x.nbytes,
                            tuple(range(8))))
gota = np.asarray(make_synthesized(atp, mesh, "x")(x))
np.testing.assert_array_equal(gota, want)
print("atp exact")

# broadcast: every rank ends with the root's shard
btask = CommTask("b", "broadcast", 48 * 4, tuple(range(8)))
bsched = synthesize_schedule(full_mesh(8), btask)
gotb = np.asarray(make_synthesized(bsched, mesh, "x")(x))
np.testing.assert_array_equal(gotb, np.tile(np.asarray(x)[:1], (8, 1)))
print("broadcast exact")

# all-gather inside an explicit shard_map: every rank stacks all shards
gtask = CommTask("g", "all_gather", x.nbytes, tuple(range(8)))
gsched = synthesize_schedule(full_mesh(8), gtask)
def gather_body(xl):
    return synthesized_collective(xl[0], "x", 8, gsched)[None]
gotg = np.asarray(jax.jit(jax.shard_map(
    gather_body, mesh=mesh, in_specs=P("x", None),
    out_specs=P("x", None, None)))(x))
np.testing.assert_array_equal(gotg, np.tile(np.asarray(x)[None], (8, 1, 1)))
print("all_gather exact")
print("OK")
"""


def test_synthesized_lowering_matches_psum_on_8_forced_devices():
    out = run_multidevice(_LOWERING, num_devices=8)
    for line in ("lossless exact", "q8 within tolerance", "atp exact",
                 "broadcast exact", "all_gather exact"):
        assert line in out, out
