"""Network layer: topology invariants + ATP in-network aggregation."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.demand import CommTask
from repro.net.topology import (dgx_cluster, fat_tree, full_mesh, ring,
                                torus2d, torus3d, tpu_pod)
from repro.sched.atp import atp_traffic


@pytest.mark.parametrize("builder,args", [
    (ring, (8,)), (full_mesh, (8,)), (torus2d, (4, 4)),
    (torus3d, (2, 2, 2)), (fat_tree, (8,)), (dgx_cluster, (2,)),
])
def test_topology_connectivity(builder, args):
    topo = builder(*args)
    accel = topo.accelerators
    assert len(accel) >= 8
    # all-pairs reachability between accelerators
    p = topo.path(accel[0], accel[-1])
    assert p[0] == accel[0] and p[-1] == accel[-1]
    assert topo.bisection_bw() > 0


def test_torus_degree():
    topo = torus2d(16, 16)
    for n in topo.accelerators:
        assert topo.graph.out_degree(n) == 4  # 2D torus: 4 links per chip


def test_tpu_pod_shapes():
    single = tpu_pod(False)
    assert single.num_accelerators == 256
    multi = tpu_pod(True)
    assert multi.num_accelerators == 512
    # inter-pod path must cross the DCN
    path = multi.path(0, 256)
    assert any(isinstance(n, str) and n.startswith("dcn") for n in path)


@given(st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_dgx_intra_faster_than_inter(num_hosts):
    """'Intra-Inter' heterogeneity: intra-host hops are NVLink, inter-host
    must traverse the slow NIC (Sec. IV-B)."""
    topo = dgx_cluster(num_hosts)
    intra = topo.path_links(0, 1)
    inter = topo.path_links(0, 8)
    min_bw_intra = min(topo.graph[u][v]["bw"] for u, v in intra)
    min_bw_inter = min(topo.graph[u][v]["bw"] for u, v in inter)
    assert min_bw_intra > 2 * min_bw_inter


def test_atp_reduces_traffic():
    """In-network aggregation cuts PS-bound traffic; degraded mode (switch
    capacity exhausted) falls back to host aggregation (ATP [15])."""
    topo = fat_tree(8)
    workers = tuple(topo.accelerators[:16])
    task = CommTask("grad", "all_reduce", 64 * 2 ** 20, workers)
    ps = topo.accelerators[-1]
    res = atp_traffic(topo, task, ps)
    assert res["traffic_reduction"] > 1.3
    assert res["speedup"] >= 1.0
    degraded = atp_traffic(topo, task, ps, switch_capacity=4)
    assert degraded["traffic_reduction"] == pytest.approx(1.0)
