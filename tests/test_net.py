"""Network layer: topology invariants + ATP in-network aggregation."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.demand import CommTask
from repro.net.topology import (dgx_cluster, fat_tree, full_mesh, ring,
                                torus2d, torus3d, tpu_pod)
from repro.sched.atp import atp_traffic


@pytest.mark.parametrize("builder,args", [
    (ring, (8,)), (full_mesh, (8,)), (torus2d, (4, 4)),
    (torus3d, (2, 2, 2)), (fat_tree, (8,)), (dgx_cluster, (2,)),
])
def test_topology_connectivity(builder, args):
    topo = builder(*args)
    accel = topo.accelerators
    assert len(accel) >= 8
    # all-pairs reachability between accelerators
    p = topo.path(accel[0], accel[-1])
    assert p[0] == accel[0] and p[-1] == accel[-1]
    assert topo.bisection_bw() > 0


def test_torus_degree():
    topo = torus2d(16, 16)
    for n in topo.accelerators:
        assert topo.graph.out_degree(n) == 4  # 2D torus: 4 links per chip


def test_tpu_pod_shapes():
    single = tpu_pod(False)
    assert single.num_accelerators == 256
    multi = tpu_pod(True)
    assert multi.num_accelerators == 512
    # inter-pod path must cross the DCN
    path = multi.path(0, 256)
    assert any(isinstance(n, str) and n.startswith("dcn") for n in path)


@given(st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_dgx_intra_faster_than_inter(num_hosts):
    """'Intra-Inter' heterogeneity: intra-host hops are NVLink, inter-host
    must traverse the slow NIC (Sec. IV-B)."""
    topo = dgx_cluster(num_hosts)
    intra = topo.path_links(0, 1)
    inter = topo.path_links(0, 8)
    min_bw_intra = min(topo.graph[u][v]["bw"] for u, v in intra)
    min_bw_inter = min(topo.graph[u][v]["bw"] for u, v in inter)
    assert min_bw_intra > 2 * min_bw_inter


def test_same_step_fanin_and_fanout_counted_once():
    """A flow belonging to a merge group (shared dst) whose source also
    fans out in the same step must be charged exactly once (regression:
    the merge and multicast passes of _route_bytes overlapped)."""
    from repro.core.demand import Flow
    from repro.net.simulate import _route_bytes
    topo = fat_tree(4, gpus_per_host=1)
    flows = [Flow(0, 2, 100, "t", 0), Flow(0, 3, 100, "t", 0),
             Flow(1, 2, 100, "t", 0)]
    agg = set(topo.switch_nodes())
    link_bytes = _route_bytes(topo, flows, agg)
    # last hop into the shared destination: merged upstream -> one payload
    assert link_bytes[("host2", 2)] == 100


def test_multicast_discount_gated_on_capable_switches():
    """The single-copy multicast discount only holds up to the last
    aggregation-capable switch on a receiver's path; with a partial
    capable set, copies diverge there and downstream links pay per
    receiver."""
    from repro.core.demand import Flow
    from repro.net.simulate import _route_bytes
    topo = fat_tree(8, gpus_per_host=1)  # 2 racks x 4 hosts, one pod
    flows = [Flow(0, d, 100, "t", 0) for d in (1, 2, 4, 5)]
    full = _route_bytes(topo, flows, set(topo.switch_nodes()))
    # every switch replicates: each fabric link carries one copy
    assert full[("tor0", "agg0")] == 100
    partial = _route_bytes(topo, flows, {"tor0"})
    # only tor0 replicates: the copies for rack-1 receivers 4 and 5 must
    # already be distinct when they leave tor0
    assert partial[("tor0", "agg0")] == 200
    assert partial[(0, "host0")] == 100  # shared stem still single-copy


def test_atp_reduces_traffic():
    """In-network aggregation cuts PS-bound traffic; degraded mode (switch
    capacity exhausted) falls back to host aggregation (ATP [15])."""
    topo = fat_tree(8)
    workers = tuple(topo.accelerators[:16])
    task = CommTask("grad", "all_reduce", 64 * 2 ** 20, workers)
    ps = topo.accelerators[-1]
    res = atp_traffic(topo, task, ps)
    assert res["traffic_reduction"] > 1.3
    assert res["speedup"] >= 1.0
    degraded = atp_traffic(topo, task, ps, switch_capacity=4)
    assert degraded["traffic_reduction"] == pytest.approx(1.0)
