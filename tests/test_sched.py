"""Scheduler layers: vertical (task) and horizontal (flow) co-design
invariants + hypothesis property tests on random task graphs."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ccl.cost import CostParams, algo_cost
from repro.ccl.select import select_algorithm
from repro.configs import get_config
from repro.core.demand import CommDemand, CommTask, ComputeTask
from repro.core.demand_builder import build_demand, janus_traffic_ratio
from repro.core.types import SHAPES_BY_NAME, SINGLE_POD_MESH
from repro.sched.flows import (JobProfile, multi_job_jct, restagger_jobs,
                               stagger_jobs, worst_stretch)
from repro.sched.tasks import simulate_iteration

CP = CostParams()


def _cost(t):
    if t.primitive == "all_reduce":
        return select_algorithm(t.primitive, t.size_bytes, len(t.group),
                                CP)[1]
    algo = "direct" if t.primitive == "all_to_all" else "ring"
    return algo_cost(t.primitive, algo, t.size_bytes, len(t.group), CP)


@pytest.mark.parametrize("arch", ["granite-3-8b", "dbrx-132b",
                                  "jamba-1.5-large-398b"])
def test_overlap_beats_serial(arch):
    dem = build_demand(get_config(arch), SHAPES_BY_NAME["train_4k"],
                       SINGLE_POD_MESH)
    serial = simulate_iteration(dem, _cost, "serial")
    for pol in ("fifo", "priority", "slack"):
        r = simulate_iteration(dem, _cost, pol)
        assert r.jct <= serial.jct + 1e-9, (arch, pol)
        assert r.exposed_comm <= serial.exposed_comm + 1e-9
    # exposure must be a real fraction of serial JCT
    assert 0.0 < serial.exposed_comm / serial.jct < 1.0


@pytest.mark.parametrize("arch", ["granite-3-8b", "dbrx-132b"])
@pytest.mark.parametrize("policy", ["serial", "fifo", "priority", "slack"])
def test_sim_invariants(arch, policy):
    dem = build_demand(get_config(arch), SHAPES_BY_NAME["train_4k"],
                       SINGLE_POD_MESH)
    r = simulate_iteration(dem, _cost, policy)
    assert r.jct >= r.compute_time - 1e-9           # can't beat compute
    assert r.exposed_comm <= r.comm_time + 1e-9     # can't expose more
    assert r.jct <= r.compute_time + r.comm_time + 1e-9  # no dead air


@given(st.lists(st.tuples(st.floats(1e-4, 1e-2), st.floats(1e-5, 1e-2)),
                min_size=1, max_size=12),
       st.sampled_from(["fifo", "priority", "slack"]))
@settings(max_examples=30, deadline=None)
def test_random_graphs_bounds(layers, policy):
    """Random layer graphs: JCT within [compute, compute+comm]."""
    demand = CommDemand()
    for i, (comp, comm) in enumerate(layers):
        demand.compute_tasks.append(ComputeTask(f"fwd{i}", 0.0, comp))
        demand.comm_tasks.append(CommTask(
            f"c{i}", "all_reduce", int(comm * 50e9), tuple(range(4)),
            after_compute=(f"fwd{i}",),
            before_compute=f"fwd{i+1}" if i + 1 < len(layers) else None))
    demand.compute_tasks.append(ComputeTask("tail", 0.0, 1e-4))
    r = simulate_iteration(demand, _cost, policy)
    total_comp = sum(c.duration for c in demand.compute_tasks)
    assert r.jct >= total_comp - 1e-12
    assert r.jct <= total_comp + r.comm_time + 1e-9


def test_preemption_beats_fifo_on_stranded_blocker():
    """Lina's mechanism: a blocking A2A arrives while a long gradient sync
    occupies the wire; preemption pauses the gradient and resumes it under
    later compute."""
    demand = CommDemand()
    demand.compute_tasks = [ComputeTask("c0", 0, 10e-3)] + [
        ComputeTask(f"c{i}", 0, 25e-3) for i in range(1, 6)
    ] + [ComputeTask("opt", 0, 1e-3)]
    demand.comm_tasks = [
        CommTask("grad", "all_reduce", int(100e-3 * 50e9), (0, 1),
                 after_compute=("c0",), before_compute="opt", slack=1.0),
        CommTask("a2a", "all_to_all", int(20e-3 * 50e9 * 2), (0, 1),
                 after_compute=("c0",), before_compute="c1", slack=0.0),
    ]
    from repro.ccl.cost import CostParams, algo_cost
    from repro.ccl.select import select_algorithm
    cp = CostParams(alpha=1e-6, link_bw=50e9)

    def cost(t):
        if t.primitive == "all_reduce":
            return select_algorithm(t.primitive, t.size_bytes, len(t.group),
                                    cp)[1]
        return algo_cost(t.primitive, "direct", t.size_bytes, len(t.group),
                         cp)

    fifo = simulate_iteration(demand, cost, "fifo")
    pre = simulate_iteration(demand, cost, "preempt")
    assert pre.jct < fifo.jct * 0.85
    # conservation: total comm identical
    assert pre.comm_time == pytest.approx(fifo.comm_time, rel=1e-6)


def test_janus_matches_paper_claim():
    """Janus reports up to 16x traffic reduction when experts are smaller
    than the data they'd attract; dbrx train_4k sits right there."""
    ratio = janus_traffic_ratio(get_config("dbrx-132b"),
                                SHAPES_BY_NAME["train_4k"],
                                SINGLE_POD_MESH)["ratio"]
    assert 8 <= ratio <= 32


def test_stagger_improves_contended_jobs():
    """CASSINI-style: two identical jobs with 50% duty-cycle bursts on one
    link: unstaggered they collide, staggered they interleave."""
    jobs = [JobProfile("j1", 0.010, 0.010),
            JobProfile("j2", 0.010, 0.010)]
    phases, base, best = stagger_jobs(jobs, grid=4)
    worst_base = max(base[j.name] / j.period for j in jobs)
    worst_best = max(best[j.name] / j.period for j in jobs)
    assert worst_best <= worst_base + 1e-6
    assert worst_best < 1.2  # staggered: near-zero slowdown
    assert worst_base > 1.2  # unstaggered: visible stretch


def test_multi_job_no_contention_when_alone():
    jobs = [JobProfile("solo", 0.01, 0.005)]
    jct = multi_job_jct(jobs, [0.0])
    assert jct["solo"] == pytest.approx(0.015, rel=0.05)


# ---------------------------------------------------------------------------
# flow-scheduler properties (hypothesis; stub fallback via conftest)
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.floats(2e-3, 2e-2), st.floats(2e-3, 2e-2)),
                min_size=1, max_size=3))
@settings(max_examples=6, deadline=None)
def test_stretch_at_least_one_and_stagger_never_worse(specs):
    """Sharing a link can only slow a job down (stretch >= 1 up to dt
    noise), and the staggered worst case is never worse than zero-phase
    (the zero-phase schedule is in the search set)."""
    jobs = [JobProfile(f"j{i}", comp, comm)
            for i, (comp, comm) in enumerate(specs)]
    dt = min(j.period for j in jobs) / 300
    phases, base, best = stagger_jobs(jobs, grid=3, horizon_iters=6, dt=dt)
    for j in jobs:
        assert base[j.name] >= j.period * 0.97
        assert best[j.name] >= j.period * 0.97
    assert worst_stretch(best, jobs) <= worst_stretch(base, jobs) + 1e-9
    assert phases[0] == 0.0  # job 0 pinned


@given(st.floats(2e-3, 2e-2), st.floats(2e-3, 2e-2))
@settings(max_examples=5, deadline=None)
def test_single_job_staggering_is_noop(comp, comm):
    job = JobProfile("solo", comp, comm)
    dt = job.period / 300
    phases, base, best = stagger_jobs([job], grid=5, horizon_iters=6, dt=dt)
    assert phases == (0.0,)
    assert base == best
    assert base["solo"] == pytest.approx(job.period, rel=0.03)


def test_multi_link_contention_is_localized():
    """Jobs a+b share link l1; job c presses l2 alone — only a and b may
    stretch (the generalized link_demands path plan_cluster uses)."""
    jobs = [JobProfile("a", 0.01, 0.01), JobProfile("b", 0.01, 0.01),
            JobProfile("c", 0.01, 0.01)]
    demands = [{"l1": 1.0}, {"l1": 1.0}, {"l2": 0.8}]
    jct = multi_job_jct(jobs, (0.0, 0.0, 0.0), link_demands=demands,
                        horizon_iters=10)
    assert jct["c"] == pytest.approx(0.02, rel=0.03)  # uncontended
    assert jct["a"] > 0.0215 and jct["b"] > 0.0215    # collided
    # a job throttles at its most-contended link: adding an idle link
    # to its map must not slow it further
    demands2 = [{"l1": 1.0, "l3": 1.0}, {"l1": 1.0}, {"l2": 0.8}]
    jct2 = multi_job_jct(jobs, (0.0, 0.0, 0.0), link_demands=demands2,
                         horizon_iters=10)
    assert jct2["a"] == pytest.approx(jct["a"], rel=1e-6)


def test_heterogeneous_periods_stay_finite():
    """A slow tenant sharing with a ~12x faster one must still get a real
    JCT (regression: a global iteration budget starved it to inf)."""
    jobs = [JobProfile("fast", 0.001, 0.001), JobProfile("slow", 0.02, 0.02)]
    jct = multi_job_jct(jobs, (0.0, 0.0),
                        link_demands=[{"l": 1.0}, {"l": 1.0}],
                        horizon_iters=12, dt=2e-5)
    assert all(v != float("inf") for v in jct.values())
    assert jct["fast"] >= 0.002 * 0.97
    # slow's burst is contended by fast's frequent bursts: stretched but
    # bounded well below a pathological blow-up
    assert 0.04 * 0.97 <= jct["slow"] <= 0.08


def test_flow_scheduler_length_mismatches_raise():
    jobs = [JobProfile("a", 0.01, 0.01), JobProfile("b", 0.01, 0.01)]
    with pytest.raises(ValueError):
        multi_job_jct(jobs, (0.0, 0.0), link_demands=[{"l": 1.0}])
    with pytest.raises(ValueError):
        multi_job_jct(jobs, (0.0,))


def test_simulate_link_dt_convergence():
    """The simulator steps exactly onto phase transitions (rates are
    piecewise constant in between), so dt-halving changes nothing: the
    old fixed-step loop discarded each transition's overshoot, an O(dt)
    bias that made halving converge only first-order."""
    jobs = [JobProfile("a", 0.012, 0.008), JobProfile("b", 0.010, 0.010)]
    coarse = multi_job_jct(jobs, (0.0, 0.003), horizon_iters=20, dt=1e-4)
    fine = multi_job_jct(jobs, (0.0, 0.003), horizon_iters=20, dt=5e-5)
    for name in coarse:
        assert coarse[name] == pytest.approx(fine[name], rel=1e-9)
    # and the uncontended single job is exact, not just converged
    solo = multi_job_jct([jobs[0]], (0.0,), horizon_iters=10, dt=1e-3)
    assert solo["a"] == pytest.approx(jobs[0].period, rel=1e-9)


@given(st.lists(st.tuples(st.floats(2e-3, 2e-2), st.floats(2e-3, 2e-2),
                          st.floats(0.0, 1.0)),
                min_size=2, max_size=3),
       st.floats(1e-4, 2e-3))
@settings(max_examples=8, deadline=None)
def test_simulate_links_dt_independent(specs, dt):
    """Property form: the event-driven loop's answer is independent of
    the dt knob for any job mix and any phase vector."""
    jobs = [JobProfile(f"j{i}", comp, comm)
            for i, (comp, comm, _) in enumerate(specs)]
    phases = tuple(frac * j.period for (_, _, frac), j in zip(specs, jobs))
    a = multi_job_jct(jobs, phases, horizon_iters=6, dt=dt)
    b = multi_job_jct(jobs, phases, horizon_iters=6, dt=dt / 2)
    for name in a:
        assert a[name] == pytest.approx(b[name], rel=1e-9)


@given(st.lists(st.tuples(st.floats(2e-3, 2e-2), st.floats(2e-3, 2e-2)),
                min_size=2, max_size=3),
       st.integers(0, 2))
@settings(max_examples=6, deadline=None)
def test_restagger_never_worse_than_frozen(specs, free_idx):
    """Incremental re-staggering (codesign.dynamics' horizontal half):
    freeing any single job's phase never worsens the worst stretch, and
    frozen jobs keep their phases."""
    jobs = [JobProfile(f"j{i}", comp, comm)
            for i, (comp, comm) in enumerate(specs)]
    free_idx = free_idx % len(jobs)
    current = [0.25 * j.period for j in jobs]
    best, base, staggered = restagger_jobs(jobs, current, [free_idx],
                                           grid=3, horizon_iters=6)
    assert worst_stretch(staggered, jobs) <= worst_stretch(base, jobs) + 1e-9
    for i, (b, c) in enumerate(zip(best, current)):
        if i != free_idx:
            assert b == pytest.approx(c)


def test_restagger_validates_inputs():
    jobs = [JobProfile("a", 0.01, 0.01), JobProfile("b", 0.01, 0.01)]
    with pytest.raises(ValueError):
        restagger_jobs(jobs, (0.0,), [0])          # phase length mismatch
    with pytest.raises(ValueError):
        restagger_jobs(jobs, (0.0, 0.0), [5])      # free index out of range
