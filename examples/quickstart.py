"""Quickstart: build a reduced model, train it on the synthetic pipeline,
then decode from it — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch dbrx-132b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.core.types import TrainConfig
from repro.data.pipeline import make_batches
from repro.models import decode_step, init_cache, init_params
from repro.optim.adamw import init_opt_state
from repro.serve.step import make_serve_step
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    # 1) a reduced (CPU-sized) variant of the assigned architecture
    cfg = smoke_config(args.arch)
    print(f"config: {cfg.name} ({cfg.family}), "
          f"{cfg.param_counts()['total']/1e6:.1f}M params")

    # 2) train on the deterministic synthetic pipeline
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                       total_steps=args.steps, remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    for i, batch in zip(range(args.steps),
                        make_batches(cfg, batch_size=8, seq_len=64)):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, b)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}")

    # 3) greedy decode: the model should continue the learned bigram chain
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32) % cfg.vocab_size
    cache = init_cache(cfg, params, 1, 64)
    serve = jax.jit(make_serve_step(cfg))
    tok = None
    for t in range(prompt.shape[1]):
        tok, _, cache = serve(params, cache, prompt[:, t:t + 1], t, key)
    out = [int(tok[0, 0])]
    for t in range(prompt.shape[1], prompt.shape[1] + 12):
        tok, _, cache = serve(params, cache, tok, t, key)
        out.append(int(tok[0, 0]))
    print("prompt:", prompt[0].tolist(), "-> generated:", out)


if __name__ == "__main__":
    main()
