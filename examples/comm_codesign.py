"""The paper-specific walkthrough: one training job through all five layers
of the communication-optimization paradigm (Fig. 5a), wired together by the
``repro.codesign`` engine behind its declarative ``CodesignProblem`` API:

  1. Para.   — pick an architecture + mesh; emit its CommDemand
  2. Codesign (vertical) — a ``CodesignProblem`` pinned knob by knob:
     placement onto a physical topology + per-task algorithm selection
     priced on that topology + JCT scheduling, via ``codesign.plan``
  3. Plan-space search — ``placement=Search()``: the optimizer walks
     packed/balanced/strided/permuted candidates + swap refinement and
     attributes the JCT win per knob
  3b. Overlap search — the demand-DAG knobs walked jointly
     (``bucket_bytes`` x ``decompose`` x policy): gradient buckets
     chained off backward layers and collective-matmul TP decomposition,
     priced through true compute-comm dependency edges
  3c. Synthesis knob — ``synthesize=Search()``: TACCL-style schedules
     synthesized for the plan's hottest collectives, priced against the
     registry under both cost models, lowered to executable shard_map
  4. CCL     — the selection crossover in detail: closed-form AlphaBeta vs
     topology-priced FlowSim, + TACCL-style synthesis
  5. Flow sched. (horizontal) — two jobs sharing links, CASSINI staggering
  6. Net.    — the same collective on torus vs oversubscribed fat-tree

    PYTHONPATH=src python examples/comm_codesign.py --arch dbrx-132b
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.ccl.select import AlphaBeta, FlowSim, select_for_task
from repro.ccl.synth import Sketch, synthesize
from repro.codesign import (Choice, CodesignProblem, JobSpec, PlanSpace,
                            Search, plan, plan_cluster, plan_iteration,
                            search)
from repro.configs import ARCHS, get_config
from repro.core.demand import CommTask
from repro.core.demand_builder import (DemandParams, build_demand,
                                       janus_traffic_ratio)
from repro.core.types import MeshConfig, SHAPES_BY_NAME, SINGLE_POD_MESH
from repro.net.simulate import simulate_flowset
from repro.net.topology import dgx_cluster, fat_tree, torus2d
from repro.ccl.algorithms import generate_flows
from repro.sched.flows import JobProfile, stagger_jobs

DP2_TP8 = MeshConfig(shape=(2, 8), axis_names=("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b", choices=ARCHS)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME["train_4k"]

    print("=" * 72)
    print(f"[1] Parallelization strategy -> CommDemand   ({cfg.name})")
    dem = build_demand(cfg, shape, SINGLE_POD_MESH, DemandParams())
    by_prim = dem.by_primitive()
    for prim, nbytes in sorted(by_prim.items()):
        print(f"    {prim:15s} {nbytes/2**30:8.2f} GiB per iteration")
    if cfg.is_moe:
        jr = janus_traffic_ratio(cfg, shape, SINGLE_POD_MESH)
        print(f"    (Janus check: expert-centric/data-centric traffic = "
              f"{jr['ratio']:.1f}x)")

    print("=" * 72)
    print("[2] Codesign engine: demand -> placement -> selection -> JCT")
    topo = dgx_cluster(2)
    print(f"    mesh {DP2_TP8.shape} (data x model) on {topo.name}")
    # the declarative surface: one problem, knobs pinned per variant
    problem = CodesignProblem(cfg, shape, DP2_TP8, topo)
    for pol in ("serial", "fifo", "priority", "preempt"):
        r = plan(problem.pinned(policy=pol))
        print(f"    {pol:9s} JCT={r.jct:7.3f}s exposed={r.exposed_comm:6.3f}s"
              f" ({100*r.comm_fraction:4.1f}%)")
    rep = plan(problem.pinned(policy="priority"))
    print("    per-primitive algorithm choices (FlowSim on the topology):")
    for prim, hist in sorted(rep.algorithms_by_primitive().items()):
        pick = ", ".join(f"{a} x{k}" for a, k in sorted(hist.items()))
        print(f"      {prim:15s} {pick}")
    print("    hottest links (bytes over one iteration):")
    for (u, v), nbytes in rep.link_hotspots[:4]:
        print(f"      {u!s:>7s} -> {v!s:<7s} {nbytes/2**30:8.2f} GiB")
    strided = plan(problem.pinned(policy="serial", placement="strided"))
    packed = plan(problem.pinned(policy="serial"))
    print(f"    placement: packed comm {packed.comm_time:.3f}s vs strided "
          f"{strided.comm_time:.3f}s "
          f"({strided.comm_time/max(packed.comm_time, 1e-12):.2f}x worse)")
    dp16 = MeshConfig(shape=(16,), axis_names=("data",),
                      data_axes=("data",), model_axes=())
    dpp = DemandParams(zero1=False)
    # plan_iteration is now a thin kwarg adapter over the same engine
    auto = plan_iteration(cfg, shape, dp16, topo, policy="serial",
                          dp_params=dpp)
    ring = plan_iteration(cfg, shape, dp16, topo, policy="serial",
                          dp_params=dpp, force={"all_reduce": "ring"})
    print(f"    gradient AR (pure DP): auto=hierarchical comm "
          f"{auto.comm_time:.3f}s vs forced flat ring {ring.comm_time:.3f}s "
          f"({ring.comm_time/max(auto.comm_time, 1e-12):.2f}x)")

    print("=" * 72)
    print("[2b] Compression (repro.compress): error budget admits lossy "
          "candidates")
    # one worker per host on an oversubscribed fat-tree: gradient syncs are
    # bandwidth-bound, the compression sweet spot (canonical copy:
    # benchmarks.paper_claims.bench_compression_candidate, asserted in CI)
    ctopo = fat_tree(num_hosts=8, gpus_per_host=1, oversub=8.0)
    dp8 = MeshConfig(shape=(8,), axis_names=("data",), data_axes=("data",),
                     model_axes=())
    small_cfg = get_config("qwen2-0.5b")
    cdpp = DemandParams(zero1=False)
    base = plan_iteration(small_cfg, shape, dp8, ctopo, policy="serial",
                          dp_params=cdpp)
    for budget in (0.01, 0.5):
        comp = plan_iteration(small_cfg, shape, dp8, ctopo, policy="serial",
                              dp_params=cdpp, error_budget=budget)
        hist = comp.algorithms_by_primitive().get("all_reduce", {})
        picks = ", ".join(f"{a} x{k}" for a, k in sorted(hist.items()))
        print(f"    budget {budget:4.2f}: JCT {base.jct:.3f}s -> "
              f"{comp.jct:.3f}s ({base.jct / comp.jct:.2f}x), wire bytes "
              f"saved {comp.wire_bytes_saved / 2 ** 30:6.2f} GiB  [{picks}]")
    print(f"    budget 0   : baseline keeps every collective exact "
          f"({', '.join(sorted(base.algorithms_by_primitive().get('all_reduce', {})))})")

    print("=" * 72)
    print("[3] Plan-space search: placement=Search() on an oversubscribed "
          "fat-tree")
    # TP-12 over 8-GPU hosts: packed straddles a host boundary 8+4, an
    # uneven partition the hierarchical all-reduce cannot use (canonical
    # copy: benchmarks.paper_claims.bench_placement_search, asserted in CI)
    stopo = fat_tree(num_hosts=4, gpus_per_host=8, hosts_per_rack=1,
                     oversub=8.0, pcie_bw=128e9)
    smesh = MeshConfig(shape=(2, 12), axis_names=("data", "model"))
    sproblem = CodesignProblem(get_config("qwen2-0.5b"), shape, smesh, stopo,
                               space=PlanSpace(placement=Search()))
    sres = search(sproblem, budget=12)
    spacked = plan(sproblem.pinned(placement="packed"))
    print(f"    explored {sres.evaluated} candidates "
          f"(budget {sres.budget}); frontier:")
    for cand in sres.frontier[:4]:
        p = cand.assignment["placement"]
        label = p.strategy if hasattr(p, "strategy") else p
        print(f"      {label:16s} JCT {cand.jct:7.3f}s")
    print(f"    best {sres.best.placement.strategy!r} "
          f"JCT {sres.best.jct:.3f}s vs packed {spacked.jct:.3f}s "
          f"({spacked.jct / sres.best.jct:.2f}x): balanced 6+6 host split "
          f"re-enables hierarchical where packed's 8+4 straddle cannot")
    print("    per-knob attribution of the win:")
    for knob, saved in sres.attribution.items():
        print(f"      {knob:12s} saves {saved:7.3f}s of JCT vs its baseline")
    blob = json.dumps(sres.best.to_dict())
    print(f"    winning plan serializes to JSON "
          f"({len(blob)} bytes via CodesignReport.to_dict)")

    print("=" * 72)
    print("[3b] Overlap search: bucket_bytes x decompose x policy "
          "(demand-DAG knobs)")
    # PCIe-class 8-GPU hosts (64 GB/s intra-host): TP collectives expose
    # real time, gradient buckets compete for the wire (canonical copy:
    # benchmarks.paper_claims.bench_overlap_search, asserted in CI)
    otopo = dgx_cluster(2, nvlink_bw=64e9)
    ocfg = get_config("h2o-danube-1.8b")
    oproblem = CodesignProblem(
        ocfg, shape, DP2_TP8, otopo,
        space=PlanSpace(bucket_bytes=Search(), decompose=Search(),
                        policy=Choice("fifo", "priority")))
    total = sum(t.size_bytes
                for t in build_demand(ocfg, shape, DP2_TP8).comm_tasks
                if t.axis == "data" and t.before_compute == "opt")
    print("    bucket-size ladder vs JCT (fifo, bulk TP collectives):")
    for bb in (None, total, total // 4, total // 16, total // 64):
        r = plan(oproblem.pinned(policy="fifo", bucket_bytes=bb,
                                 decompose=False))
        label = "per-layer" if bb is None else f"{bb / 2 ** 20:.0f} MiB"
        print(f"      bucket {label:>10s}: JCT {r.jct:.3f}s "
              f"exposed {r.exposed_comm:.3f}s")
    onaive = plan(oproblem.pinned(policy="fifo", bucket_bytes=None,
                                  decompose=False))
    ores = search(oproblem, budget=40)
    ba = ores.best_assignment
    print(f"    searched best (of {ores.evaluated}): policy={ba['policy']!r} "
          f"bucket_bytes={ba['bucket_bytes']} decompose={ba['decompose']}")
    print(f"    JCT {onaive.jct:.3f}s -> {ores.best.jct:.3f}s "
          f"({onaive.jct / ores.best.jct:.2f}x vs naive overlap)")
    print("    per-knob attribution of the win:")
    for knob, saved in ores.attribution.items():
        print(f"      {knob:12s} saves {saved:7.3f}s of JCT vs its baseline")
    print("    hottest remaining exposure (task_exposed_s):")
    for tid, s in ores.best.top_exposed_tasks(4):
        print(f"      {tid:18s} {s:7.4f}s")

    print("=" * 72)
    print("[3c] Synthesis as a knob: synthesize=Search() on a flat "
          "8-GPU mesh")
    # latency-regime TP all-reduces: the registry's best (6 serialized
    # halving-doubling steps) pays 3x the synthesized 2-step mesh
    # schedule's alphas; the knob finds and attributes that, per model
    from repro.ccl.primitives import make_synthesized
    from repro.ccl.synth import synthesize_schedule
    from repro.core.types import ShapeConfig
    smesh = MeshConfig(shape=(8,), axis_names=("model",), data_axes=(),
                       model_axes=("model",))
    from repro.net.topology import full_mesh
    stopo = full_mesh(8)
    sproblem = CodesignProblem(
        get_config("qwen2-0.5b"), ShapeConfig("tiny", 64, 1, "train"),
        smesh, stopo, space=PlanSpace(synthesize=Search()))
    for cm in ("alphabeta", "flowsim"):
        import dataclasses as _dc
        sres = search(_dc.replace(sproblem, cost_model=cm), budget=8)
        soff = plan(_dc.replace(sproblem, cost_model=cm).pinned(
            synthesize=False))
        nsyn = len(sres.best.synthesized_choices)
        print(f"    {cm:9s} JCT {soff.jct * 1e3:.3f}ms -> "
              f"{sres.best.jct * 1e3:.3f}ms "
              f"({nsyn} tasks synthesized, knob buys "
              f"{sres.attribution.get('synthesize', 0.0) * 1e3:.3f}ms, "
              f"solver cache {sres.telemetry.get('synth_hit_rate', 0.0):.0%}"
              f" hits)")
    # the winning schedule is executable: lower it to a jitted shard_map
    c = sres.best.synthesized_choices[0]
    sched = synthesize_schedule(
        stopo, CommTask(c.task_id, c.primitive, c.size_bytes, c.group))
    assert callable(make_synthesized)  # winner lowers to a jitted shard_map
    print(f"    winner ({c.primitive}, {c.size_bytes / 2 ** 10:.0f} KiB): "
          f"{sched.num_steps} ppermute steps, {len(sched.moves)} moves, "
          f"ring-equal wire bytes ({sched.wire_bytes() / 2 ** 10:.0f} KiB); "
          f"make_synthesized(sched, mesh, axis) executes it")

    print("=" * 72)
    print("[4] CCL: algorithm selection per payload, AlphaBeta vs FlowSim")
    ab = AlphaBeta.from_topology(topo)
    fsim = FlowSim(topo)
    group = tuple(topo.accelerators)
    for size in (2 ** 12, 2 ** 20, 2 ** 28):
        task = CommTask("ar", "all_reduce", size, group)
        sa = select_for_task(task, ab)
        sf = select_for_task(task, fsim)
        print(f"    all_reduce {size:>12,d} B -> closed-form "
              f"{sa.algorithm:14s} ({sa.cost*1e6:9.1f} us) | topology-sim "
              f"{sf.algorithm:14s} ({sf.cost*1e6:9.1f} us)")
    task = CommTask("ag", "all_gather", 2 ** 22, group)
    ring_t = simulate_flowset(topo, generate_flows(task, "ring"))
    syn = synthesize(topo, task, Sketch(max_hops=4))
    print(f"    TACCL-style synthesis on DGXx2 all-gather: ring "
          f"{ring_t*1e3:.2f} ms -> synthesized {syn.makespan*1e3:.2f} ms "
          f"({ring_t/syn.makespan:.2f}x)")

    print("=" * 72)
    print("[5] Flow scheduler (horizontal): two jobs on one link (CASSINI)")
    jobs = [JobProfile("jobA", 0.012, 0.008), JobProfile("jobB", 0.010, 0.010)]
    phases, base, best = stagger_jobs(jobs, grid=6)
    for j in jobs:
        print(f"    {j.name}: unstaggered {base[j.name]*1e3:6.2f} ms/iter"
              f" -> staggered {best[j.name]*1e3:6.2f} ms/iter "
              f"(period {j.period*1e3:.0f} ms)")

    print("    --- plan_cluster: the same idea on real CodesignReports ---")
    # (spelled out for the walkthrough; the canonical copy of this scenario
    # is benchmarks.paper_claims._contended_cluster, asserted in CI)
    small = get_config("qwen2-0.5b")
    ctopo = fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=2,
                     nic_bw=2e9, agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    dp4 = MeshConfig(shape=(4,), axis_names=("data",), data_axes=("data",),
                     model_axes=())
    dpp = DemandParams(zero1=False)
    crep = plan_cluster(
        [JobSpec("tenantA", small, shape, dp4,
                 devices=ctopo.hosts[0] + ctopo.hosts[2], dp_params=dpp),
         # a JobSpec can carry a full CodesignProblem instead of flat knobs
         JobSpec("tenantB", devices=ctopo.hosts[1] + ctopo.hosts[3],
                 problem=CodesignProblem(small, shape, dp4, ctopo,
                                         dp_params=dpp))],
        ctopo, grid=6)
    print(f"    two DP-4 tenants straddling both racks of {ctopo.name}: "
          f"{len(crep.contended)} contended links")
    for (u, v), users in list(crep.contended.items())[:2]:
        share = ", ".join(f"{j} {b/2**30:.2f} GiB" for j, b in users.items())
        print(f"      {u!s:>6s} -> {v!s:<6s} {share}")
    for name in crep.solo_jct:
        print(f"    {name}: solo {crep.solo_jct[name]:6.3f}s | naive "
              f"{crep.naive_jct[name]:6.3f}s | staggered "
              f"{crep.staggered_jct[name]:6.3f}s "
              f"(phase +{crep.phases[name]*1e3:.0f} ms)")
    print(f"    worst-case stretch {crep.naive_worst_stretch:.4f} -> "
          f"{crep.staggered_worst_stretch:.4f} "
          f"({crep.stagger_speedup:.3f}x recovered)")

    print("=" * 72)
    print("[6] Network: same ring all-reduce, different fabrics")
    n = 256
    t = CommTask("ar", "all_reduce", 256 * 2 ** 20, tuple(range(n)))
    fs = generate_flows(t, "ring")
    for name, topo2 in (("torus 16x16 (TPU pod)", torus2d(16, 16)),
                        ("fat-tree 8x oversub",
                         fat_tree(n // 8, oversub=8.0))):
        print(f"    {name:24s} {simulate_flowset(topo2, fs)*1e3:8.2f} ms")
    print("=" * 72)


if __name__ == "__main__":
    main()
