"""The paper-specific walkthrough: one training job through all five layers
of the communication-optimization paradigm (Fig. 5a).

  1. Para.   — pick an architecture + mesh; emit its CommDemand
  2. Task sched. (vertical) — overlap/priority policies vs exposed comm
  3. CCL     — per-task algorithm selection (NCCL-style) + TACCL synthesis
  4. Flow sched. (horizontal) — two jobs sharing links, CASSINI staggering
  5. Net.    — the same collective on torus vs oversubscribed fat-tree

    PYTHONPATH=src python examples/comm_codesign.py --arch dbrx-132b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.ccl.cost import CostParams, algo_cost
from repro.ccl.select import select_algorithm
from repro.ccl.synth import Sketch, synthesize
from repro.configs import ARCHS, get_config
from repro.core.demand import CommTask
from repro.core.demand_builder import (DemandParams, build_demand,
                                       janus_traffic_ratio)
from repro.core.types import SHAPES_BY_NAME, SINGLE_POD_MESH
from repro.net.simulate import simulate_flowset
from repro.net.topology import dgx_cluster, fat_tree, torus2d
from repro.ccl.algorithms import generate_flows
from repro.sched.flows import JobProfile, stagger_jobs
from repro.sched.tasks import simulate_iteration


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b", choices=ARCHS)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME["train_4k"]

    print("=" * 72)
    print(f"[1] Parallelization strategy -> CommDemand   ({cfg.name})")
    dem = build_demand(cfg, shape, SINGLE_POD_MESH, DemandParams())
    by_prim = dem.by_primitive()
    for prim, nbytes in sorted(by_prim.items()):
        print(f"    {prim:15s} {nbytes/2**30:8.2f} GiB per iteration")
    if cfg.is_moe:
        jr = janus_traffic_ratio(cfg, shape, SINGLE_POD_MESH)
        print(f"    (Janus check: expert-centric/data-centric traffic = "
              f"{jr['ratio']:.1f}x)")

    print("=" * 72)
    print("[2] Task scheduler (vertical co-design): exposed communication")
    cp = CostParams(alpha=1e-6, link_bw=50e9)

    def cost(t):
        if t.primitive == "all_reduce":
            return select_algorithm(t.primitive, t.size_bytes,
                                    len(t.group), cp)[1]
        return algo_cost(t.primitive,
                         "direct" if t.primitive == "all_to_all" else "ring",
                         t.size_bytes, len(t.group), cp)

    for pol in ("serial", "fifo", "priority", "preempt"):
        r = simulate_iteration(dem, cost, pol)
        print(f"    {pol:9s} JCT={r.jct:7.3f}s exposed={r.exposed_comm:6.3f}s"
              f" ({100*r.comm_fraction:4.1f}%)")

    print("=" * 72)
    print("[3] CCL: algorithm selection per payload (ICI cost model)")
    for size in (2 ** 12, 2 ** 20, 2 ** 28):
        best, c, costs = select_algorithm("all_reduce", size, 16, cp)
        print(f"    all_reduce {size:>12,d} B -> {best:18s} "
              f"({c*1e6:9.1f} us; " +
              ", ".join(f"{k}={v*1e6:.1f}us" for k, v in costs.items())
              + ")")
    topo = dgx_cluster(2)
    task = CommTask("ag", "all_gather", 2 ** 22, tuple(topo.accelerators))
    ring_t = simulate_flowset(topo, generate_flows(task, "ring"))
    syn = synthesize(topo, task, Sketch(max_hops=4))
    print(f"    TACCL-style synthesis on DGXx2 all-gather: ring "
          f"{ring_t*1e3:.2f} ms -> synthesized {syn.makespan*1e3:.2f} ms "
          f"({ring_t/syn.makespan:.2f}x)")

    print("=" * 72)
    print("[4] Flow scheduler (horizontal): two jobs on one link (CASSINI)")
    jobs = [JobProfile("jobA", 0.012, 0.008), JobProfile("jobB", 0.010, 0.010)]
    phases, base, best = stagger_jobs(jobs, grid=6)
    for j in jobs:
        print(f"    {j.name}: unstaggered {base[j.name]*1e3:6.2f} ms/iter"
              f" -> staggered {best[j.name]*1e3:6.2f} ms/iter "
              f"(period {j.period*1e3:.0f} ms)")

    print("=" * 72)
    print("[5] Network: same ring all-reduce, different fabrics")
    n = 256
    t = CommTask("ar", "all_reduce", 256 * 2 ** 20, tuple(range(n)))
    fs = generate_flows(t, "ring")
    for name, topo2 in (("torus 16x16 (TPU pod)", torus2d(16, 16)),
                        ("fat-tree 8x oversub",
                         fat_tree(n // 8, oversub=8.0))):
        print(f"    {name:24s} {simulate_flowset(topo2, fs)*1e3:8.2f} ms")
    print("=" * 72)


if __name__ == "__main__":
    main()
