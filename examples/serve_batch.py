"""Batched serving: prefill a batch of prompts, then decode continuously,
reporting per-step latency and aggregate tokens/s — the serving-side driver
(deliverable b).  Works for every architecture family, including the
attention-free (mamba2) and hybrid (jamba) decode paths.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-130m

``--codesign`` adds the modeled half: a serving tenant co-scheduled next
to a training tenant on a shared fat-tree via ``plan_cluster``, printing
SLO attainment and the naive-vs-staggered tail latency
(``repro.codesign.serving``).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.data.stubs import audio_frames, vision_patches
from repro.models import encode, init_cache, init_params
from repro.serve.step import make_serve_step


def codesign_cotenancy():
    """Mixed training + serving co-tenancy through the codesign engine:
    one DP-4 training tenant and one disaggregated serving tenant share
    the tor<->agg uplinks of an oversubscribed fat-tree."""
    from repro.codesign import (JobSpec, ServingSLO, ServingSpec,
                                plan_cluster)
    from repro.configs import get_config
    from repro.core.demand_builder import DemandParams
    from repro.core.types import MeshConfig, SHAPES_BY_NAME
    from repro.net.topology import fat_tree
    from repro.sched.arrivals import PoissonArrivals

    topo = fat_tree(num_hosts=4, gpus_per_host=2, hosts_per_rack=2,
                    nic_bw=2e9, agg_bw=8e9, oversub=4.0, pcie_bw=4e9)
    cfg = get_config("qwen2-0.5b")
    mesh = MeshConfig(shape=(4,), axis_names=("data",),
                      data_axes=("data",), model_axes=())
    train = JobSpec("train", cfg, SHAPES_BY_NAME["train_4k"], mesh,
                    policy="serial",
                    devices=topo.hosts[0] + topo.hosts[2],
                    dp_params=DemandParams(zero1=False))
    svc = ServingSpec(name="svc", cfg=cfg, prefill_devices=2,
                      decode_devices=2,
                      arrivals=PoissonArrivals(rate_rps=3.0,
                                               prompt_tokens=1024,
                                               decode_tokens=32, seed=0),
                      slo=ServingSLO(ttft_s=0.05, tpot_s=0.01),
                      prefill_batch=1, decode_slots=8, horizon_s=8.0)
    serve = JobSpec("svc", serving=svc,
                    devices=topo.hosts[1] + topo.hosts[3])
    rep = plan_cluster([train, serve], topo, grid=6)
    sm = rep.serving["svc"]
    print(f"\nco-tenancy on shared fabric "
          f"({len(rep.contended)} contended links):")
    print(f"  training JCT: solo {rep.solo_jct['train']:.3f}s -> "
          f"co-tenant {rep.staggered_jct['train']:.3f}s")
    print(f"  serving burst stretch: naive "
          f"{sm['naive_burst_stretch']:.4f} -> staggered "
          f"{sm['staggered_burst_stretch']:.4f}")
    print(f"  serving TTFT p99: naive {sm['naive_ttft_p99']*1e3:.2f}ms "
          f"-> staggered {sm['staggered_ttft_p99']*1e3:.2f}ms")
    print(f"  SLO attainment: {sm['staggered_slo_attainment']:.2%}  "
          f"goodput {sm['staggered_goodput']:.1f} req/s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--codesign", action="store_true",
                    help="also model training/serving co-tenancy on a "
                         "shared fat-tree (plan_cluster)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_len}")

    context = None
    if cfg.is_encoder_decoder:
        context = encode(cfg, params, jnp.asarray(
            audio_frames(cfg, args.batch)))
        print(f"  encoder context: {context.shape}")
    elif cfg.cross_attn_period:
        context = jnp.asarray(vision_patches(cfg, args.batch))
        print(f"  vision context: {context.shape}")

    max_len = args.prompt_len + args.gen_len
    cache = init_cache(cfg, params, args.batch, max_len, context=context)
    serve = jax.jit(make_serve_step(cfg, temperature=args.temperature))

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    # prefill by feeding the prompt through decode steps (cache-exact)
    tok = None
    t0 = time.time()
    for t in range(args.prompt_len):
        tok, _, cache = serve(params, cache, prompts[:, t:t + 1], t,
                              jax.random.fold_in(key, t))
    prefill_s = time.time() - t0

    outs = []
    lat = []
    for t in range(args.prompt_len, max_len):
        t1 = time.time()
        tok, _, cache = serve(params, cache, tok, t,
                              jax.random.fold_in(key, t))
        tok.block_until_ready()
        lat.append(time.time() - t1)
        outs.append(np.asarray(tok[:, 0]))
    gen = np.stack(outs, axis=1)
    assert gen.max() < cfg.vocab_size  # padding logits masked
    total = args.batch * args.gen_len
    print(f"prefill: {prefill_s*1e3:.1f} ms")
    print(f"decode:  p50={np.percentile(lat, 50)*1e3:.2f} ms/step  "
          f"p99={np.percentile(lat, 99)*1e3:.2f} ms/step  "
          f"throughput={total/sum(lat):,.0f} tok/s")
    print("sample:", gen[0][:24].tolist())

    if args.codesign:
        codesign_cotenancy()


if __name__ == "__main__":
    main()
