"""Batched serving: prefill a batch of prompts, then decode continuously,
reporting per-step latency and aggregate tokens/s — the serving-side driver
(deliverable b).  Works for every architecture family, including the
attention-free (mamba2) and hybrid (jamba) decode paths.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-130m
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.data.stubs import audio_frames, vision_patches
from repro.models import encode, init_cache, init_params
from repro.serve.step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_len}")

    context = None
    if cfg.is_encoder_decoder:
        context = encode(cfg, params, jnp.asarray(
            audio_frames(cfg, args.batch)))
        print(f"  encoder context: {context.shape}")
    elif cfg.cross_attn_period:
        context = jnp.asarray(vision_patches(cfg, args.batch))
        print(f"  vision context: {context.shape}")

    max_len = args.prompt_len + args.gen_len
    cache = init_cache(cfg, params, args.batch, max_len, context=context)
    serve = jax.jit(make_serve_step(cfg, temperature=args.temperature))

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    # prefill by feeding the prompt through decode steps (cache-exact)
    tok = None
    t0 = time.time()
    for t in range(args.prompt_len):
        tok, _, cache = serve(params, cache, prompts[:, t:t + 1], t,
                              jax.random.fold_in(key, t))
    prefill_s = time.time() - t0

    outs = []
    lat = []
    for t in range(args.prompt_len, max_len):
        t1 = time.time()
        tok, _, cache = serve(params, cache, tok, t,
                              jax.random.fold_in(key, t))
        tok.block_until_ready()
        lat.append(time.time() - t1)
        outs.append(np.asarray(tok[:, 0]))
    gen = np.stack(outs, axis=1)
    assert gen.max() < cfg.vocab_size  # padding logits masked
    total = args.batch * args.gen_len
    print(f"prefill: {prefill_s*1e3:.1f} ms")
    print(f"decode:  p50={np.percentile(lat, 50)*1e3:.2f} ms/step  "
          f"p99={np.percentile(lat, 99)*1e3:.2f} ms/step  "
          f"throughput={total/sum(lat):,.0f} tok/s")
    print("sample:", gen[0][:24].tolist())


if __name__ == "__main__":
    main()
