"""End-to-end training driver: a ~100M-parameter dense model trained for a
few hundred steps on the synthetic pipeline, with LR schedule, gradient
clipping, checkpointing and eval — the (b) deliverable's train driver.

    PYTHONPATH=src python examples/train_100m.py --steps 300

CPU note: ~100M params at seq 256 is a few seconds/step on one core; use
``--d-model 384 --layers 6 --steps 100`` for a faster demonstration run.
"""
import argparse
import dataclasses
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.types import TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.optim.adamw import init_opt_state
from repro.train.step import make_eval_step, make_train_step


def build_config(args):
    """~100M-param member of the qwen2 family (GQA + QKV-bias + SwiGLU)."""
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(
        base, name="qwen2-100m", num_layers=args.layers,
        d_model=args.d_model, num_heads=args.d_model // 64, num_kv_heads=2,
        head_dim=64, d_ff=args.d_model * 4, vocab_size=args.vocab,
        tie_embeddings=True, max_seq_len=args.seq)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--eval-every", type=int, default=50)
    args = ap.parse_args()

    cfg = build_config(args)
    n = cfg.param_counts()["total"]
    print(f"model: {cfg.name} = {n/1e6:.1f}M params "
          f"(L={cfg.num_layers}, d={cfg.d_model}, V={cfg.vocab_size})")

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps, grad_clip=1.0, remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    evaluate = jax.jit(make_eval_step(cfg))

    # same seed => same bigram permutation; eval uses a held-out epoch so
    # the sequences (start tokens) differ but the task is the same
    train_ds = SyntheticLM(cfg.vocab_size, args.seq, seed=0)
    eval_ds = SyntheticLM(cfg.vocab_size, args.seq, seed=0)
    eval_batch = {k: jnp.asarray(v)
                  for k, v in eval_ds.batch(1, 0, args.batch).items()}
    uniform = math.log(cfg.vocab_size)
    print(f"uniform-baseline loss = {uniform:.3f}")

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 train_ds.batch(0, i * args.batch, args.batch).items()}
        params, opt, m = step(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            tps = (i + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} tok/s={tps:,.0f}")
        if i and i % args.eval_every == 0:
            print(f"  eval ce={float(evaluate(params, eval_batch)):.4f}")

    eval_ce = float(evaluate(params, eval_batch))
    print(f"final eval ce={eval_ce:.4f} (uniform {uniform:.3f})")
    path = save_checkpoint(args.ckpt_dir, args.steps, params, opt,
                           extra={"eval_ce": eval_ce})
    print(f"checkpoint written: {path}")
    p2, _, s = restore_checkpoint(path, params)
    assert s == args.steps
    print("checkpoint restore verified")


if __name__ == "__main__":
    main()
