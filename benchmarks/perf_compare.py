"""§Perf comparison printer: baseline vs hillclimb variants per pair.

    PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")

PAIRS = [
    ("starcoder2-3b", "train_4k"),
    ("jamba-1.5-large-398b", "decode_32k"),
    ("deepseek-v2-236b", "train_4k"),
]


def fmt(s):
    return f"{s:.3f}s" if s >= 0.1 else f"{s*1e3:.1f}ms"


def main():
    for arch, shape in PAIRS:
        base_fp = os.path.join(RESULTS_DIR, f"{arch}_{shape}_16x16.json")
        variants = sorted(
            f for f in glob.glob(os.path.join(
                RESULTS_DIR, f"{arch}_{shape}_16x16_*.json")))
        if not os.path.exists(base_fp):
            print(f"missing baseline for {arch} x {shape}")
            continue
        base = json.load(open(base_fp))
        print(f"\n## {arch} x {shape}")
        print("| variant | compute | memory | collective | dominant | "
              "useful | temp GiB | Δdominant |")
        print("|---|---|---|---|---|---|---|---|")

        def row(r, name, base_dom=None):
            t = r["roofline"]
            dom_key = r["dominant"]
            delta = ""
            if base_dom is not None:
                delta = f"{base_dom / t[base_dom_key] :.2f}x" \
                    if t[base_dom_key] else ""
            print(f"| {name} | {fmt(t['compute_s'])} | {fmt(t['memory_s'])}"
                  f" | {fmt(t['collective_s'])} | {dom_key[:-2]} "
                  f"| {r['useful_flops_ratio']:.2f} "
                  f"| {r['memory']['temp_size_in_bytes']/2**30:.1f} "
                  f"| {delta} |")

        base_dom_key = base["dominant"]
        base_dom = base["roofline"][base_dom_key]
        row(base, "baseline")
        for vf in variants:
            v = json.load(open(vf))
            name = os.path.basename(vf).split("16x16_")[1][:-5]
            row(v, name, base_dom)


if __name__ == "__main__":
    main()
